"""DRA benchmark (Fig. 12 / Table 7): DLG gradient-inversion quality vs
the fraction of the update exposed to the attacker (1/A), and vs DSC
compression on top."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from benchmarks.common import KEY
from repro.core import masks as masks_lib
from repro.core import privacy
from repro.core.compressors import RandP


def _setup(dim=64, classes=4, hidden=4):
    """Small hidden width => the first-layer gradient (the outer product
    x . delta^T that DLG exploits) has only ``hidden`` entries per input
    coordinate, so FSA sharding quickly makes the attack underdetermined —
    the shallow-model analogue of the paper's Fig. 12 degradation."""
    k1, _ = jax.random.split(KEY)
    params0 = {"w1": 0.4 * jax.random.normal(k1, (dim, hidden)),
               "b1": jnp.zeros(hidden),
               "w2": 0.4 * jax.random.normal(jax.random.fold_in(k1, 1),
                                             (hidden, classes)),
               "b2": jnp.zeros(classes)}
    x_flat, unravel = ravel_pytree(params0)

    def loss_single(xf, inp, label):
        p = unravel(xf)
        h = jnp.tanh(inp @ p["w1"] + p["b1"])
        return -jax.nn.log_softmax(h @ p["w2"] + p["b2"])[label]

    return x_flat, jax.grad(loss_single), dim


def run(quick: bool = True):
    steps = 300 if quick else 800
    x_flat, grad_fn, dim = _setup()
    target = jax.random.normal(jax.random.fold_in(KEY, 2), (dim,))
    label = jnp.int32(2)
    g_true = grad_fn(x_flat, target, label)
    n = x_flat.shape[0]
    rows = []
    for A in (1, 2, 4, 8, 16):
        assign = masks_lib.make_assignment(n, A, "strided")
        obs = masks_lib.mask_for(assign, 0)
        out = privacy.dlg_attack(jax.random.fold_in(KEY, 3), grad_fn,
                                 x_flat, g_true * obs, obs, (dim,), label,
                                 steps=steps, lr=0.05)
        mse = privacy.reconstruction_mse(out["reconstruction"], target)
        rows.append({"name": f"reconstruction/dlg/A={A}",
                     "us_per_call": 0.0,
                     "derived": f"recon_mse={mse:.3f} "
                                f"observed_frac={1.0/A:.3f}"})
    # DSC on top of FSA (A=2): compression alone vs combined (Table 7)
    for p in (0.5, 0.1):
        comp = RandP(p=p)
        v = comp(jax.random.fold_in(KEY, 4), g_true)
        assign = masks_lib.make_assignment(n, 2, "strided")
        obs = masks_lib.mask_for(assign, 0) * (v != 0)
        out = privacy.dlg_attack(jax.random.fold_in(KEY, 5), grad_fn,
                                 x_flat, v * obs, obs.astype(jnp.float32),
                                 (dim,), label, steps=steps, lr=0.05)
        mse = privacy.reconstruction_mse(out["reconstruction"], target)
        rows.append({"name": f"reconstruction/dlg_dsc/A=2,p={p}",
                     "us_per_call": 0.0,
                     "derived": f"recon_mse={mse:.3f} "
                                f"observed_frac={p/2:.3f}"})
    return rows
