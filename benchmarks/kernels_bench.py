"""Kernel micro-benchmarks: wall time of the jnp reference paths on this
host (the Pallas kernels themselves are TPU programs validated in
interpret mode — interpret wall-time is not meaningful) + derived
bytes/flops so the TPU-side roofline expectation is recorded."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import time_call
from repro.kernels import ref

KEY = jax.random.PRNGKey(0)


def run(quick: bool = True):
    rows = []
    n = 1 << 20 if quick else 1 << 24
    g = jax.random.normal(KEY, (n,))
    s = jax.random.normal(jax.random.fold_in(KEY, 1), (n,))
    f = jax.jit(lambda g, s: ref.dsc_update_ref(g, s, jnp.uint32(1), 0.1,
                                                0.5))
    us = time_call(f, g, s)
    bytes_moved = n * (4 + 4 + 4 + 4)
    rows.append({"name": "kernels/dsc_update_ref",
                 "us_per_call": us,
                 "derived": f"n={n} hbm_bytes={bytes_moved} "
                            f"tpu_time_at_819GBps_us="
                            f"{bytes_moved/819e9*1e6:.1f}"})
    # scan-path measurement: T fused DSC rounds as ONE compiled program
    # (the pipeline's scan driver shape) vs T separate jitted dispatches.
    T = 50

    def one_round(s, seed):
        v, s = ref.dsc_update_ref(g, s, seed, 0.1, 0.5)
        return s, v.sum()

    scanned = jax.jit(lambda s: jax.lax.scan(
        one_round, s, jnp.arange(T, dtype=jnp.uint32)))
    us_scan = time_call(scanned, s, reps=5, warmup=2)

    stepped = jax.jit(one_round)

    def loop(s0):
        s = s0
        for t in range(T):
            s, _ = stepped(s, jnp.uint32(t))
        return s
    us_loop = time_call(loop, s, reps=5, warmup=2)
    rows.append({"name": "kernels/dsc_update_scan_path",
                 "us_per_call": us_scan,
                 "derived": f"T={T} loop_us={us_loop:.0f} "
                            f"scan_us={us_scan:.0f} "
                            f"dispatch_amortization="
                            f"{us_loop / max(us_scan, 1e-9):.2f}x"})
    q = jax.jit(lambda x: ref.quantize_ref(x, jnp.uint32(3)))
    us = time_call(q, g)
    rows.append({"name": "kernels/quantize_ref",
                 "us_per_call": us,
                 "derived": f"n={n} wire_bytes={n + 4*n//256} "
                            f"compression_vs_bf16={2*n/(n+4*n//256):.2f}x"})
    # fused vs unfused DSC->int8 wire: the unfused chain (mask/shift ->
    # quantize -> dequantize -> shift update) sweeps HBM four times; the
    # one-pass kernels/dsc_quantize does everything per VMEM block.
    # Wall time below is the composed jnp reference (what the fused
    # kernel replaces); the HBM accounting is the TPU-side expectation.
    fused = jax.jit(lambda g, s: ref.dsc_quantize_ref(
        g, s, jnp.uint32(5), jnp.uint32(7), p=0.1, gamma=0.5))
    us = time_call(fused, g, s)
    scale_b = 4 * n // 256
    unfused_b = n * (12 + 5 + 5 + 12) + 2 * scale_b   # 4 sweeps + scales
    fused_b = n * (4 + 4 + 1 + 4) + scale_b           # g,s in; q,s' out
    rows.append({"name": "kernels/dsc_quantize_fused_vs_unfused",
                 "us_per_call": us,
                 "derived": f"n={n} unfused_hbm_B/coord="
                            f"{unfused_b/n:.2f} fused_hbm_B/coord="
                            f"{fused_b/n:.2f} "
                            f"sweep_reduction={unfused_b/fused_b:.2f}x "
                            f"tpu_time_at_819GBps_us="
                            f"{fused_b/819e9*1e6:.1f}"})
    B, H, S, d = (1, 4, 1024, 64) if quick else (4, 16, 4096, 128)
    qkv = [jax.random.normal(jax.random.fold_in(KEY, i), (B, H, S, d))
           for i in range(3)]
    fa = jax.jit(lambda a, b, c: ref.flash_attention_ref(a, b, c))
    us = time_call(fa, *qkv)
    flops = 4 * B * H * S * S * d
    rows.append({"name": "kernels/flash_attention_ref",
                 "us_per_call": us,
                 "derived": f"BHSd={B}x{H}x{S}x{d} flops={flops:.2e} "
                            f"tpu_time_at_197TFs_us={flops/197e12*1e6:.1f}"})
    # flash vs naive training-forward HBM traffic: naive materializes the
    # S x S score matrix to HBM across the softmax sweeps (and again in
    # the backward); flash re-reads K/V per query block and never spills
    # scores.  Wall time is the naive jnp path flash replaces.
    block_q = 128
    qkv_b = 3 * B * H * S * d * 4
    naive_b = qkv_b + B * H * S * S * 4 * 4 + B * H * S * d * 4
    flash_b = qkv_b + (S // block_q - 1) * 2 * B * H * S * d * 4 \
        + B * H * S * d * 4
    rows.append({"name": "kernels/flash_vs_naive_attention",
                 "us_per_call": us,
                 "derived": f"BHSd={B}x{H}x{S}x{d} "
                            f"naive_hbm_B={naive_b:.3e} "
                            f"flash_hbm_B={flash_b:.3e} "
                            f"hbm_reduction={naive_b/flash_b:.1f}x "
                            f"tpu_time_at_819GBps_us="
                            f"{flash_b/819e9*1e6:.1f}"})
    return rows
