"""Shared benchmark utilities: timing + the standard small FL problem."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.fl import FLConfig, FLRun
from repro.data import federated_classification

KEY = jax.random.PRNGKey(0)
DIM, CLASSES = 8, 3


def time_call(fn, *args, reps: int = 20, warmup: int = 3) -> float:
    """Median wall-time (us) of a jitted callable."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def mlp_problem(key=KEY, K: int = 6, S: int = 16, hidden: int = 16,
                alpha=None):
    """Returns (data=(x, y), init_fn, loss_fn, acc_fn)."""
    x, y = federated_classification(key, K, S, dim=DIM, n_classes=CLASSES,
                                    alpha=alpha)

    def init(key):
        k1, k2 = jax.random.split(key)
        return {"w1": 0.3 * jax.random.normal(k1, (DIM, hidden)),
                "b1": jnp.zeros(hidden),
                "w2": 0.3 * jax.random.normal(k2, (hidden, CLASSES)),
                "b2": jnp.zeros(CLASSES)}

    def loss_fn(p, batch):
        xx, yy = batch
        h = jnp.tanh(xx @ p["w1"] + p["b1"])
        logits = h @ p["w2"] + p["b2"]
        return -jnp.take_along_axis(jax.nn.log_softmax(logits),
                                    yy[:, None], 1).mean()

    def acc_fn(p, batch):
        xx, yy = batch
        h = jnp.tanh(xx @ p["w1"] + p["b1"])
        return float((jnp.argmax(h @ p["w2"] + p["b2"], -1) == yy).mean())

    return (x, y), init, loss_fn, acc_fn


def run_method(cfg: FLConfig, data, init, loss_fn, collect=False):
    """Run a method; returns (run, x_traj, views_client0)."""
    run = FLRun(cfg, init(KEY), loss_fn)
    xs, views = [], []
    for t in range(cfg.rounds):
        if collect:
            xs.append(run.x)
            v = run.step(data, collect_views=True)
            views.append(v[0] if v is not None else jnp.zeros(run.n))
        else:
            run.step(data)
    return run, xs, views
