"""Privacy-leakage snapshot: commit the empirical Thm 3.3 trajectory.

Distills the scan-compiled audit harness (``repro.privacy.harness``)
into one committed ``BENCH_privacy.json`` at the repo root, next to
``BENCH_tp.json``: MIA AUC (with bootstrap CIs), balanced accuracy and
DLG scale-invariant reconstruction MSE as functions of the aggregator
count A in {1, 2, 4, 8, 16}, with and without the DSC shifted wire and
the int8 wire round trip, plus the Cor. D.2 collusion curve, the
sampling-amplified curve (AUC vs per-round participation probability q
at fixed A, run on the buffered async engine whose arrival model zeroes
a skipped client's wire rows) and a transformer-family (config-zoo)
slice.  The nightly CI job regenerates
the snapshot into its run artifacts and FAILS on leakage-monotonicity
violations (:func:`check_snapshot`) — intervals are compared, not point
estimates — and on drift outside the committed entries' CI bands.

    PYTHONPATH=src:. python benchmarks/privacy_snapshot.py --regen --check
"""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

SNAPSHOT = Path(__file__).resolve().parent.parent / "BENCH_privacy.json"

A_GRID = (1, 2, 4, 8, 16)
LM_A_GRID = (1, 4, 16)
Q_GRID = (0.125, 0.25, 0.5, 1.0)   # per-round participation (sampling)
SAMPLING_A = 4                     # fixed aggregator count for the q curve
SEEDS = (0, 1, 2)
MIA_KW = dict(rounds=40, lr=0.5, n_canaries=24, n_bootstrap=200)
MIA_DIM = 16
VARIANTS = {
    "base": dict(),
    "dsc": dict(use_dsc=True, p=0.5),
    "dsc_int8": dict(use_dsc=True, p=1.0, int8_wire=True),
}


def _mean_ci(results: list[dict]) -> dict:
    """Seed-average the audit metrics; CIs average bound-wise (the gate
    compares the averaged intervals)."""
    out = {
        "auc": float(np.mean([r["auc"] for r in results])),
        "bal_acc": float(np.mean([r["balanced_accuracy"]
                                  for r in results])),
        "auc_ci": [float(np.mean([r["auc_ci"][i] for r in results]))
                   for i in (0, 1)],
        "bal_acc_ci": [float(np.mean([r["bal_acc_ci"][i] for r in results]))
                       for i in (0, 1)],
        "mi_bound": float(results[0]["mi_bound"]),
        "seeds": len(results),
    }
    return out


def generate() -> dict:
    """Run the full audit grid (a few minutes on CPU)."""
    from repro.privacy import harness
    snap: dict = {}
    # ---- Fig. 2: MIA vs A, per wire variant (MLP, seed-averaged) -------
    for vname, vkw in VARIANTS.items():
        for A in A_GRID:
            runs = [harness.mia_mlp(
                harness.AuditSpec(A=A, seed=s, **vkw, **MIA_KW),
                dim=MIA_DIM) for s in SEEDS]
            snap[f"mia/mlp/{vname}/A={A}"] = _mean_ci(runs)
    # ---- sampling amplification: AUC vs q at fixed A (async engine) ----
    for q in Q_GRID:
        runs = [harness.mia_mlp(
            harness.AuditSpec(A=SAMPLING_A, q=q, seed=s, **MIA_KW),
            dim=MIA_DIM) for s in SEEDS]
        snap[f"mia/mlp/sampling/A={SAMPLING_A}/q={q}"] = _mean_ci(runs)
    # ---- Fig. 5: collusion curve at A = 8 (one run, vmapped sweep) -----
    sweeps = [harness.mia_mlp_collusion_sweep(
        harness.AuditSpec(A=8, seed=s, **MIA_KW), dim=MIA_DIM)
        for s in SEEDS]
    for i, a_c in enumerate(sweeps[0]["a_c"]):
        runs = [{"auc": float(s["auc"][i]),
                 "balanced_accuracy": float(s["balanced_accuracy"][i]),
                 "auc_ci": [float(s["auc_ci"][i][0]),
                            float(s["auc_ci"][i][1])],
                 "bal_acc_ci": [float(s["bal_acc_ci"][i][0]),
                                float(s["bal_acc_ci"][i][1])],
                 "mi_bound": 0.0} for s in sweeps]
        ent = _mean_ci(runs)
        del ent["mi_bound"]
        snap[f"mia/mlp/collusion/A=8/ac={int(a_c)}"] = ent
    # ---- config-zoo slice: transformer canary audit --------------------
    cfg = harness.tiny_lm_config()
    for A in LM_A_GRID:
        runs = [harness.mia_lm(cfg, harness.AuditSpec(
            A=A, K=2, rounds=8, n_canaries=6, lr=0.5, seed=s,
            n_bootstrap=200)) for s in SEEDS[:2]]
        snap[f"mia/lm/base/A={A}"] = _mean_ci(runs)
    # ---- Fig. 12: DLG reconstruction vs A, f32 vs int8 wire ------------
    for wire in ("f32", "int8"):
        per_seed = [harness.dlg_mlp(A_GRID, wire=wire, seed=s, steps=400)
                    for s in SEEDS]
        for A in A_GRID:
            snap[f"dlg/mlp/{wire}/A={A}"] = {
                "si_mse": float(np.mean([d[A] for d in per_seed])),
                "seeds": len(per_seed)}
    lm_dlg = {w: harness.dlg_lm(cfg, LM_A_GRID, wire=w, steps=200)
              for w in ("f32", "int8")}
    for w, d in lm_dlg.items():
        for A in LM_A_GRID:
            snap[f"dlg/lm/{w}/A={A}"] = {"si_mse": float(d[A]), "seeds": 1}
    return snap


# ------------------------------------------------------------ the gate
def _curves(snap: dict, prefix: str) -> dict:
    """Group entries of one metric family into {curve: {A: entry}}."""
    out: dict = {}
    for key, ent in snap.items():
        # sampling entries end in /q=<float>: rpartition on /A= would
        # choke on the tail — they get their own gate below
        if (not key.startswith(prefix) or "/collusion/" in key
                or "/q=" in key):
            continue
        curve, _, a = key.rpartition("/A=")
        out.setdefault(curve, {})[int(a)] = ent
    return out


def check_snapshot(snap: dict, slack: float = 0.0) -> list[str]:
    """Thm 3.3 / Cor. D.2 gates on a snapshot.  Interval-compared:
    a violation needs the ENTIRE CI at larger A above the entire CI at
    smaller A.  Returns human-readable violation strings (empty = pass).
    """
    bad = []
    # MIA: AUC monotone non-increasing in A, per curve
    for curve, ents in _curves(snap, "mia/").items():
        As = sorted(ents)
        for i, a_lo in enumerate(As):
            for a_hi in As[i + 1:]:
                lo_ci, hi_ci = ents[a_lo]["auc_ci"], ents[a_hi]["auc_ci"]
                if hi_ci[0] > lo_ci[1] + slack:
                    bad.append(
                        f"{curve}: AUC not monotone in A — "
                        f"A={a_hi} CI {hi_ci} above A={a_lo} CI {lo_ci}")
    # sampling: AUC non-decreasing in the participation prob. q
    # (amplification by subsampling — LESS participation must not leak
    # MORE), interval-compared; the q-amplified Thm 3.3 bound must be
    # strictly increasing in q by construction
    samp: dict = {}
    for key, ent in snap.items():
        if "/sampling/" not in key or "/q=" not in key:
            continue
        curve, _, qs = key.rpartition("/q=")
        samp.setdefault(curve, {})[float(qs)] = ent
    for curve, ents in samp.items():
        qs = sorted(ents)
        for i, q_lo in enumerate(qs):
            for q_hi in qs[i + 1:]:
                lo_ci, hi_ci = ents[q_lo]["auc_ci"], ents[q_hi]["auc_ci"]
                if lo_ci[0] > hi_ci[1] + slack:
                    bad.append(
                        f"{curve}: AUC not non-decreasing in q — "
                        f"q={q_lo} CI {lo_ci} above q={q_hi} CI {hi_ci}")
            if i and not (ents[qs[i - 1]]["mi_bound"]
                          < ents[qs[i]]["mi_bound"]):
                bad.append(f"{curve}: amplified bound not increasing in "
                           f"q at q={qs[i]}")
        # q = 1 is the synchronous engine: it must recover the base
        # A-curve entry (same spec, no arrival model)
        a_tag = curve.rpartition("/A=")[2]
        full = snap.get(f"mia/mlp/base/A={a_tag}")
        if full and 1.0 in ents:
            got, want = ents[1.0]["auc"], full["auc"]
            if abs(got - want) > 0.02:
                bad.append(f"{curve}: q=1 AUC {got:.3f} does not recover "
                           f"the synchronous A={a_tag} entry {want:.3f}")
    # collusion: AUC non-decreasing in a_c; a_c = A recovers A=1
    coll = {int(k.rpartition("=")[2]): v for k, v in snap.items()
            if "/collusion/" in k}
    if coll:
        acs = sorted(coll)
        for i, c_lo in enumerate(acs):
            for c_hi in acs[i + 1:]:
                if coll[c_hi]["auc_ci"][1] < coll[c_lo]["auc_ci"][0] - slack:
                    bad.append(
                        f"collusion: AUC not non-decreasing in a_c — "
                        f"ac={c_hi} below ac={c_lo}")
        full = snap.get("mia/mlp/base/A=1")
        if full and acs and acs[-1] == 8:
            got, want = coll[acs[-1]]["auc"], full["auc"]
            if abs(got - want) > 0.02:
                bad.append(
                    f"collusion: a_c=A AUC {got:.3f} does not recover the "
                    f"A=1 attack strength {want:.3f}")
    # DLG: reconstruction error monotone non-decreasing in A — ALL
    # ordered pairs, like the MIA gate, so a slow steady violation
    # cannot hide inside the per-step slack; the int8 payload never
    # reconstructs better than f32
    for curve, ents in _curves(snap, "dlg/").items():
        As = sorted(ents)
        for i, a_lo in enumerate(As):
            for a_hi in As[i + 1:]:
                lo, hi = ents[a_lo]["si_mse"], ents[a_hi]["si_mse"]
                if hi < lo * 0.9 - 0.02 - slack:
                    bad.append(f"{curve}: DLG MSE not monotone in A — "
                               f"A={a_hi} {hi:.3f} < A={a_lo} {lo:.3f}")
    for key, ent in snap.items():
        if key.startswith("dlg/") and "/int8/" in key:
            f32 = snap.get(key.replace("/int8/", "/f32/"))
            if f32 and ent["si_mse"] < f32["si_mse"] - 0.05 - slack:
                bad.append(f"{key}: int8 payload reconstructs BETTER than "
                           f"f32 ({ent['si_mse']:.3f} < "
                           f"{f32['si_mse']:.3f})")
    return bad


def check_drift(snap: dict, committed: dict) -> list[str]:
    """Regenerated-vs-committed comparison: MIA AUC must land inside the
    committed CI (widened a little for cross-version RNG drift); DLG MSE
    within a factor-2 band."""
    bad = []
    for key, ent in committed.items():
        got = snap.get(key)
        if got is None:
            bad.append(f"{key}: missing from regenerated snapshot")
            continue
        if "auc" in ent:
            lo, hi = ent["auc_ci"]
            if not (lo - 0.05 <= got["auc"] <= hi + 0.05):
                bad.append(f"{key}: regenerated AUC {got['auc']:.3f} "
                           f"outside committed CI [{lo:.3f}, {hi:.3f}]")
        elif "si_mse" in ent:
            want = ent["si_mse"]
            if not (0.5 * want - 0.1 <= got["si_mse"] <= 2 * want + 0.1):
                bad.append(f"{key}: regenerated DLG MSE "
                           f"{got['si_mse']:.3f} vs committed "
                           f"{want:.3f} (outside 2x band)")
    return bad


def run(quick: bool = True):
    """benchmarks/run.py protocol: report the committed snapshot's
    entries (regeneration is the nightly job's ``--regen``; quick mode
    never re-runs the multi-minute grid)."""
    rows = []
    if not SNAPSHOT.exists():
        return [{"name": "privacy_snapshot/EMPTY", "us_per_call": 0.0,
                 "derived": "no committed BENCH_privacy.json — run "
                            "benchmarks/privacy_snapshot.py --regen"}]
    snap = json.loads(SNAPSHOT.read_text())
    for key, ent in snap.items():
        if "auc" in ent:
            lo, hi = ent["auc_ci"]
            derived = (f"auc={ent['auc']:.3f} ci=[{lo:.3f},{hi:.3f}] "
                       f"bal_acc={ent['bal_acc']:.3f}")
        else:
            derived = f"si_mse={ent['si_mse']:.3f}"
        rows.append({"name": f"privacy_snapshot/{key}",
                     "us_per_call": 0.0, "derived": derived})
    bad = check_snapshot(snap)
    rows.append({"name": "privacy_snapshot/monotonicity",
                 "us_per_call": 0.0,
                 "derived": "OK" if not bad else "; ".join(bad)})
    return rows


if __name__ == "__main__":
    import argparse
    import sys
    ap = argparse.ArgumentParser()
    ap.add_argument("--regen", action="store_true",
                    help="re-run the audit grid (minutes on CPU)")
    ap.add_argument("--out", default=str(SNAPSHOT))
    ap.add_argument("--check", action="store_true",
                    help="fail (exit 1) on Thm 3.3 monotonicity "
                         "violations / drift from the committed snapshot")
    args = ap.parse_args()
    out_path = Path(args.out)
    # the committed baseline is read BEFORE any regeneration so the
    # drift gate still compares against it when --out is the committed
    # path itself (the docstring's --regen --check invocation)
    committed = (json.loads(SNAPSHOT.read_text()) if SNAPSHOT.exists()
                 else None)
    if args.regen:
        snap = generate()
        out_path.parent.mkdir(parents=True, exist_ok=True)
        out_path.write_text(json.dumps(snap, indent=1, sort_keys=True)
                            + "\n")
        print(f"wrote {len(snap)} entries to {out_path}")
    else:
        snap = json.loads(out_path.read_text())
    if args.check:
        bad = check_snapshot(snap)
        if args.regen and committed is not None:
            bad += check_drift(snap, committed)
        for b in bad:
            print("VIOLATION:", b)
        sys.exit(1 if bad else 0)
