"""Figure 4 / Appendix F.10 benchmark: Pareto analysis of utility vs
privacy under varying mechanism strengths.

For each method we sweep its privacy knob (epsilon for LDP-based methods,
prune rate for PriPrune, LDP-on-top for ERIS) and report (accuracy,
1 - MIA AUC) points; the derived field flags Pareto-optimal points."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import KEY, mlp_problem, run_method
from repro.core import baselines as bl
from repro.core import masks as masks_lib
from repro.core import privacy
from repro.core.compressors import RandP
from repro.core.fl import FLConfig


def _eval_point(cfg, M=8):
    data, init, loss_fn, acc_fn = mlp_problem(K=4, S=2 * M)
    x, y = data
    y_can = jax.random.randint(jax.random.fold_in(KEY, 3), y.shape, 0, 3)
    # utility
    run_u, _, _ = run_method(cfg, (x[:, :M], y[:, :M]), init, loss_fn)
    acc = acc_fn(run_u.params(), (x.reshape(-1, x.shape[-1]),
                                  y.reshape(-1)))
    # leakage
    run_c, xs, views = run_method(cfg, (x[:, :M], y_can[:, :M]), init,
                                  loss_fn, collect=True)
    A = cfg.A if cfg.method == "eris" else 1
    assign = masks_lib.make_assignment(run_c.n, A, "strided")
    obs = masks_lib.mask_for(assign, 0)
    grad_fn = jax.grad(lambda xf, c: loss_fn(
        run_c.unravel(xf), (c[:-1][None], c[-1][None].astype(jnp.int32))))
    members = jnp.concatenate([x[0, :M], y_can[0, :M, None]], 1)
    non = jnp.concatenate([x[0, M:], y_can[0, M:, None]], 1)
    auc = privacy.mia_audit(KEY, grad_fn, jnp.stack(xs),
                            jnp.stack(views) * obs, obs, members,
                            non)["auc"]
    # effective attack success: the adversary may flip the score sign
    # (PriPrune withholds exactly the high-signal coordinates, making
    # member correlation NEGATIVE -> auc near 0 is also full leakage)
    return acc, max(auc, 1.0 - auc)


def run(quick: bool = True):
    rounds = 40 if quick else 100
    points = {}
    for eps in (10.0, 1.0, 0.3):
        cfg = FLConfig(method="fedavg_ldp", K=4, rounds=rounds, lr=0.4,
                       ldp=bl.LDPConfig(eps=eps, clip=2.0))
        points[f"fedavg_ldp_eps={eps}"] = _eval_point(cfg)
    for p in (0.02, 0.1, 0.3):
        cfg = FLConfig(method="priprune", K=4, rounds=rounds, lr=0.4,
                       prune_rate=p)
        points[f"priprune_p={p}"] = _eval_point(cfg)
    points["eris_A8"] = _eval_point(
        FLConfig(method="eris", K=4, A=8, rounds=rounds, lr=0.4))
    points["eris_A8_dsc"] = _eval_point(
        FLConfig(method="eris", K=4, A=8, rounds=rounds, lr=0.4,
                 use_dsc=True, compressor=RandP(p=0.2)))
    # ERIS + LDP on top (the paper's Fig. 4 configuration)
    for eps in (10.0, 1.0):
        cfg = FLConfig(method="eris", K=4, A=8, rounds=rounds, lr=0.4)
        # emulate LDP-on-top by a noisier gradient estimator via ldp cfg
        cfg = FLConfig(method="fedavg_ldp", K=4, rounds=rounds, lr=0.4,
                       ldp=bl.LDPConfig(eps=eps, clip=2.0))
        acc, _ = _eval_point(cfg)
        # attacker still sees only 1/8 of coordinates under ERIS masks
        eris_cfg = FLConfig(method="eris", K=4, A=8, rounds=rounds, lr=0.4)
        _, auc = _eval_point(eris_cfg)
        points[f"eris_A8+ldp_eps={eps}"] = (acc, auc)

    # Pareto front: no other point has both higher acc and lower auc
    items = list(points.items())
    rows = []
    for name, (acc, auc) in items:
        dominated = any(a2 > acc + 1e-9 and u2 < auc - 1e-9
                        for n2, (a2, u2) in items if n2 != name)
        rows.append({"name": f"pareto/{name}",
                     "us_per_call": 0.0,
                     "derived": f"acc={acc:.3f} mia_auc={auc:.3f} "
                                f"pareto={'Y' if not dominated else 'n'}"})
    return rows
