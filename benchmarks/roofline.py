"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh)
from the dry-run artifacts.

  compute term    = HLO_FLOPs(per dev)            / peak_FLOP/s
  memory term     = HBM traffic proxy (per dev)   / HBM_bw
  collective term = weighted collective bytes     / link_bw

Collective weights (ring algorithms on a 1D slice of the mesh):
  all-gather / reduce-scatter: (n-1)/n x payload crosses each link
  all-reduce: 2x that;  collective-permute: 1x.
  all-to-all: (n-1)/n — each device keeps 1/n of its payload local and
  ships the rest (this is the scatter half of the FSA reduce-scatter when
  the payload is int8-quantized, so it must be weighted like one; on the
  MODEL axis it is the expert-parallel MoE token dispatch/combine).
These weights make the sequence-parallel conjugate pair (psum_scatter +
all_gather, (n-1)/n each) cost exactly one all-reduce (2(n-1)/n) on the
wire — the per-axis pricing below is what the seq-parallel 512-device
regression compares.
HLO FLOPs / bytes are trip-count-aware (repro.launch.hlo_analysis); the
payload bytes come from the HLO operand dtypes, so the int8 wire path is
accounted at its actual ~1.03 B/coord, not the ``grad_dtype`` width.

Overlap crediting: collective-permute traffic comes from the
double-buffered chunk rings (``models/layers.py::ring_all_reduce``) that
decompose each model-axis psum conjugate — the ppermute chunks are
issued back-to-back with the blockwise accumulation, so the scheduler
hides them under the layer's compute.  ``analyze_record`` therefore
moves ``min(cp_seconds, compute_seconds)`` out of the collective term
into ``terms_s['overlapped']``; only the un-hideable remainder stays on
the critical path.  Monolithic all-reduce / reduce-scatter / all-gather
payloads are synchronization barriers and are never credited.

Also reports MODEL_FLOPS = 6 * N_active * tokens and the usefulness ratio
MODEL_FLOPS / (devices * HLO_FLOPs) — catching remat/redundancy waste.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.launch.mesh import PEAK_FLOPS_BF16, HBM_BW, ICI_BW
from repro.launch.shapes import SHAPES

DRYRUN_DIR = Path("experiments/dryrun")


def _ring_weights(n: int) -> dict:
    n = max(n, 2)
    return {"all-gather": (n - 1) / n, "reduce-scatter": (n - 1) / n,
            "all-reduce": 2 * (n - 1) / n, "all-to-all": (n - 1) / n,
            "collective-permute": 1.0}


def collective_seconds(coll: dict, devices: int, model_size: int = 1,
                       pipe_size: int = 1) -> tuple[float, dict]:
    """Convert per-kind payload bytes into link-seconds.

    When the record carries the per-axis breakdown (``axes``), each
    axis's collectives are weighted with THAT axis's ring size — a
    model-axis psum circulates over ``model_size`` neighbors and a
    pipe-boundary ppermute over ``pipe_size`` stages, not the whole
    mesh — otherwise everything is priced at the full device count
    (the pre-TP behavior, an upper bound)."""
    axes = coll.get("axes")
    if axes and (model_size > 1 or pipe_size > 1):
        ring = {"model": model_size,
                "pipe": pipe_size,
                "client": max(devices // (model_size * pipe_size), 1),
                "all": devices}
        per_kind = {k: 0.0 for k in _ring_weights(devices)}
        for axis, by_kind in axes.items():
            w = _ring_weights(ring.get(axis, devices))
            for k in per_kind:
                per_kind[k] += by_kind.get(k, 0.0) * w[k] / ICI_BW
        return sum(per_kind.values()), per_kind
    w = _ring_weights(devices)
    per_kind = {k: coll.get(k, 0.0) * w[k] / ICI_BW for k in w}
    return sum(per_kind.values()), per_kind


def model_axis_seconds(rec: dict) -> float:
    """Ring-weighted link-seconds of the MODEL-axis collectives alone —
    the quantity a sequence-parallel plan must not increase (it trades
    each psum pair for the psum_scatter/all_gather conjugates at equal
    wire cost) and an expert-parallel plan spends on token all_to_all."""
    model_size = rec.get("tp", {}).get("size", 1)
    by_kind = rec["collective_bytes_per_device"].get("axes", {}).get(
        "model", {})
    w = _ring_weights(max(model_size, 2))
    return sum(v * w.get(k, 1.0) / ICI_BW for k, v in by_kind.items())


def model_flops(rec: dict) -> float:
    shape = SHAPES[rec["shape"]]
    if rec["kind"] == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * rec["active_params"] * tokens
    if rec["kind"] == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * rec["active_params"] * tokens
    # decode: one token per sequence
    return 2.0 * rec["active_params"] * shape.global_batch


def analyze_record(rec: dict) -> dict:
    n = rec["devices"]
    t_compute = rec["flops_per_device"] / PEAK_FLOPS_BF16
    t_memory = rec["bytes_accessed_per_device"] / HBM_BW
    t_coll, per_kind = collective_seconds(
        rec["collective_bytes_per_device"], n,
        model_size=rec.get("tp", {}).get("size", 1),
        pipe_size=rec.get("pp", {}).get("size", 1))
    # ppermute chunk rings run concurrently with the blockwise matmul
    # accumulation: up to one compute-term of cp time hides under compute
    t_overlap = min(per_kind.get("collective-permute", 0.0), t_compute)
    # 1F1B pipeline bubble: (p-1) of the (m+p-1) wavefront ticks per
    # stage run on padding, stretching the compute term by
    # bubble/(1-bubble) of itself (0 for non-pipelined records)
    bubble = rec.get("pp", {}).get("bubble_fraction", 0.0)
    t_bubble = t_compute * bubble / (1.0 - bubble) if bubble < 1.0 else 0.0
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll - t_overlap, "overlapped": t_overlap,
             "bubble": t_bubble}
    dominant = max(("compute", "memory", "collective", "bubble"),
                   key=lambda k: terms[k])
    mf = model_flops(rec)
    useful = mf / (n * rec["flops_per_device"]) if rec["flops_per_device"] \
        else float("nan")
    bound = max(terms["compute"] + terms["bubble"], terms["memory"],
                terms["collective"])
    mfu_upper = (mf / n / PEAK_FLOPS_BF16) / bound if bound else float("nan")
    return {**{k: rec[k] for k in ("arch", "shape", "mesh", "devices",
                                   "kind", "tag")},
            "terms_s": terms, "dominant": dominant,
            "collective_per_kind_s": per_kind,
            "model_flops": mf, "useful_ratio": useful,
            "mfu_upper_bound": mfu_upper}


def load_records(dryrun_dir=DRYRUN_DIR, tag=""):
    recs = []
    for f in sorted(Path(dryrun_dir).glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("tag", "") == tag:
            recs.append(r)
    return recs


def run(quick: bool = True):
    rows = []
    for rec in load_records():
        a = analyze_record(rec)
        rows.append({
            "name": f"roofline/{a['arch']}/{a['shape']}/{a['mesh']}",
            "us_per_call": a["terms_s"][a["dominant"]] * 1e6,
            "derived": (f"dom={a['dominant']} "
                        f"comp={a['terms_s']['compute']*1e3:.2f}ms "
                        f"mem={a['terms_s']['memory']*1e3:.2f}ms "
                        f"coll={a['terms_s']['collective']*1e3:.2f}ms "
                        f"ovl={a['terms_s']['overlapped']*1e3:.2f}ms "
                        f"bub={a['terms_s']['bubble']*1e3:.2f}ms "
                        f"useful={a['useful_ratio']:.2f} "
                        f"mfu_ub={a['mfu_upper_bound']:.3f}"),
        })
    return rows


def markdown_table(tag="") -> str:
    lines = ["| arch | shape | mesh | compute (ms) | memory (ms) | "
             "collective (ms) | overlapped (ms) | bubble (ms) | dominant "
             "| useful | MFU-UB |",
             "|---|---|---|---|---|---|---|---|---|---|---|"]
    for rec in load_records(tag=tag):
        a = analyze_record(rec)
        t = a["terms_s"]
        lines.append(
            f"| {a['arch']} | {a['shape']} | {a['mesh']} "
            f"| {t['compute']*1e3:.2f} | {t['memory']*1e3:.2f} "
            f"| {t['collective']*1e3:.2f} | {t['overlapped']*1e3:.2f} "
            f"| {t['bubble']*1e3:.2f} | **{a['dominant']}** "
            f"| {a['useful_ratio']:.2f} | {a['mfu_upper_bound']:.3f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
