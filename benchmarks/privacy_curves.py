"""Figure 2 + Figure 5 benchmark: MIA attack strength vs the number of
aggregators A (FSA), vs compression retention p (DSC), and vs the size of
a colluding coalition (Cor. D.2) — plus the matching MI bounds."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import KEY, mlp_problem, run_method
from repro.core import masks as masks_lib
from repro.core import privacy
from repro.core.compressors import RandP
from repro.core.fl import FLConfig


def _mia_once(A: int, rounds: int, seed: int, compressor=None,
              a_c: int = 1):
    M = 8
    data, init, loss_fn, _ = mlp_problem(jax.random.PRNGKey(seed),
                                         K=4, S=2 * M)
    x, y = data
    y_can = jax.random.randint(jax.random.fold_in(KEY, seed + 3),
                               y.shape, 0, 3)
    x_tr, y_tr = x[:, :M], y_can[:, :M]
    kw = dict(use_dsc=True, compressor=compressor) if compressor else {}
    cfg = FLConfig(method="eris", K=4, A=A, rounds=rounds, lr=0.4,
                   seed=seed, **kw)
    run_obj, xs, views = run_method(cfg, (x_tr, y_tr), init, loss_fn,
                                    collect=True)
    assign = masks_lib.make_assignment(run_obj.n, A, "strided")
    obs = sum(masks_lib.mask_for(assign, a) for a in range(a_c))
    grad_fn = jax.grad(lambda xf, c: loss_fn(
        run_obj.unravel(xf),
        (c[:-1][None], c[-1][None].astype(jnp.int32))))
    members = jnp.concatenate([x[0, :M], y_can[0, :M, None]], 1)
    non = jnp.concatenate([x[0, M:], y_can[0, M:, None]], 1)
    res = privacy.mia_audit(KEY, grad_fn, jnp.stack(xs),
                            jnp.stack(views) * obs, obs, members, non)
    return res["auc"]


def _mia_for(A: int, rounds: int, compressor=None, a_c: int = 1,
             n_seeds: int = 3):
    import numpy as np
    return float(np.mean([_mia_once(A, rounds, s, compressor, a_c)
                          for s in range(n_seeds)]))


def run(quick: bool = True):
    rounds = 30 if quick else 60
    rows = []
    n_model = 339   # params of the standard MLP problem
    # --- Fig. 2 left: vary A
    for A in (1, 2, 4, 8):
        auc = _mia_for(A, rounds)
        bound = privacy.mi_bound(n_model, rounds, 1.0, A)
        rows.append({"name": f"privacy/fig2_fsa/A={A}",
                     "us_per_call": 0.0,
                     "derived": f"mia_auc={auc:.3f} mi_bound={bound:.0f}"})
    # --- Fig. 2 right: fix A, vary DSC retention p
    for p in (1.0, 0.5, 0.2):
        comp = None if p == 1.0 else RandP(p=p)
        auc = _mia_for(4, rounds, compressor=comp)
        bound = privacy.mi_bound(n_model, rounds, p, 4)
        rows.append({"name": f"privacy/fig2_dsc/p={p}",
                     "us_per_call": 0.0,
                     "derived": f"mia_auc={auc:.3f} mi_bound={bound:.0f}"})
    # --- Fig. 5: colluding aggregators (A=8 fixed)
    for a_c in (1, 2, 4, 8):
        auc = _mia_for(8, rounds, a_c=a_c)
        bound = privacy.mi_bound(n_model, rounds, 1.0, 8, a_c=a_c)
        rows.append({"name": f"privacy/fig5_collusion/Ac={a_c}",
                     "us_per_call": 0.0,
                     "derived": f"mia_auc={auc:.3f} mi_bound={bound:.0f}"})
    return rows
