"""Theorem 3.2 benchmark: convergence of ERIS(+DSC) vs FedAvg vs
SoteriaFL-style compression on the standard MLP problem (the loss-curve
evidence behind Table 1's 'FedAvg-level utility') + the scan-compiled
multi-round driver vs the per-round Python loop."""
from __future__ import annotations

import time

import jax

from benchmarks.common import mlp_problem, run_method, time_call, KEY
from repro.core.compressors import QSGD, RandP
from repro.core.fl import FLConfig, FLRun


def run(quick: bool = True):
    rounds = 120 if quick else 400
    data, init, loss_fn, acc_fn = mlp_problem()
    full = (data[0].reshape(-1, data[0].shape[-1]), data[1].reshape(-1))
    cases = {
        "fedavg": FLConfig(method="fedavg", K=6, rounds=rounds, lr=0.3),
        "eris_A8": FLConfig(method="eris", K=6, A=8, rounds=rounds, lr=0.3),
        "eris_dsc_p0.2": FLConfig(method="eris", K=6, A=8, rounds=rounds,
                                  lr=0.3, use_dsc=True,
                                  compressor=RandP(p=0.2)),
        "eris_dsc_qsgd4": FLConfig(method="eris", K=6, A=8, rounds=rounds,
                                   lr=0.3, use_dsc=True,
                                   compressor=QSGD(s=4)),
        "soteriafl_p0.2": FLConfig(method="soteriafl", K=6, rounds=rounds,
                                   lr=0.3, compressor=RandP(p=0.2)),
        "shatter": FLConfig(method="shatter", K=6, rounds=rounds, lr=0.3,
                            shatter_chunks=8, shatter_r=3),
        "secure_agg": FLConfig(method="secure_agg", K=6, rounds=rounds,
                               lr=0.3),
        "eris_fedadam": FLConfig(method="eris", K=6, A=8, rounds=rounds,
                                 lr=0.05, server_opt="fedadam"),
        "eris_ef_topk": FLConfig(method="eris", K=6, A=8, rounds=rounds,
                                 lr=0.3, use_ef=True,
                                 compressor=__import__(
                                     "repro.core.compressors",
                                     fromlist=["TopK"]).TopK(k=16)),
        "eris_partial_50pct": FLConfig(method="eris", K=6, A=8,
                                       rounds=rounds, lr=0.3,
                                       participation=0.5),
    }
    rows = []
    for name, cfg in cases.items():
        run_obj, _, _ = run_method(cfg, data, init, loss_fn)
        loss = float(loss_fn(run_obj.params(), full))
        acc = acc_fn(run_obj.params(), full)
        t_round = time_call(lambda: run_obj.step(data) or 0)
        rows.append({"name": f"convergence/{name}",
                     "us_per_call": t_round,
                     "derived": f"final_loss={loss:.4f} acc={acc:.3f} "
                                f"rounds={rounds}"})
    rows.append(_scan_vs_loop(data, init, loss_fn, rounds))
    return rows


def _scan_vs_loop(data, init, loss_fn, rounds: int) -> dict:
    """Multi-round driver comparison: T jitted per-round dispatches vs the
    ONE scan-compiled T-round XLA program (same trajectory, see
    tests/test_pipeline.py::test_scan_driver_matches_step_driver)."""
    import jax.numpy as jnp

    cfg = FLConfig(method="eris", K=6, A=8, rounds=rounds, lr=0.3,
                   use_dsc=True, compressor=RandP(p=0.2))
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (rounds, *x.shape)), data)
    run = FLRun(cfg, init(KEY), loss_fn)
    state0, key0 = run.state, run.key

    def loop_once():
        run.state, run.key = state0, key0
        for _ in range(rounds):
            run.step(data)
        return run.x

    def scan_once():
        run.state, run.key = state0, key0
        run.run_scanned(stacked)
        return run.x

    jax.block_until_ready(loop_once())          # warm the per-round jit
    t0 = time.perf_counter()
    jax.block_until_ready(loop_once())
    t_loop = (time.perf_counter() - t0) * 1e6
    jax.block_until_ready(scan_once())          # warm the scan compile
    t0 = time.perf_counter()
    jax.block_until_ready(scan_once())
    t_scan = (time.perf_counter() - t0) * 1e6
    return {"name": "convergence/scan_vs_loop",
            "us_per_call": t_scan,
            "derived": f"loop_us={t_loop:.0f} scan_us={t_scan:.0f} "
                       f"speedup={t_loop / max(t_scan, 1e-9):.2f}x "
                       f"rounds={rounds}"}
