"""Appendix F.5 benchmark (Figs. 10/11): accuracy under aggregator
dropout and client-aggregator link failures."""
from __future__ import annotations

from benchmarks.common import mlp_problem, run_method
from repro.core.fl import FLConfig


def run(quick: bool = True):
    # FIXED round budget (the paper's setting): failures slow convergence,
    # so accuracy under the cap degrades only at extreme failure rates
    rounds = 25 if quick else 60
    data, init, loss_fn, acc_fn = mlp_problem(K=6, S=16, alpha=0.5)
    full = (data[0].reshape(-1, data[0].shape[-1]), data[1].reshape(-1))
    rows = []
    for drop in (0.0, 0.3, 0.5, 0.7, 0.9):
        cfg = FLConfig(method="eris", K=6, A=8, rounds=rounds, lr=0.2,
                       agg_dropout=drop, seed=2)
        run_obj, _, _ = run_method(cfg, data, init, loss_fn)
        rows.append({"name": f"robustness/agg_dropout={drop}",
                     "us_per_call": 0.0,
                     "derived": f"acc={acc_fn(run_obj.params(), full):.3f} "
                                f"loss={loss_fn(run_obj.params(), full):.3f}"})
    for lf in (0.0, 0.25, 0.5, 0.75):
        cfg = FLConfig(method="eris", K=6, A=8, rounds=rounds, lr=0.2,
                       link_failure=lf, seed=2)
        run_obj, _, _ = run_method(cfg, data, init, loss_fn)
        rows.append({"name": f"robustness/link_failure={lf}",
                     "us_per_call": 0.0,
                     "derived": f"acc={acc_fn(run_obj.params(), full):.3f} "
                                f"loss={loss_fn(run_obj.params(), full):.3f}"})
    return rows
