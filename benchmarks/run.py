"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--full`` runs the slower
settings; default is the quick profile.
"""
from __future__ import annotations

import argparse
import sys
import time


MODULES = [
    ("equivalence", "Thm B.1: FSA == FedAvg + aggregation cost"),
    ("convergence", "Thm 3.2 / Table 1: loss & accuracy per method"),
    ("utility_privacy", "Table 1: accuracy vs MIA leakage per method"),
    ("privacy_curves", "Fig. 2 + Fig. 5: leakage vs A, p, collusion"),
    ("reconstruction", "Fig. 12 / Table 7: DLG inversion vs exposure"),
    ("scalability", "Table 2 / F.2: upload + distribution time model"),
    ("robustness", "F.5: aggregator dropout + link failures"),
    ("pareto", "Fig. 4 / F.10: utility-privacy Pareto analysis"),
    ("kernels_bench", "kernel reference timings + TPU expectations"),
    ("roofline", "dry-run roofline terms per (arch x shape x mesh)"),
    ("tp_snapshot", "committed BENCH_tp.json: compile time + per-axis "
                    "collective bytes + roofline across PRs"),
    ("privacy_snapshot", "committed BENCH_privacy.json: MIA AUC (CIs) + "
                         "DLG MSE vs A / wire / collusion, Thm 3.3 gate"),
    ("serve_snapshot", "committed BENCH_serve.json: ServeEngine tokens/s "
                       "+ p50/p99 latency vs concurrency, batching-"
                       "invariance + block-budget gates"),
    ("scenario_snapshot", "committed BENCH_pareto.json: utility / MIA AUC "
                          "/ cumulative (eps,delta) / wire bytes per "
                          "defense x failure cell, Pareto + drift gates"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    quick = not args.full
    only = {m for m in args.only.split(",") if m}
    print("name,us_per_call,derived")
    failures = []
    for mod_name, desc in MODULES:
        if only and mod_name not in only:
            continue
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            rows = mod.run(quick=quick)
            for r in rows:
                print(f"{r['name']},{r['us_per_call']:.1f},"
                      f"\"{r['derived']}\"", flush=True)
        except Exception as e:  # keep the suite running
            failures.append((mod_name, repr(e)))
            print(f"{mod_name}/ERROR,0,\"{e!r}\"", flush=True)
        print(f"# {mod_name} ({desc}) took {time.time()-t0:.1f}s",
              file=sys.stderr, flush=True)
    if failures:
        print(f"# FAILURES: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
