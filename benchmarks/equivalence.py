"""Theorem B.1 benchmark: FSA == FedAvg bit-exactness over many rounds +
per-round cost of the sharded vs centralized aggregation (App. B)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import time_call
from repro.core import baselines, fsa, masks


def run(quick: bool = True):
    rows = []
    n, K, T = (4096, 16, 50) if quick else (65536, 64, 200)
    key = jax.random.PRNGKey(0)
    for A in (2, 8, 32):
        assign = masks.make_assignment(n, A, "strided")
        x_f = x_c = jax.random.normal(key, (n,))
        max_dev = 0.0
        for t in range(T):
            g = jax.random.normal(jax.random.fold_in(key, t), (K, n))
            x_f = fsa.fsa_round_sharded(x_f, g, assign, A, 0.05,
                                        keep_views=False).x_new
            x_c = baselines.fedavg_round(x_c, g, 0.05)
            max_dev = max(max_dev, float(jnp.abs(x_f - x_c).max()))
        g = jax.random.normal(key, (K, n))
        t_sharded = time_call(jax.jit(
            lambda x, g: fsa.fsa_round_sharded(x, g, assign, A, 0.05,
                                               keep_views=False).x_new),
            x_f, g)
        t_central = time_call(jax.jit(
            lambda x, g: baselines.fedavg_round(x, g, 0.05)), x_c, g)
        rows.append({
            "name": f"equivalence/thmB1/A={A}",
            "us_per_call": t_sharded,
            "derived": (f"max_dev_over_{T}_rounds={max_dev:.2e} "
                        f"central_us={t_central:.0f} n={n} K={K}"),
        })
    return rows
