"""Table 1 benchmark: utility (accuracy) + MIA leakage per method on the
standard problem in the paper's low-data regime, including the idealized
Min.Leakage bound."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import KEY, mlp_problem, run_method
from repro.core import baselines as bl
from repro.core import masks as masks_lib
from repro.core import privacy
from repro.core.compressors import RandP
from repro.core.fl import FLConfig


def run(quick: bool = True):
    rounds = 40 if quick else 100
    M = 8
    data, init, loss_fn, acc_fn = mlp_problem(K=4, S=2 * M)
    x, y = data
    y_can = jax.random.randint(jax.random.fold_in(KEY, 3), y.shape, 0, 3)
    train = (x[:, :M], y[:, :M])
    full = (x.reshape(-1, x.shape[-1]), y.reshape(-1))
    # canary run shares the training data but mislabels the member canaries
    canary_train = (x[:, :M], y_can[:, :M])

    cases = {
        "fedavg": FLConfig(method="fedavg", K=4, rounds=rounds, lr=0.4),
        "fedavg_ldp_e10": FLConfig(method="fedavg_ldp", K=4, rounds=rounds,
                                   lr=0.4, ldp=bl.LDPConfig(eps=10, clip=2)),
        "soteriafl": FLConfig(method="soteriafl", K=4, rounds=rounds,
                              lr=0.4, compressor=RandP(p=0.2)),
        "priprune_p0.05": FLConfig(method="priprune", K=4, rounds=rounds,
                                   lr=0.4, prune_rate=0.05),
        "shatter": FLConfig(method="shatter", K=4, rounds=rounds, lr=0.4,
                            shatter_chunks=4, shatter_r=2),
        "eris_A8": FLConfig(method="eris", K=4, A=8, rounds=rounds, lr=0.4),
        "eris_dsc": FLConfig(method="eris", K=4, A=8, rounds=rounds, lr=0.4,
                             use_dsc=True, compressor=RandP(p=0.2)),
        "secure_agg": FLConfig(method="secure_agg", K=4, rounds=rounds,
                               lr=0.4),
        "min_leakage": FLConfig(method="min_leakage", K=4, rounds=rounds,
                                lr=0.4),
    }
    rows = []
    for name, cfg in cases.items():
        # utility on true labels
        run_u, _, _ = run_method(cfg, train, init, loss_fn)
        acc = acc_fn(run_u.params(), full)
        # leakage with canaries
        run_c, xs, views = run_method(cfg, canary_train, init, loss_fn,
                                      collect=True)
        if name == "min_leakage":
            # adversary sees only the final model -> use last-round view=0;
            # report the loss-gap attack on the final model instead
            p_final = run_c.params()
            li = jnp.array([loss_fn(p_final, (x[0, i:i + 1],
                                              y_can[0, i:i + 1]))
                            for i in range(M)])
            lo = jnp.array([loss_fn(p_final, (x[0, M + i:M + i + 1],
                                              y_can[0, M + i:M + i + 1]))
                            for i in range(M)])
            auc = float((li[:, None] < lo[None, :]).mean())
        else:
            A = cfg.A if cfg.method == "eris" else 1
            assign = masks_lib.make_assignment(run_c.n, A, "strided")
            obs = masks_lib.mask_for(assign, 0)
            grad_fn = jax.grad(lambda xf, c: loss_fn(
                run_c.unravel(xf),
                (c[:-1][None], c[-1][None].astype(jnp.int32))))
            members = jnp.concatenate([x[0, :M], y_can[0, :M, None]], 1)
            non = jnp.concatenate([x[0, M:], y_can[0, M:, None]], 1)
            auc = privacy.mia_audit(KEY, grad_fn, jnp.stack(xs),
                                    jnp.stack(views) * obs, obs,
                                    members, non)["auc"]
        rows.append({"name": f"utility_privacy/{name}",
                     "us_per_call": 0.0,
                     "derived": f"acc={acc:.3f} mia_auc={auc:.3f}"})
    return rows
