"""TP perf snapshot: commit the tensor-parallel trajectory to the repo.

Distills the dry-run artifacts (``experiments/dryrun/*.json``) into one
committed ``BENCH_tp.json`` at the repo root so the perf trajectory —
compile time, the per-mesh-axis collective payload split, and the
roofline estimate — is recorded ACROSS PRs instead of living only in CI
artifact retention.  The nightly job regenerates the dry-run records and
rewrites the snapshot; a PR that changes the lowering shows up as a
diff on BENCH_tp.json.

Each entry keys ``{arch}/{shape}/{mesh}[/{tag}]`` and carries:

* ``lower_s`` / ``compile_s`` — XLA cost of the (lower, compile) pair
* ``tp``      — the shard plan the lowering engaged (size + region flags)
* ``pp``      — the pipeline plan (stage count, microbatches, bubble
  fraction) and ``param_bytes_per_device`` — resident parameter bytes at
  the pipe x TP-local compute layout (the ≥26B acceptance bound)
* ``wire_dtype`` — the FSA exchange's on-mesh dtype
* ``axis_bytes`` / ``axis_counts`` — per-axis {kind: payload bytes /
  trip-weighted op count} from the HLO replica groups (model vs client)
* ``roofline`` — the roofline terms (s, incl. the overlapped-collective
  credit) + dominant + MFU bound

Run as a script with ``--check`` (the nightly job does) to regenerate
AND gate: any entry whose ``roofline.mfu_upper_bound`` falls more than
``MFU_REGRESSION_THRESHOLD`` below the committed snapshot fails the run.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.roofline import DRYRUN_DIR, analyze_record

SNAPSHOT = Path(__file__).resolve().parent.parent / "BENCH_tp.json"

# nightly gate: a lowering change may not cost more than this fraction of
# any entry's roofline MFU upper bound (deterministic — derived from HLO
# byte/FLOP counts, not wall-clock, so it is safe to gate on in CI)
MFU_REGRESSION_THRESHOLD = 0.10


def check_mfu_regression(committed: dict, fresh: dict,
                         threshold: float = MFU_REGRESSION_THRESHOLD):
    """Entries whose regenerated ``roofline.mfu_upper_bound`` fell more
    than ``threshold`` below the committed snapshot's value.  Only keys
    present on both sides are compared (new entries have no baseline;
    stale committed entries have no fresh record)."""
    failures = []
    for key in sorted(set(committed) & set(fresh)):
        old = committed[key].get("roofline", {}).get("mfu_upper_bound")
        new = fresh[key].get("roofline", {}).get("mfu_upper_bound")
        if not old or not new:
            continue
        if new < old * (1.0 - threshold):
            failures.append(
                f"{key}: mfu_upper_bound {old:.5f} -> {new:.5f} "
                f"({(new / old - 1.0) * 100:+.1f}%, gate -{threshold:.0%})")
    return failures


def snapshot_from_records(records: list[dict]) -> dict:
    out = {}
    for rec in sorted(records, key=lambda r: (r["arch"], r["shape"],
                                              r["mesh"], r.get("tag", ""))):
        key = f"{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        if rec.get("tag"):
            key += f"/{rec['tag']}"
        coll = rec.get("collective_bytes_per_device", {})
        roof = analyze_record(rec)
        out[key] = {
            "devices": rec["devices"],
            "lower_s": rec.get("lower_s"),
            "compile_s": rec.get("compile_s"),
            "tp": rec.get("tp", {}),
            "pp": rec.get("pp", {"size": 1}),
            "param_bytes_per_device": rec.get("param_bytes_per_device"),
            "wire_dtype": rec.get("wire_dtype", ""),
            "axis_bytes": {ax: {k: round(v) for k, v in kinds.items()}
                           for ax, kinds in coll.get("axes", {}).items()},
            "axis_counts": coll.get("axis_counts", {}),
            "roofline": {
                "terms_s": roof["terms_s"],
                "dominant": roof["dominant"],
                "mfu_upper_bound": roof["mfu_upper_bound"],
            },
        }
    return out


def write_snapshot(dryrun_dir=None, path: Path = SNAPSHOT) -> dict:
    """Refresh BENCH_tp.json from every dry-run record (all tags).

    MERGES into the existing snapshot: only the entries the available
    records cover are rewritten, so a partial dry-run directory (one
    leftover arch, a single fresh run) updates its own entries without
    clobbering the rest of the committed trajectory."""
    d = Path(dryrun_dir) if dryrun_dir else DRYRUN_DIR
    records = [json.loads(f.read_text()) for f in sorted(d.glob("*.json"))]
    snap = snapshot_from_records(records)
    if path.exists():
        snap = {**json.loads(path.read_text()), **snap}
    if snap:
        path.write_text(json.dumps(snap, indent=1, sort_keys=True) + "\n")
    return snap


def run(quick: bool = True):
    """benchmarks/run.py protocol: refresh the committed snapshot from
    the available dry-run records and report each entry as a row."""
    snap = write_snapshot()
    rows = []
    for key, ent in snap.items():
        model_ab = ent["axis_bytes"].get("model", {})
        rows.append({
            "name": f"tp_snapshot/{key}",
            "us_per_call": (ent.get("compile_s") or 0.0) * 1e6,
            "derived": (f"tp={ent['tp'].get('size', 1)} "
                        f"wire={ent['wire_dtype'] or 'n/a'} "
                        f"model_bytes={sum(model_ab.values()):.2e} "
                        f"dom={ent['roofline']['dominant']} "
                        f"mfu_ub={ent['roofline']['mfu_upper_bound']:.3f}"),
        })
    if not rows:
        rows.append({"name": "tp_snapshot/EMPTY", "us_per_call": 0.0,
                     "derived": "no dryrun records under "
                                "experiments/dryrun — run "
                                "repro.launch.dryrun first"})
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default=None)
    ap.add_argument("--out", default=str(SNAPSHOT))
    ap.add_argument("--check", action="store_true",
                    help="fail (exit 1) when any regenerated entry's "
                         "roofline.mfu_upper_bound regresses more than "
                         f"{MFU_REGRESSION_THRESHOLD:.0%} below the "
                         "committed snapshot")
    args = ap.parse_args()
    d = Path(args.dryrun_dir) if args.dryrun_dir else DRYRUN_DIR
    records = [json.loads(f.read_text()) for f in sorted(d.glob("*.json"))]
    fresh = snapshot_from_records(records)
    path = Path(args.out)
    committed = json.loads(path.read_text()) if path.exists() else {}
    snap = {**committed, **fresh}
    if snap:
        path.write_text(json.dumps(snap, indent=1, sort_keys=True) + "\n")
    print(f"wrote {len(snap)} entries to {args.out} "
          f"({len(fresh)} regenerated)")
    if args.check:
        fails = check_mfu_regression(committed, fresh)
        for msg in fails:
            print(f"MFU REGRESSION: {msg}")
        if fails:
            raise SystemExit(1)
        print(f"mfu gate OK: {len(set(committed) & set(fresh))} entries "
              f"within {MFU_REGRESSION_THRESHOLD:.0%} of committed")
