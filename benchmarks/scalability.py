"""Table 2 / Appendix F.2 benchmark: per-client upload and minimum
distribution time per round — the paper's analytic model (Eqs. 52-55)
instantiated for our architectures, plus measured compressed payloads.

Payload sizes come from the ACTUAL wire representation each method
transmits (bf16 for the default reduce-scatter path, int8 blocks + f32
scales for the int8 wire, value+index pairs for sparse DSC) — not from an
assumed fp32 ``grad_dtype`` convention.

Rates: homogeneous 20 MB/s up/down (Table 2's setting)."""
from __future__ import annotations

import numpy as np

from repro.core.compressors import RandP
from repro.configs import get_config
from repro.kernels.quantize import wire_payload_bytes
from repro.models.transformer import param_count

RATE = 20e6                      # bytes/s


def payload_bytes(n: int, wire: str) -> float:
    """Bytes one client transmits for an n-coordinate update, by wire
    format (the distributed runtime's actual payload dtypes)."""
    if wire == "int8":
        return float(wire_payload_bytes(n))
    return float(n) * np.dtype(wire).itemsize


def d_fedavg(K: int, b: float) -> float:
    """Eq. 52 with homogeneous rates."""
    return max(K * b / RATE, b / RATE) + max(K * b / RATE, b / RATE)


def d_eris(K: int, A: int, b_up: float, b_down: float) -> float:
    """Eq. 53 with homogeneous rates."""
    up = max((K - 1) * b_up / (A * RATE), b_up / RATE)
    down = max((K - 1) * b_down / (A * RATE), b_down / RATE)
    return up + down


def d_ako(b: float) -> float:
    return max(b / RATE, b / RATE)                      # Eq. 54


def d_shatter(K: int, b: float, r: int = 4) -> float:   # Eq. 55
    return max(b / RATE, r * b / RATE, r * b / (K * RATE))


def run(quick: bool = True):
    rows = []
    K = 50
    for arch in ("eris-gptneo-1.3b", "qwen2-0.5b", "xlstm-350m"):
        cfg = get_config(arch)
        n = param_count(cfg)
        b = payload_bytes(n, "bfloat16")  # runtime's default wire dtype
        b_int8 = payload_bytes(n, "int8")  # int8 blocks + f32 scales
        # measured DSC payload (rand-p wire format, p=0.05)
        comp = RandP(p=0.05)
        b_dsc = float(comp.wire_bits(n)) / 8.0
        cases = {
            "fedavg": (b, "bf16", d_fedavg(K, b)),
            "shatter": (b, "bf16", d_shatter(K, b)),
            "ako": (b, "bf16", d_ako(b)),
            "priprune_p0.1": (0.9 * b, "bf16", d_fedavg(K, 0.9 * b) * 0.95),
            "soteriafl_5pct": (0.05 * b, "bf16",
                               max(K * 0.05 * b / RATE, 0.05 * b / RATE)
                               + max(K * b / RATE, b / RATE)),
            "eris_A2": (b, "bf16", d_eris(K, 2, b, b)),
            "eris_A50": (b, "bf16", d_eris(K, 50, b, b)),
            "eris_int8_A50": (b_int8, "s8", d_eris(K, 50, b_int8, b)),
            "eris_dsc_A50": (b_dsc, "sparse", d_eris(K, 50, b_dsc, b)),
        }
        base = cases["fedavg"][2]
        for name, (upload, wire, dist) in cases.items():
            rows.append({
                "name": f"scalability/{arch}/{name}",
                "us_per_call": dist * 1e6,
                "derived": (f"upload_MB={upload/1e6:.2f} wire={wire} "
                            f"dist_s={dist:.2f} "
                            f"speedup_vs_fedavg={base/dist:.1f}x"),
            })
    return rows
