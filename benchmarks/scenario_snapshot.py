"""Cross-silo scenario snapshot: the utility-privacy-bytes Pareto surface.

Sweeps the ``repro.core.rounds.scenarios`` matrix — FSA composed with
{int8 wire, DSC+int8, LDP noise, LDP+int8, secure-agg pairwise masking}
x {healthy, aggregator dropout + link failure, client dropout} — into
one committed ``BENCH_pareto.json`` at the repo root, next to
``BENCH_tp.json``/``BENCH_privacy.json``.  Every feasible cell runs

* the **simulator** (``FLRun.step``) and **scan** (``run_scanned``)
  engines on the MLP canary problem: final utility (mean client loss),
  engine parity, captured adversary views -> the gradient-alignment MIA
  audit (AUC + bootstrap CI) against a single curious aggregator, and
  the cumulative RDP (eps, delta) from ``core.accountant`` for LDP
  cells (subsampling-amplified by the client-dropout rate);
* the **distributed** shard_map engine (subprocess, 8 host devices) on
  the config-zoo tiny transformer via the ``TrainSettings`` twin of the
  same composition, with per-round wire bytes from
  ``dist.sharding.mesh_wire_bytes`` — the same accounting the HLO
  traffic tests pin to the compiled collectives.

Infeasible cells are committed as ``refused/<name>`` with the protocol
reason — the matrix stays total, refusals stay loud.  The transformer-
scale MIA audits ride along as ``audit/lm/A=<A>`` at the
sharded-attack-compute scale (128-256 canaries over an ``attack`` device
mesh): with the old 6-canary audit the AUC estimator had 36 orderings
and memorizing runs pinned it at exactly 1.0; at this scale every
committed entry resolves strictly below 1.0 with an informative CI.

The nightly CI job regenerates the snapshot into its run artifacts and
FAILS on gate violations (:func:`check_snapshot`) or drift outside the
committed CI bands (:func:`check_drift`):

    PYTHONPATH=src:. python benchmarks/scenario_snapshot.py --regen --check
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np

SNAPSHOT = Path(__file__).resolve().parent.parent / "BENCH_pareto.json"

# sim/scan leg: the MLP canary problem at the scenario-standard shape
K, A, ROUNDS, LR, SEED = 6, 4, 20, 0.3, 0
N_CANARIES = 12
MLP_DIM = 16
AUDIT_SALT = 0x5CE0

# transformer-scale audits (sharded attack compute; see module docstring)
LM_AUDITS = {
    4: dict(K=16, rounds=1, n_canaries=256, lr=0.02),
    8: dict(K=8, rounds=1, n_canaries=256, lr=0.02),
    16: dict(K=8, rounds=1, n_canaries=128, lr=0.02),
}

# distributed leg: 8 host devices, tiny config-zoo transformer
DIST_DEVICES = 8
DIST_ROUNDS = 4
DIST_LR = 0.1


def _dist_settings_kw(cell) -> dict:
    """The ``TrainSettings`` twin of a scenario cell's stage composition
    (grad_dtype pinned to f32 so utility is comparable across cells and
    the pairwise masks stay exactly cancelling)."""
    k = cell.knobs
    kw: dict = {"grad_dtype": "float32"}
    if k.get("int8_wire"):
        kw["int8_wire"] = True
    if k.get("use_dsc"):
        kw.update(use_dsc=True, dsc_p=0.5)
    if "ldp" in k:
        ldp = k["ldp"]
        kw.update(ldp_eps=ldp.eps, ldp_delta=ldp.delta, ldp_clip=ldp.clip)
    if k.get("secure_mask"):
        kw["secure_mask"] = True
    if "agg_dropout" in k:
        kw.update(agg_dropout=k["agg_dropout"],
                  link_failure=k["link_failure"])
    if "client_dropout" in k:
        kw.update(async_buffer=True, client_dropout=k["client_dropout"])
    return kw


_DIST_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
import json, sys
import jax, jax.numpy as jnp
from repro.data import lm_token_batches
from repro.dist.sharding import mesh_wire_bytes
from repro.launch.mesh import make_host_mesh
from repro.launch.train import TrainSettings, make_train_step, init_dsc_state
from repro.models import transformer as tr
from repro.optim import sgd
from repro.privacy.harness import tiny_lm_config

cells = json.loads(sys.stdin.read())
cfg = tiny_lm_config()
toks = lm_token_batches(jax.random.PRNGKey(0), 1, 8, 32, cfg.vocab)[0]
batch = {"tokens": toks}
opt = sgd(%f)
mesh = make_host_mesh(data=%d)
out = {}
for name, kw in cells.items():
    settings = TrainSettings(**kw)
    step, shardings = make_train_step(cfg, mesh, opt, settings)
    with mesh:
        params = jax.device_put(tr.init_params(jax.random.PRNGKey(0), cfg),
                                shardings["store"])
        opt_state = opt.init(params)
        st = init_dsc_state(cfg, mesh, settings)
        jstep = jax.jit(step)
        losses = []
        for i in range(%d):
            params, opt_state, st, m = jstep(
                params, opt_state, st, batch, jax.random.PRNGKey(i))
            losses.append(float(m["loss"]))
    out[name] = {
        "loss0": losses[0], "loss": losses[-1],
        "wire_bytes": int(mesh_wire_bytes(
            cfg, mesh, int8=settings.int8_wire, grad_bytes=4))}
print(json.dumps(out))
""" % (DIST_DEVICES, DIST_LR, DIST_DEVICES, DIST_ROUNDS)


def _dist_leg(cells) -> dict:
    """All feasible cells through the distributed engine in ONE
    subprocess (the host-device-count flag must be set before jax
    imports, so the sweep cannot run in-process)."""
    payload = json.dumps({c.name: _dist_settings_kw(c) for c in cells})
    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")
    root = Path(__file__).resolve().parent.parent
    env["PYTHONPATH"] = os.pathsep.join(
        [str(root / "src")] + [p for p in (env.get("PYTHONPATH"),) if p])
    r = subprocess.run([sys.executable, "-c", _DIST_SCRIPT],
                       input=payload, capture_output=True, text=True,
                       timeout=3600, env=env)
    if r.returncode != 0:
        raise RuntimeError(f"distributed leg failed:\n{r.stderr[-4000:]}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def _sim_scan_leg(cell) -> dict:
    """One cell through the simulator AND scan engines: utility parity,
    captured views -> MIA audit, accountant state, wire bytes."""
    import jax
    import jax.numpy as jnp
    from repro.core.fl import FLRun
    from repro.privacy import harness

    spec = harness.AuditSpec(
        A=A, rounds=ROUNDS, K=K, n_canaries=N_CANARIES, lr=LR, seed=SEED,
        use_dsc=bool(cell.knobs.get("use_dsc")),
        p=0.5 if cell.knobs.get("use_dsc") else 1.0,
        int8_wire=cell.int8, q=cell.q, n_bootstrap=200)
    params0, loss_fn, batches, members, non = harness.mlp_canary_problem(
        spec, dim=MLP_DIM)
    cfg = cell.fl_config(K=K, A=A, rounds=ROUNDS, lr=LR, seed=SEED,
                         keep_views=True)

    # scan engine (captures the adversary views in the same program)
    run = FLRun(cfg, params0, loss_fn)
    x0 = run.x
    stacked = jax.tree.map(lambda b: jnp.stack([b] * ROUNDS), batches)
    xs, views = run.run_scanned(stacked, collect_views=True)
    x_traj = jnp.concatenate([x0[None], xs[:-1]], axis=0)

    # simulator engine (step loop), same composition + keys
    run_s = FLRun(cfg, params0, loss_fn)
    for _ in range(ROUNDS):
        run_s.step(batches)

    def mean_loss(xf):
        p = run.unravel(xf)
        per = [loss_fn(p, jax.tree.map(lambda b: b[k], batches))
               for k in range(K)]
        return float(np.mean([float(v) for v in per]))

    grad_fn = jax.grad(lambda xf, c: loss_fn(
        run.unravel(xf), (c[:-1][None], c[-1][None].astype(jnp.int32))))
    audit = harness._audit_captured(spec, run, x_traj, views, grad_fn,
                                    members, non, AUDIT_SALT)
    acc = cell.accountant(ROUNDS)
    ent = {
        "scan_loss": mean_loss(xs[-1]),
        "sim_loss": mean_loss(run_s.x),
        "auc": float(audit["auc"]),
        "auc_ci": [float(v) for v in audit["auc_ci"]],
        "bal_acc": float(audit["balanced_accuracy"]),
        "mi_bound": float(audit["mi_bound"]),
        "wire_bytes_per_client": cell.wire_bytes_per_client(run.n),
        "eps": None if acc is None else float(acc["eps"]),
        "delta": None if acc is None else float(acc["delta"]),
    }
    return ent


def generate() -> dict:
    """Run the full scenario sweep (a few minutes on CPU)."""
    from repro.core.rounds import scenario_matrix
    from repro.privacy import harness

    snap: dict = {}
    cells = scenario_matrix(feasible_only=False)
    feasible = [c for c in cells if c.feasible]
    for cell in cells:
        if not cell.feasible:
            snap[f"refused/{cell.name}"] = {"reason": cell.refusal}
    dist = _dist_leg(feasible)
    for cell in feasible:
        ent = _sim_scan_leg(cell)
        ent["dist"] = dist[cell.name]
        snap[f"scenario/{cell.name}"] = ent
    # transformer-scale audits, sharded attack compute (PR 5 caveat)
    cfg = harness.tiny_lm_config()
    for A_lm, kw in LM_AUDITS.items():
        r = harness.mia_lm(cfg, harness.AuditSpec(
            A=A_lm, seed=SEED, n_bootstrap=200, shard_attack=True, **kw))
        snap[f"audit/lm/A={A_lm}"] = {
            "auc": float(r["auc"]),
            "auc_ci": [float(v) for v in r["auc_ci"]],
            "bal_acc": float(r["balanced_accuracy"]),
            "mi_bound": float(r["mi_bound"]),
            "spec": dict(kw),
        }
    return snap


# ------------------------------------------------------------ the gate
def check_snapshot(snap: dict) -> list[str]:
    """Structural + Pareto gates on a snapshot (committed or fresh).
    Returns human-readable violation strings (empty = pass)."""
    from repro.core.rounds import scenario_matrix

    bad = []
    cells = {c.name: c for c in scenario_matrix(feasible_only=False)}
    for name, cell in cells.items():
        key = (f"scenario/{name}" if cell.feasible else f"refused/{name}")
        if key not in snap:
            bad.append(f"{key}: missing from snapshot")
    scen = {k.split("/", 1)[1]: v for k, v in snap.items()
            if k.startswith("scenario/")}
    base = scen.get("none+none")
    for name, ent in scen.items():
        # engine parity: the scan engine IS the simulator, fused
        if abs(ent["sim_loss"] - ent["scan_loss"]) > 1e-3:
            bad.append(f"{name}: sim/scan utility diverged "
                       f"({ent['sim_loss']:.4f} vs {ent['scan_loss']:.4f})")
        # wire accounting: int8 cells must ship < half the f32 bytes,
        # format-preserving defenses must not change the payload size
        f32_name = name.replace("dsc_int8", "none").replace(
            "ldp_int8", "ldp").replace("int8", "none")
        f32 = scen.get(f32_name)
        if "int8" in name and f32 is not None:
            if not (ent["wire_bytes_per_client"]
                    < 0.5 * f32["wire_bytes_per_client"]):
                bad.append(f"{name}: int8 wire bytes "
                           f"{ent['wire_bytes_per_client']} not < half of "
                           f"{f32_name}'s {f32['wire_bytes_per_client']}")
            if not (ent["dist"]["wire_bytes"]
                    < 0.5 * f32["dist"]["wire_bytes"]):
                bad.append(f"{name}: distributed int8 wire bytes not < "
                           f"half of the f32 cell's")
        # accountant: LDP cells carry finite cumulative eps; others none
        if cells[name].ldp is not None:
            if not (ent["eps"] is not None and np.isfinite(ent["eps"])
                    and ent["eps"] > 0):
                bad.append(f"{name}: LDP cell without a finite eps")
        elif ent["eps"] is not None:
            bad.append(f"{name}: eps reported without an LDP stage")
        # the distributed twin must actually run (and train, unless the
        # cell is noise-dominated by design)
        if not np.isfinite(ent["dist"]["loss"]):
            bad.append(f"{name}: distributed loss not finite")
        if cells[name].ldp is None and not (ent["dist"]["loss"]
                                            < ent["dist"]["loss0"]):
            bad.append(f"{name}: distributed engine did not train "
                       f"({ent['dist']['loss0']:.3f} -> "
                       f"{ent['dist']['loss']:.3f})")
    # subsampling amplification: client dropout must shrink the
    # cumulative eps at the same defense
    for name, ent in scen.items():
        if name.endswith("+client_drop") and ent["eps"] is not None:
            full = scen.get(name.replace("+client_drop", "+none"))
            if full and not ent["eps"] < full["eps"]:
                bad.append(f"{name}: subsampled eps {ent['eps']:.2f} not "
                           f"below the full-participation "
                           f"{full['eps']:.2f}")
    # privacy ordering: the defended wires must not leak MORE than the
    # undefended one (interval-compared), and the masked wire must sit
    # near chance — every received row is masked
    if base is not None:
        for dname in ("ldp", "secure_agg"):
            ent = scen.get(f"{dname}+none")
            if ent and ent["auc_ci"][0] > base["auc_ci"][1]:
                bad.append(f"{dname}+none: defended AUC CI {ent['auc_ci']} "
                           f"entirely above undefended {base['auc_ci']}")
        sa = scen.get("secure_agg+none")
        if sa and sa["auc"] > 0.75:
            bad.append(f"secure_agg+none: masked-wire AUC {sa['auc']:.3f} "
                       f"far from chance (masks not hiding the payload?)")
    # transformer-scale audits: present, and NOT pinned at AUC 1.0
    for key, ent in snap.items():
        if not key.startswith("audit/lm/"):
            continue
        lo, hi = ent["auc_ci"]
        if ent["auc"] >= 0.9995 or hi >= 0.9995:
            bad.append(f"{key}: AUC {ent['auc']:.4f} CI [{lo:.3f},{hi:.3f}] "
                       f"pinned at 1.0 (saturated audit)")
        if not hi > lo:
            bad.append(f"{key}: degenerate AUC CI [{lo}, {hi}]")
    if not any(k.startswith("audit/lm/") for k in snap):
        bad.append("audit/lm: transformer-scale audit entries missing")
    return bad


def check_drift(snap: dict, committed: dict) -> list[str]:
    """Regenerated-vs-committed: AUCs inside the committed CI (widened
    for cross-version RNG drift), byte counts exact, eps near-exact,
    losses within a 15% band."""
    bad = []
    for key, ent in committed.items():
        got = snap.get(key)
        if got is None:
            bad.append(f"{key}: missing from regenerated snapshot")
            continue
        if "auc" in ent:
            lo, hi = ent["auc_ci"]
            if not (lo - 0.05 <= got["auc"] <= hi + 0.05):
                bad.append(f"{key}: regenerated AUC {got['auc']:.3f} "
                           f"outside committed CI [{lo:.3f}, {hi:.3f}]")
        if "wire_bytes_per_client" in ent:
            if got["wire_bytes_per_client"] != ent["wire_bytes_per_client"]:
                bad.append(f"{key}: wire bytes changed "
                           f"{ent['wire_bytes_per_client']} -> "
                           f"{got['wire_bytes_per_client']}")
        if ent.get("eps") is not None:
            if abs(got["eps"] - ent["eps"]) > 1e-6 * max(1.0, ent["eps"]):
                bad.append(f"{key}: accountant eps drifted "
                           f"{ent['eps']:.6f} -> {got['eps']:.6f}")
        for lk in ("sim_loss", "scan_loss"):
            if lk in ent and abs(got[lk] - ent[lk]) > 0.15 * abs(ent[lk]):
                bad.append(f"{key}: {lk} drifted {ent[lk]:.4f} -> "
                           f"{got[lk]:.4f}")
    return bad


def run(quick: bool = True):
    """benchmarks/run.py protocol: report the committed snapshot's
    entries (regeneration is the nightly job's ``--regen``; quick mode
    never re-runs the multi-minute sweep)."""
    rows = []
    if not SNAPSHOT.exists():
        return [{"name": "scenario_snapshot/EMPTY", "us_per_call": 0.0,
                 "derived": "no committed BENCH_pareto.json — run "
                            "benchmarks/scenario_snapshot.py --regen"}]
    snap = json.loads(SNAPSHOT.read_text())
    for key, ent in snap.items():
        if key.startswith("refused/"):
            derived = "refused"
        elif key.startswith("audit/"):
            lo, hi = ent["auc_ci"]
            derived = f"auc={ent['auc']:.3f} ci=[{lo:.3f},{hi:.3f}]"
        else:
            eps = "-" if ent["eps"] is None else f"{ent['eps']:.1f}"
            derived = (f"loss={ent['scan_loss']:.3f} auc={ent['auc']:.3f} "
                       f"eps={eps} B={ent['wire_bytes_per_client']}")
        rows.append({"name": f"scenario_snapshot/{key}",
                     "us_per_call": 0.0, "derived": derived})
    bad = check_snapshot(snap)
    rows.append({"name": "scenario_snapshot/gates", "us_per_call": 0.0,
                 "derived": "OK" if not bad else "; ".join(bad)})
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--regen", action="store_true",
                    help="re-run the scenario sweep (minutes on CPU)")
    ap.add_argument("--out", default=str(SNAPSHOT))
    ap.add_argument("--check", action="store_true",
                    help="fail (exit 1) on gate violations / drift from "
                         "the committed snapshot")
    args = ap.parse_args()
    out_path = Path(args.out)
    # the committed baseline is read BEFORE any regeneration so the
    # drift gate still compares against it when --out is the committed
    # path itself (the docstring's --regen --check invocation)
    committed = (json.loads(SNAPSHOT.read_text()) if SNAPSHOT.exists()
                 else None)
    if args.regen:
        # the transformer-scale audits shard their attack compute over
        # the host devices — expose them before the first jax import
        os.environ.setdefault(
            "XLA_FLAGS",
            f"--xla_force_host_platform_device_count={DIST_DEVICES}")
        snap = generate()
        out_path.parent.mkdir(parents=True, exist_ok=True)
        out_path.write_text(json.dumps(snap, indent=1, sort_keys=True)
                            + "\n")
        print(f"wrote {len(snap)} entries to {out_path}")
    else:
        snap = json.loads(out_path.read_text())
    if args.check:
        bad = check_snapshot(snap)
        if args.regen and committed is not None:
            bad += check_drift(snap, committed)
        for b in bad:
            print("VIOLATION:", b)
        sys.exit(1 if bad else 0)
