"""Serving perf snapshot: commit the continuous-batching trajectory.

Runs the real :class:`repro.serve.ServeEngine` (paged KV cache + Pallas
decode attention, CPU interpret mode) over a deterministic request set
at a sweep of concurrency levels and distills the result into a
committed ``BENCH_serve.json`` at the repo root — tokens/s and p50/p99
request latency vs concurrency — so the serving trajectory is recorded
ACROSS PRs instead of living only in CI artifact retention.

Gates (``--check``, the nightly job):

* HARD — decode output at every concurrency is token-identical to the
  concurrency-1 run (the engine's batching-invariance contract);
* HARD — ``peak_blocks`` never exceeds the block budget;
* HARD — the fresh entries carry the committed schema and the committed
  ``token_checksum`` (a lowering/numerics change that moves greedy
  decode shows up as a checksum drift — regen + commit when expected);
* INFORMATIONAL — throughput/latency numbers (wall-clock varies per
  machine; they are recorded, uploaded, and eyeballed, never gated).

``--hist PATH`` additionally writes the per-request latency histogram
(one row per concurrency) for the nightly artifact upload.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import time
from pathlib import Path

SNAPSHOT = Path(__file__).resolve().parent.parent / "BENCH_serve.json"

CONCURRENCIES = (1, 4, 8, 16)
N_REQUESTS = 16
MAX_NEW_TOKENS = 12


def _problem():
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.models import transformer as tr

    cfg = dataclasses.replace(get_config("qwen2-0.5b").smoke(),
                              n_layers=2, dtype="float32")
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab,
                            size=int(rng.integers(4, 12))).tolist()
               for _ in range(N_REQUESTS)]
    return cfg, params, prompts


def _settings(concurrency: int):
    from repro.serve import ServeSettings
    return ServeSettings(max_concurrency=concurrency, block_size=8,
                         num_blocks=96, max_model_len=64,
                         prefill_bucket=16, max_new_tokens=MAX_NEW_TOKENS,
                         cache_dtype="float32")


def _checksum(token_lists) -> str:
    blob = json.dumps(token_lists, separators=(",", ":")).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def collect(concurrencies=CONCURRENCIES) -> tuple[dict, dict]:
    """Run the sweep.  Returns (snapshot entries, latency histograms)."""
    from repro.serve import ServeEngine

    cfg, params, prompts = _problem()
    entries, hists, reference = {}, {}, None
    for c in concurrencies:
        eng = ServeEngine(cfg, params, _settings(c))
        t0 = time.perf_counter()
        outs = eng.run(prompts)
        wall = time.perf_counter() - t0
        tokens = [o.tokens for o in outs]
        if reference is None:
            reference = tokens
        elif tokens != reference:
            bad = [i for i, (a, b) in enumerate(zip(tokens, reference))
                   if a != b]
            raise AssertionError(
                f"concurrency={c} diverged from the concurrency-1 decode "
                f"on request(s) {bad} — batching invariance broken")
        st = eng.stats()
        if st["peak_blocks"] > st["block_capacity"]:
            raise AssertionError(
                f"concurrency={c}: peak_blocks {st['peak_blocks']} "
                f"exceeds budget {st['block_capacity']}")
        lat = sorted(o.latency_s for o in outs)
        n = len(lat)
        entries[f"qwen2-smoke/c{c}"] = {
            "concurrency": c,
            "n_requests": n,
            "new_tokens": sum(len(t) for t in tokens),
            "decode_steps": st["steps"],
            "peak_blocks": st["peak_blocks"],
            "block_capacity": st["block_capacity"],
            "preemptions": sum(o.preemptions for o in outs),
            "tokens_per_s": round(sum(len(t) for t in tokens) / wall, 2),
            "p50_ms": round(lat[n // 2] * 1e3, 2),
            "p99_ms": round(lat[min(n - 1, (99 * n) // 100)] * 1e3, 2),
            "token_checksum": _checksum(tokens),
        }
        hists[str(c)] = {"latency_s": [round(x, 4) for x in lat],
                         "ttft_s": [round(o.ttft_s, 4) for o in outs]}
    return entries, hists


def check_drift(committed: dict, fresh: dict) -> list[str]:
    """Schema + checksum gate against the committed snapshot (throughput
    fields are informational and never compared)."""
    fails = []
    missing = set(committed) - set(fresh)
    if missing:
        fails.append(f"committed entries not regenerated: {sorted(missing)}")
    for key in sorted(set(committed) & set(fresh)):
        old, new = committed[key], fresh[key]
        if set(old) != set(new):
            fails.append(f"{key}: schema drift "
                         f"{sorted(set(old) ^ set(new))}")
            continue
        if old["token_checksum"] != new["token_checksum"]:
            fails.append(f"{key}: token_checksum "
                         f"{old['token_checksum']} -> "
                         f"{new['token_checksum']} — greedy decode moved; "
                         f"regen + commit BENCH_serve.json if intended")
    return fails


def run(quick: bool = True):
    """benchmarks/run.py protocol: one row per concurrency level."""
    entries, _ = collect(CONCURRENCIES[:2] if quick else CONCURRENCIES)
    return [{
        "name": f"serve_snapshot/{key}",
        "us_per_call": 1e6 / max(ent["tokens_per_s"], 1e-9),
        "derived": (f"c={ent['concurrency']} tok/s={ent['tokens_per_s']} "
                    f"p50={ent['p50_ms']}ms p99={ent['p99_ms']}ms "
                    f"blocks={ent['peak_blocks']}/{ent['block_capacity']}"),
    } for key, ent in entries.items()]


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=str(SNAPSHOT))
    ap.add_argument("--regen", action="store_true",
                    help="rewrite the snapshot from a fresh sweep")
    ap.add_argument("--check", action="store_true",
                    help="fail (exit 1) on batching-invariance / block "
                         "budget violations or schema/checksum drift vs "
                         "the committed snapshot")
    ap.add_argument("--hist", default=None, metavar="PATH",
                    help="write per-request latency histograms (JSON)")
    args = ap.parse_args()
    path = Path(args.out)
    committed = json.loads(path.read_text()) if path.exists() else {}
    fresh, hists = collect()
    if args.hist:
        Path(args.hist).parent.mkdir(parents=True, exist_ok=True)
        Path(args.hist).write_text(json.dumps(hists, indent=1) + "\n")
        print(f"wrote latency histograms to {args.hist}")
    if args.regen or not committed:
        path.write_text(json.dumps(fresh, indent=1, sort_keys=True) + "\n")
        print(f"wrote {len(fresh)} entries to {path}")
    if args.check:
        fails = check_drift(committed, fresh) if committed else []
        for msg in fails:
            print(f"SERVE DRIFT: {msg}")
        if fails:
            raise SystemExit(1)
        print(f"serve gate OK: {len(fresh)} entries, batching-invariant, "
              f"blocks within budget"
              + (f", {len(set(committed) & set(fresh))} checksums match"
                 if committed else " (no committed baseline)"))
