"""End-to-end driver: federated training of a transformer LM with ERIS.

The full paper pipeline on a real model: K clients hold disjoint token
streams; every round each client computes an update on its own data, DSC
shift-compresses it, FSA shards it across A aggregators; the reassembled
model is identical to centralized FedAvg.  Runs a reduced-family config
(selectable with --arch) on CPU, a few hundred rounds, with checkpointing
and perplexity eval.

    PYTHONPATH=src python examples/fl_train_lm.py --arch qwen2-0.5b \
        --rounds 200 [--dsc] [--A 8]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save
from repro.configs import get_config
from repro.core.compressors import RandP
from repro.core.fl import FLConfig, FLRun
from repro.data import lm_token_batches
from repro.models import transformer as tr

KEY = jax.random.PRNGKey(0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--K", type=int, default=4)
    ap.add_argument("--A", type=int, default=8)
    ap.add_argument("--dsc", action="store_true")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--ckpt", default="/tmp/eris_lm.msgpack")
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()      # reduced same-family variant
    params0 = tr.init_params(KEY, cfg)
    n_params = sum(int(jnp.size(p)) for p in jax.tree.leaves(params0))
    print(f"arch={cfg.name} family={cfg.family} params={n_params/1e6:.2f}M "
          f"K={args.K} A={args.A} dsc={args.dsc}")

    # disjoint client token streams
    toks = lm_token_batches(jax.random.fold_in(KEY, 1), args.K, args.batch,
                            args.seq, cfg.vocab)          # (K, B, S)
    eval_toks = lm_token_batches(jax.random.fold_in(KEY, 2), 1, 8,
                                 args.seq, cfg.vocab)[0]

    def loss_fn(params, batch):
        return tr.loss_fn(params, cfg, {"tokens": batch})

    fl_cfg = FLConfig(method="eris", K=args.K, A=args.A,
                      rounds=args.rounds, lr=args.lr,
                      use_dsc=args.dsc,
                      compressor=RandP(p=0.25) if args.dsc else
                      RandP(p=1.0))
    run = FLRun(fl_cfg, params0, loss_fn)
    t0 = time.time()
    for t in range(args.rounds):
        run.step(toks)
        if t % 20 == 0 or t == args.rounds - 1:
            ppl = float(jnp.exp(loss_fn(run.params(), eval_toks)))
            print(f"round {t:4d}  eval_ppl={ppl:9.2f}  "
                  f"({time.time()-t0:.0f}s)", flush=True)
    save(args.ckpt, run.params())
    print(f"saved checkpoint to {args.ckpt}")
    ppl0 = float(jnp.exp(loss_fn(params0, eval_toks)))
    ppl1 = float(jnp.exp(loss_fn(run.params(), eval_toks)))
    print(f"perplexity: init={ppl0:.1f} -> final={ppl1:.1f} "
          f"(vocab={cfg.vocab}, structured-token task)")


if __name__ == "__main__":
    main()
