"""Privacy demo: the empirical Thm 3.3 story in one script — MIA audit
(with bootstrap CIs) and DLG inversion against the captured adversary
views at different aggregator counts, wire formats (f32 vs the int8
round trip, DSC shifted compression) and colluding-coalition sizes.

    PYTHONPATH=src python examples/privacy_attack.py
"""
from repro.privacy import harness


def main():
    # ------------- membership inference vs A (Fig. 2 left) --------------
    kw = dict(rounds=40, lr=0.5, n_canaries=24, n_bootstrap=128)
    print("== MIA vs number of aggregators A (scan-compiled capture) ==")
    for A in (1, 2, 4, 8):
        res = harness.mia_mlp(harness.AuditSpec(A=A, seed=0, **kw), dim=16)
        lo, hi = res["auc_ci"]
        print(f"  A={A}: AUC={res['auc']:.3f} [{lo:.3f}, {hi:.3f}]   "
              f"MI bound ∝ {res['mi_bound']:.0f} nats")

    print("\n== ... with the REAL wire (DSC p=1 + int8 round trip) ==")
    for A in (1, 8):
        res = harness.mia_mlp(harness.AuditSpec(
            A=A, seed=0, use_dsc=True, int8_wire=True, **kw), dim=16)
        lo, hi = res["auc_ci"]
        print(f"  A={A}: AUC={res['auc']:.3f} [{lo:.3f}, {hi:.3f}]")

    # ------------------- collusion curve (Fig. 5) -----------------------
    print("\n== Colluding aggregators at A=8 (Cor. D.2, one vmapped "
          "sweep) ==")
    sweep = harness.mia_mlp_collusion_sweep(
        harness.AuditSpec(A=8, seed=0, **kw), dim=16)
    for i, a_c in enumerate(sweep["a_c"]):
        lo, hi = sweep["auc_ci"][i]
        print(f"  a_c={int(a_c)}: AUC={float(sweep['auc'][i]):.3f} "
              f"[{lo:.3f}, {hi:.3f}]")

    # ------------------- gradient inversion (Fig. 12) -------------------
    print("\n== DLG reconstruction vs A (lower MSE = better attack) ==")
    for wire in ("f32", "int8"):
        out = harness.dlg_mlp([1, 4, 16], wire=wire, steps=300)
        row = "  ".join(f"A={A}: {mse:.3f}" for A, mse in out.items())
        print(f"  {wire:>4} wire:  {row}")

    # ------------- transformer-family (config zoo) attacks --------------
    print("\n== Transformer (config-zoo tiny member): embedding DLG ==")
    cfg = harness.tiny_lm_config()
    out = harness.dlg_lm(cfg, [1, 4, 16], wire="f32", steps=150)
    for A, mse in out.items():
        print(f"  A={A}: observed={1/A:.1%}, embedding SI-MSE={mse:.3f}")


if __name__ == "__main__":
    main()
