"""Privacy demo: run the MIA audit and DLG inversion against ERIS at
different aggregator counts — the Fig. 2 / Fig. 12 story in one script.

    PYTHONPATH=src python examples/privacy_attack.py
"""
import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.core import masks as masks_lib
from repro.core import privacy
from repro.core.fl import FLConfig, FLRun
from repro.data import federated_classification

KEY = jax.random.PRNGKey(0)


def main():
    # ---------------- membership inference (Fig. 2 left) ----------------
    M, K, dim, classes = 8, 4, 8, 3
    x, y = federated_classification(KEY, K, 2 * M, dim=dim,
                                    n_classes=classes)
    y_can = jax.random.randint(jax.random.fold_in(KEY, 3), y.shape, 0, 3)

    def init(key):
        k1, k2 = jax.random.split(key)
        return {"w": 0.3 * jax.random.normal(k1, (dim, classes)),
                "b": jnp.zeros(classes)}

    def loss_fn(p, batch):
        xx, yy = batch
        logp = jax.nn.log_softmax(xx @ p["w"] + p["b"])
        return -jnp.take_along_axis(logp, yy[:, None], 1).mean()

    print("== Membership inference vs number of aggregators A ==")
    for A in (1, 2, 4, 8):
        cfg = FLConfig(method="eris", K=K, A=A, rounds=40, lr=0.4, seed=1)
        run = FLRun(cfg, init(KEY), loss_fn)
        xs, views = [], []
        for _ in range(cfg.rounds):
            xs.append(run.x)
            views.append(run.step((x[:, :M], y_can[:, :M]),
                                  collect_views=True)[0])
        assign = masks_lib.make_assignment(run.n, A, "strided")
        obs = masks_lib.mask_for(assign, 0)
        grad_fn = jax.grad(lambda xf, c: loss_fn(
            run.unravel(xf), (c[:-1][None], c[-1][None].astype(jnp.int32))))
        members = jnp.concatenate([x[0, :M], y_can[0, :M, None]], 1)
        non = jnp.concatenate([x[0, M:], y_can[0, M:, None]], 1)
        res = privacy.mia_audit(KEY, grad_fn, jnp.stack(xs),
                                jnp.stack(views) * obs, obs, members, non)
        bound = privacy.mi_bound(run.n, cfg.rounds, 1.0, A)
        print(f"  A={A}: attack AUC={res['auc']:.3f}   "
              f"MI bound ∝ {bound:.0f} nats")

    # ------------------- gradient inversion (Fig. 12) -------------------
    print("\n== DLG reconstruction vs A (lower MSE = better attack) ==")
    dim = 64
    k1, k2, k3 = jax.random.split(KEY, 3)
    p0 = {"w1": 0.4 * jax.random.normal(k1, (dim, 4)), "b1": jnp.zeros(4),
          "w2": 0.4 * jax.random.normal(k2, (4, 4)), "b2": jnp.zeros(4)}
    x_flat, unravel = ravel_pytree(p0)

    def loss_single(xf, inp, label):
        p = unravel(xf)
        h = jnp.tanh(inp @ p["w1"] + p["b1"])
        return -jax.nn.log_softmax(h @ p["w2"] + p["b2"])[label]

    grad_fn = jax.grad(loss_single)
    target = jax.random.normal(k3, (dim,))
    g_true = grad_fn(x_flat, target, jnp.int32(2))
    for A in (1, 4, 16):
        assign = masks_lib.make_assignment(x_flat.shape[0], A, "strided")
        obs = masks_lib.mask_for(assign, 0)
        out = privacy.dlg_attack(jax.random.fold_in(KEY, 7), grad_fn,
                                 x_flat, g_true * obs, obs, (dim,),
                                 jnp.int32(2), steps=300, lr=0.05)
        mse = privacy.reconstruction_mse(out["reconstruction"], target)
        print(f"  A={A}: observed={1/A:.1%} of gradient, "
              f"reconstruction MSE={mse:.3f}")


if __name__ == "__main__":
    main()
