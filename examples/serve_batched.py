"""Continuous-batching serving with ``ServeEngine``: requests of mixed
length share a paged KV cache and a single fixed-shape decode jit —
admitted as slots free up, evicted the step they finish.

Runs a reduced-family model on CPU, serves a batch of prompts (greedy
plus a couple of sampled requests), and verifies the engine's batched
output is token-identical to serving one request at a time.

    PYTHONPATH=src python examples/serve_batched.py --arch qwen2-0.5b \
        [--requests 8] [--gen 16] [--concurrency 4]

To serve a ``launch/train.py --save`` artifact instead of fresh params:

    PYTHONPATH=src python -m repro.launch.train --smoke --save ckpt/
    PYTHONPATH=src python examples/serve_batched.py --ckpt ckpt/
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer as tr
from repro.serve import SamplingParams, ServeEngine, ServeSettings

KEY = jax.random.PRNGKey(0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--concurrency", type=int, default=4)
    ap.add_argument("--ckpt", default=None,
                    help="serve a launch/train.py --save artifact")
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    settings = ServeSettings(max_concurrency=args.concurrency,
                             block_size=16, num_blocks=128,
                             max_model_len=64 + args.gen,
                             max_new_tokens=args.gen,
                             cache_dtype="float32")
    if args.ckpt:
        engine = ServeEngine.from_checkpoint(args.ckpt, cfg, settings)
    else:
        engine = ServeEngine(cfg, tr.init_params(KEY, cfg), settings)
    print(f"arch={cfg.name} family={cfg.family} "
          f"requests={args.requests} concurrency={args.concurrency}")

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab,
                              size=int(rng.integers(4, 24))).tolist()
        samp = (SamplingParams() if i % 3 else
                SamplingParams(temperature=0.8, top_k=20, top_p=0.95))
        engine.submit(prompt, sampling=samp, seed=i)

    outs = []
    while engine.waiting or engine._active():
        outs.extend(engine.step())
    outs.sort(key=lambda o: o.rid)
    st = engine.stats()
    print(f"decode: {st['steps']} engine steps, {st['tokens_out']} tokens "
          f"({st['tokens_per_s']:.1f} tok/s), peak blocks "
          f"{st['peak_blocks']}/{st['block_capacity']}")

    # ---- batching invariance: each request alone gives the same stream
    import dataclasses
    solo_settings = dataclasses.replace(settings, max_concurrency=1)
    agree = 0
    for o in outs:
        solo = (ServeEngine.from_checkpoint(args.ckpt, cfg, solo_settings)
                if args.ckpt else
                ServeEngine(engine.cfg, engine.params, solo_settings))
        samp = (SamplingParams() if o.rid % 3 else
                SamplingParams(temperature=0.8, top_k=20, top_p=0.95))
        solo.submit(o.prompt, sampling=samp, seed=o.rid)
        agree += solo.run()[0].tokens == o.tokens
    print(f"batched vs solo token identity: {agree}/{len(outs)}")
    for o in outs[:2]:
        print(f"  request {o.rid}: prompt={o.prompt[:8]}... "
              f"-> generated={o.tokens[:10]}... "
              f"({o.finish_reason}, ttft {o.ttft_s*1e3:.0f}ms)")


if __name__ == "__main__":
    main()
