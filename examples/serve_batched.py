"""Batched serving: prefill a batch of requests, then decode tokens
autoregressively — the serve_step path the decode dry-run shapes lower.

Runs a reduced-family model on CPU with greedy sampling and verifies the
decoded continuation matches teacher-forced forward logits.

    PYTHONPATH=src python examples/serve_batched.py --arch qwen2-0.5b \
        [--batch 4] [--prompt-len 16] [--gen 24]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import lm_token_batches
from repro.models import transformer as tr

KEY = jax.random.PRNGKey(0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    params = tr.init_params(KEY, cfg)
    prompts = lm_token_batches(jax.random.fold_in(KEY, 1), 1, args.batch,
                               args.prompt_len, cfg.vocab)[0]
    max_len = args.prompt_len + args.gen
    print(f"arch={cfg.name} family={cfg.family} batch={args.batch} "
          f"prompt={args.prompt_len} gen={args.gen}")

    # ---- prefill: full forward in 'prefill' mode builds the caches ----
    t0 = time.time()
    logits, caches, _ = tr.forward(params, cfg, prompts, mode="prefill",
                                   remat=False)
    # resize kv caches to max_len (recurrent states are fixed-size)
    if "kv" in (caches or {}):
        pad = max_len - args.prompt_len
        caches["kv"] = {k: jnp.pad(v, ((0, 0), (0, 0), (0, pad),
                                       (0, 0), (0, 0)))
                        for k, v in caches["kv"].items()}
    next_tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    print(f"prefill: {time.time()-t0:.2f}s")

    # ---- decode loop: one serve_step per generated token ----
    step = jax.jit(lambda c, t, p: tr.decode_step(params, cfg, c, t, p))
    out_tokens = [next_tok]
    t0 = time.time()
    cache = caches
    for i in range(args.gen - 1):
        pos = jnp.int32(args.prompt_len + i)
        logits, cache = step(cache, out_tokens[-1], pos)
        out_tokens.append(jnp.argmax(logits[:, -1:], -1).astype(jnp.int32))
    gen = jnp.concatenate(out_tokens, axis=1)
    dt = time.time() - t0
    print(f"decode: {args.gen-1} steps in {dt:.2f}s "
          f"({(args.gen-1)*args.batch/dt:.1f} tok/s batched)")

    # ---- consistency: teacher-forced forward must agree (greedy path) ----
    full_seq = jnp.concatenate([prompts, gen], axis=1)
    full_logits, _, _ = tr.forward(params, cfg, full_seq)
    tf_next = jnp.argmax(full_logits[:, args.prompt_len - 1:-1], -1)
    agree = float((tf_next == gen).mean())
    print(f"greedy decode vs teacher-forced agreement: {agree:.1%}")
    for b in range(min(2, args.batch)):
        print(f"  request {b}: prompt={list(map(int, prompts[b][:8]))}... "
              f"-> generated={list(map(int, gen[b][:10]))}...")


if __name__ == "__main__":
    main()
