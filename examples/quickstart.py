"""Quickstart: ERIS (FSA) vs FedAvg on a small federated problem.

Shows the paper's headline property: the sharded protocol is bit-identical
to centralized FedAvg (Theorem B.1) while no aggregator ever observes a
full client update.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core.compressors import RandP
from repro.core.fl import FLConfig, run_fl
from repro.data import federated_classification

KEY = jax.random.PRNGKey(0)
DIM, CLASSES, K, S = 8, 3, 6, 32


def init_mlp(key):
    k1, k2 = jax.random.split(key)
    return {"w1": 0.3 * jax.random.normal(k1, (DIM, 16)),
            "b1": jnp.zeros(16),
            "w2": 0.3 * jax.random.normal(k2, (16, CLASSES)),
            "b2": jnp.zeros(CLASSES)}


def loss_fn(p, batch):
    x, y = batch
    h = jnp.tanh(x @ p["w1"] + p["b1"])
    logp = jax.nn.log_softmax(h @ p["w2"] + p["b2"])
    return -jnp.take_along_axis(logp, y[:, None], 1).mean()


def accuracy(p, batch):
    x, y = batch
    h = jnp.tanh(x @ p["w1"] + p["b1"])
    return float((jnp.argmax(h @ p["w2"] + p["b2"], -1) == y).mean())


def main():
    x, y = federated_classification(KEY, K, S, dim=DIM, n_classes=CLASSES)
    full = (x.reshape(-1, DIM), y.reshape(-1))
    batches = lambda t, k: (x, y)
    results = {}
    for name, cfg in {
        "fedavg": FLConfig(method="fedavg", K=K, rounds=100, lr=0.3),
        "eris A=8": FLConfig(method="eris", K=K, A=8, rounds=100, lr=0.3),
        "eris A=8 +DSC(p=0.2)": FLConfig(
            method="eris", K=K, A=8, rounds=100, lr=0.3,
            use_dsc=True, compressor=RandP(p=0.2)),
    }.items():
        run, losses = run_fl(cfg, init_mlp(KEY), loss_fn, batches,
                             eval_batch=full, eval_every=25)
        results[name] = run
        print(f"{name:24s} acc={accuracy(run.params(), full):.3f} "
              f"losses={[f'{l:.3f}' for _, l in losses]}")
    dev = float(jnp.abs(results["fedavg"].x - results["eris A=8"].x).max())
    print(f"\nTheorem B.1 check: max |x_fedavg - x_eris| over all params "
          f"after 100 rounds = {dev:.2e} (bit-exact)")
    frac = 1.0 / 8
    print(f"Privacy: each of the 8 aggregators observed only "
          f"{frac:.1%} of every client update (MI bound scales with 1/A).")


if __name__ == "__main__":
    main()
