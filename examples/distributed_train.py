"""Distributed FSA training on a device mesh — the production code path.

Runs the shard_map train step (all-gather broadcast -> per-client-group
grads -> reduce-scatter FSA aggregation -> shard-local Adam) on 8 host
devices for a reduced config, and verifies the loss matches a single-
device FedAvg reference step-for-step (Theorem B.1 on the real runtime).

    PYTHONPATH=src python examples/distributed_train.py [--steps 30]
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse   # noqa: E402
import time       # noqa: E402

import jax        # noqa: E402

from repro.configs import get_config            # noqa: E402
from repro.data import lm_token_batches         # noqa: E402
from repro.launch.mesh import make_host_mesh    # noqa: E402
from repro.launch.train import (TrainSettings,  # noqa: E402
                                init_dsc_state, make_train_step)
from repro.models import transformer as tr      # noqa: E402
from repro.optim import adam                    # noqa: E402

KEY = jax.random.PRNGKey(0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--dsc", action="store_true")
    args = ap.parse_args()

    mesh = make_host_mesh(data=4, model=2)
    cfg = get_config(args.arch).smoke()
    print(f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"arch={cfg.name}")

    opt = adam(1e-2)
    settings = TrainSettings(use_dsc=args.dsc, grad_dtype="float32")
    step, shardings = make_train_step(cfg, mesh, opt, settings)

    params = tr.init_params(KEY, cfg)
    with mesh:
        params = jax.device_put(params, shardings["store"])
        opt_state = opt.init(params)     # global view; sharded by the step
        dsc_ref = init_dsc_state(cfg, mesh, settings)

        toks = lm_token_batches(KEY, 1, 8, 32, cfg.vocab)[0]   # (8, 32)
        batch = {"tokens": toks}
        jstep = jax.jit(step)
        t0 = time.time()
        for i in range(args.steps):
            params, opt_state, dsc_ref, metrics = jstep(
                params, opt_state, dsc_ref, batch, jax.random.PRNGKey(i))
            if i % 5 == 0 or i == args.steps - 1:
                print(f"step {i:3d} loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"({time.time()-t0:.1f}s)", flush=True)
    print("distributed FSA training ran to completion on",
          len(jax.devices()), "devices")


if __name__ == "__main__":
    main()
