from repro.checkpoint.msgpack_ckpt import save, restore  # noqa: F401
