from repro.checkpoint.msgpack_ckpt import (save, restore,  # noqa: F401
                                           save_sharded, restore_sharded,
                                           restore_any)
