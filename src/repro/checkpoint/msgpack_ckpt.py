"""msgpack checkpointing for pytrees of arrays — single-file and
sharded multi-host.

Single-file (:func:`save` / :func:`restore`): dtype/shape + raw bytes
per leaf with the flattened tree path as key.  Enough for the FL
simulator and the examples.

Sharded (:func:`save_sharded` / :func:`restore_sharded`): the
distributed runtime's format and the train->serve handoff.  ``save``
writes a DIRECTORY:

    manifest.msgpack        global dtype/shape per leaf (process 0)
    shard-{proc}.msgpack    this process's addressable shards, each as
                            (start offsets, local bytes)

Every process saves only what it holds (deduplicated by shard index —
replicated leaves are written once per content, by the lowest
replica), so no host ever materializes a global array.  ``restore``
reads manifest + all shard files, assembles each leaf, and — given
``shardings`` — ``jax.device_put``s it straight into the requested
layout.  That device_put IS the store->use reshard: ``launch/train.py``
saves parameters in the FSA store layout (model axis @ TP dim x client
axes @ scatter dim) and ``ServeEngine`` restores them under the serve
mesh's ``use`` shardings, whatever mesh shape either side ran on
(parity across mesh shapes is gated in tests/test_ckpt.py).  A real
deployment would swap in Orbax/tensorstore behind the same calls.
"""
from __future__ import annotations

from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _key(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def save(path: str | Path, tree) -> None:
    leaves = {}
    for p, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        arr = np.asarray(leaf)
        leaves[_key(p)] = {"dtype": str(arr.dtype),
                           "shape": list(arr.shape),
                           "data": arr.tobytes()}
    Path(path).write_bytes(msgpack.packb(leaves))


def restore(path: str | Path, target):
    raw = msgpack.unpackb(Path(path).read_bytes())
    paths, treedef = jax.tree_util.tree_flatten_with_path(target)
    out = []
    for p, leaf in paths:
        rec = raw[_key(p)]
        arr = np.frombuffer(rec["data"], dtype=rec["dtype"]).reshape(
            rec["shape"])
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch at {_key(p)}: "
                             f"{arr.shape} vs {np.shape(leaf)}")
        out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


# ====================================================== sharded checkpoints
_MANIFEST = "manifest.msgpack"


def _shards_of(leaf):
    """(start_offsets, numpy block) per addressable shard this process
    should write — one writer per distinct shard index (replica 0)."""
    if not isinstance(leaf, jax.Array):
        arr = np.asarray(leaf)
        return [([0] * arr.ndim, arr)]
    out = []
    for s in leaf.addressable_shards:
        if s.replica_id != 0:
            continue  # another device holds the identical copy
        starts = [int(idx.start or 0) for idx in s.index]
        out.append((starts, np.asarray(s.data)))
    return out


def save_sharded(path: str | Path, tree) -> None:
    """Write ``tree`` as a checkpoint directory (see module docstring).

    Safe under ``jax.jit``-produced sharded arrays: each process writes
    only its addressable, replica-0 shards.  Single-process runs produce
    ``manifest.msgpack`` + ``shard-0.msgpack``.
    """
    path = Path(path)
    path.mkdir(parents=True, exist_ok=True)
    proc = jax.process_index()
    manifest, shards = {}, {}
    for p, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _key(p)
        arr_dtype = np.dtype(jnp.asarray(leaf).dtype
                             if isinstance(leaf, jax.Array)
                             else np.asarray(leaf).dtype)
        manifest[key] = {"dtype": arr_dtype.name,
                         "shape": list(np.shape(leaf))}
        recs = []
        for starts, block in _shards_of(leaf):
            recs.append({"start": starts,
                         "shape": list(block.shape),
                         "data": np.ascontiguousarray(block).tobytes()})
        shards[key] = recs
    (path / f"shard-{proc}.msgpack").write_bytes(msgpack.packb(shards))
    if proc == 0:
        (path / _MANIFEST).write_bytes(msgpack.packb(manifest))


def restore_sharded(path: str | Path, target, shardings=None):
    """Assemble a checkpoint directory onto ``target``'s structure.

    ``shardings``: optional pytree (same structure) of
    ``jax.sharding.Sharding`` — each assembled leaf is ``device_put``
    under it, which performs the store->use (or any cross-mesh) reshard.
    Without it, leaves come back as ordinary committed-to-default arrays.
    """
    path = Path(path)
    manifest = msgpack.unpackb((path / _MANIFEST).read_bytes())
    merged: dict[str, Any] = {}
    for f in sorted(path.glob("shard-*.msgpack")):
        for key, recs in msgpack.unpackb(f.read_bytes()).items():
            merged.setdefault(key, []).extend(recs)
    paths, treedef = jax.tree_util.tree_flatten_with_path(target)
    sh_leaves = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: x is None)
        if shardings is not None else [None] * len(paths))
    out = []
    for (p, leaf), sh in zip(paths, sh_leaves):
        key = _key(p)
        meta = manifest[key]
        if tuple(meta["shape"]) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch at {key}: "
                             f"{tuple(meta['shape'])} vs {np.shape(leaf)}")
        full = np.zeros(meta["shape"], dtype=meta["dtype"])
        for rec in merged.get(key, ()):
            sl = tuple(slice(st, st + sz)
                       for st, sz in zip(rec["start"], rec["shape"]))
            full[sl] = np.frombuffer(
                rec["data"], dtype=meta["dtype"]).reshape(rec["shape"])
        out.append(jax.device_put(full, sh) if sh is not None
                   else jnp.asarray(full))
    return jax.tree_util.tree_unflatten(treedef, out)


def restore_any(path: str | Path, target, shardings=None):
    """Dispatch on the checkpoint's format: a directory restores the
    sharded layout, a single file the legacy one (``shardings`` then
    applies as a plain post-restore device_put)."""
    path = Path(path)
    if path.is_dir():
        return restore_sharded(path, target, shardings=shardings)
    tree = restore(path, target)
    if shardings is not None:
        tree = jax.tree_util.tree_map(jax.device_put, tree, shardings)
    return tree
