"""Minimal msgpack checkpointing for pytrees of arrays.

Stores dtype/shape + raw bytes per leaf with the flattened tree path as
key; restores onto a target structure (shape/dtype checked).  Enough for
the FL simulator and the examples; a real deployment would swap in
Orbax/tensorstore behind the same two calls.
"""
from __future__ import annotations

from pathlib import Path

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _key(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def save(path: str | Path, tree) -> None:
    leaves = {}
    for p, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        arr = np.asarray(leaf)
        leaves[_key(p)] = {"dtype": str(arr.dtype),
                           "shape": list(arr.shape),
                           "data": arr.tobytes()}
    Path(path).write_bytes(msgpack.packb(leaves))


def restore(path: str | Path, target):
    raw = msgpack.unpackb(Path(path).read_bytes())
    paths, treedef = jax.tree_util.tree_flatten_with_path(target)
    out = []
    for p, leaf in paths:
        rec = raw[_key(p)]
        arr = np.frombuffer(rec["data"], dtype=rec["dtype"]).reshape(
            rec["shape"])
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch at {_key(p)}: "
                             f"{arr.shape} vs {np.shape(leaf)}")
        out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)
