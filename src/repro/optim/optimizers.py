"""Minimal optimizer library (optax-style (init, update) pairs).

Works on arbitrary pytrees — including the shard-local parameter segments
the distributed FSA runtime updates (each aggregator runs the optimizer on
its own disjoint shard; since all optimizers here are coordinate-wise, the
sharded update equals the centralized one, preserving Theorem B.1 for
FedAdam/momentum too — see paper Sec. 5 'Benefits')."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]  # (g, state, p) -> (delta, state)


def sgd(lr: float) -> Optimizer:
    return Optimizer(
        init=lambda p: (),
        update=lambda g, s, p: (jax.tree.map(lambda gi: -lr * gi, g), s))


def momentum(lr: float, beta: float = 0.9) -> Optimizer:
    def init(p):
        return jax.tree.map(jnp.zeros_like, p)

    def update(g, m, p):
        m = jax.tree.map(lambda mi, gi: beta * mi + gi, m, g)
        return jax.tree.map(lambda mi: -lr * mi, m), m

    return Optimizer(init, update)


def adam(lr: float, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    class AdamState(NamedTuple):
        mu: Any
        nu: Any
        t: jax.Array

    def init(p):
        z = lambda q: jax.tree.map(jnp.zeros_like, q)
        return AdamState(z(p), z(p), jnp.zeros((), jnp.int32))

    def update(g, s, p):
        t = s.t + 1
        mu = jax.tree.map(lambda m, gi: b1 * m + (1 - b1) * gi, s.mu, g)
        nu = jax.tree.map(lambda v, gi: b2 * v + (1 - b2) * gi * gi, s.nu, g)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def step(m, v, pi):
            d = -lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                d = d - lr * weight_decay * pi
            return d

        return jax.tree.map(step, mu, nu, p), AdamState(mu, nu, t)

    return Optimizer(init, update)
