"""Composable decoder: parameter spec / init / forward / loss / decode.

All per-layer parameters are stacked along a leading L axis so the layer
stack is a single ``jax.lax.scan`` (O(1) trace & HLO size regardless of
depth — essential for the 512-device dry-run compiles).  Every block type
(dense / moe / hybrid / ssm) shares this contract:

    block(cfg, lp, x, mode, cache) -> (x, new_cache, aux)

where lp is one layer's parameter slice and cache is that layer's decode
state (None in train/prefill).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.config import ModelConfig


def _flash_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _ring(cfg: ModelConfig, tp) -> int:
    """Static model-axis size when ring-overlapped collectives are on
    (0 selects the monolithic psum conjugates)."""
    return tp.size if (tp is not None and cfg.overlap_collectives) else 0


# ==================================================== tensor parallelism
# The model-axis shard-plan subsystem lives in ``models/shard_plan``
# (family-generic: expert-parallel MoE, sharded recurrent mixers,
# sequence parallelism).  Re-exported here under the historical names.
from repro.models.shard_plan import (TPPlan, TPRuntime,  # noqa: F401
                                     tp_plan)


# ============================================================ param spec
def param_spec(cfg: ModelConfig) -> dict:
    """Shapes of every parameter (single source of truth; init + counting
    + sharding rules all derive from this)."""
    D, V, Lyr = cfg.d_model, cfg.vocab, cfg.n_layers
    F, Q, KV, hd, H = cfg.d_ff, cfg.q_dim, cfg.kv_dim, cfg.hd, cfg.n_heads
    blk: dict[str, tuple] = {"ln1": (Lyr, D), "ln2": (Lyr, D)}
    if cfg.family != "ssm":
        blk.update(wq=(Lyr, D, Q), wk=(Lyr, D, KV), wv=(Lyr, D, KV),
                   wo=(Lyr, Q, D))
        if cfg.qkv_bias:
            blk.update(bq=(Lyr, Q), bk=(Lyr, KV), bv=(Lyr, KV))
        if cfg.qk_norm:
            blk.update(q_norm=(Lyr, hd), k_norm=(Lyr, hd))
    if cfg.family == "moe":
        E = cfg.n_experts
        blk.update(router=(Lyr, D, E), w_gate=(Lyr, E, D, F),
                   w_up=(Lyr, E, D, F), w_down=(Lyr, E, F, D))
    elif cfg.family == "ssm":
        blk.update(xq=(Lyr, D, Q), xk=(Lyr, D, Q), xv=(Lyr, D, Q),
                   xo=(Lyr, Q, D), w_i=(Lyr, D, H), w_f=(Lyr, D, H),
                   b_i=(Lyr, H), b_f=(Lyr, H),
                   p_up=(Lyr, D, 2 * D), p_gate=(Lyr, D, 2 * D),
                   p_down=(Lyr, 2 * D, D))
    elif cfg.family == "hybrid":
        Di, N = D, cfg.ssm_state
        blk.update(m_in=(Lyr, D, 2 * Di), m_dt=(Lyr, D, Di),
                   m_bc=(Lyr, D, 2 * N), m_A=(Lyr, Di, N),
                   m_D=(Lyr, Di), m_out=(Lyr, Di, D), m_ln=(Lyr, Di),
                   w_gate=(Lyr, D, F), w_up=(Lyr, D, F), w_down=(Lyr, F, D))
    else:                                   # dense / audio / vlm
        blk.update(w_gate=(Lyr, D, F), w_up=(Lyr, D, F), w_down=(Lyr, F, D))
    spec = {"embed": (V, D), "ln_f": (D,), "blocks": blk}
    if not cfg.tie_embeddings:
        spec["lm_head"] = (D, V)
    if cfg.frontend == "vlm":
        spec["proj_in"] = (cfg.d_frontend, D)
    return spec


def param_count(cfg: ModelConfig) -> int:
    import numpy as np
    spec = param_spec(cfg)
    total = 0
    for k, v in spec.items():
        if k == "blocks":
            total += sum(int(np.prod(s)) for s in v.values())
        else:
            total += int(np.prod(v))
    return total


def active_param_count(cfg: ModelConfig) -> int:
    """Params touched per token (MoE: top_k of n_experts expert FFNs)."""
    total = param_count(cfg)
    if cfg.family == "moe":
        expert = 3 * cfg.d_model * cfg.d_ff
        total -= cfg.n_layers * (cfg.n_experts - cfg.top_k) * expert
    return total


def init_params(key: jax.Array, cfg: ModelConfig) -> dict:
    spec = param_spec(cfg)
    dtype = jnp.dtype(cfg.dtype)

    def one(key, name, shape):
        if name.startswith(("ln", "q_norm", "k_norm", "m_ln")):
            return jnp.ones(shape, dtype)
        if name.startswith("b") or name in ("m_D",):
            return jnp.zeros(shape, dtype)
        if name == "b_f":
            return jnp.full(shape, 2.0, dtype)      # open forget gates
        if name == "m_A":
            return jnp.log(jnp.broadcast_to(
                jnp.arange(1, shape[-1] + 1, dtype=jnp.float32),
                shape)).astype(dtype)               # S4D-real init
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        return (jax.random.normal(key, shape, jnp.float32) *
                (fan_in ** -0.5)).astype(dtype)

    flat: dict[str, Any] = {}
    idx = 0
    for name, shape in spec.items():
        if name == "blocks":
            flat["blocks"] = {}
            for bn, bs in shape.items():
                flat["blocks"][bn] = one(jax.random.fold_in(key, idx), bn, bs)
                idx += 1
        else:
            flat[name] = one(jax.random.fold_in(key, idx), name, shape)
            idx += 1
    return flat


# ================================================================= blocks
def _attn_ctx(cfg: ModelConfig, lp, x, positions, window, tp, seq):
    """Context-parallel (ring) attention region: the sequence, not the
    heads, shards over the model axis — the escape hatch for configs
    whose head counts can't divide (odd heads, GQA kv < tp).  Weights
    are replicated (grads partial — see shard_plan._leaf_spec); each
    position projects q/k/v for ITS S/n chunk and K/V chunks rotate
    through the ppermute ring with online-softmax accumulation.  Under a
    seq plan the residual stream already IS the chunk, so entry/exit are
    free; otherwise ctx_enter/ctx_exit slice and reassemble."""
    B = x.shape[0]
    n = tp.size
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    if not seq:
        h = L.ctx_enter(h, tp.axis, n)
    C = h.shape[1]
    cpos = jax.lax.dynamic_slice_in_dim(positions, tp.index * C, C, 1)
    q = h @ lp["wq"]
    k = h @ lp["wk"]
    v = h @ lp["wv"]
    if cfg.qkv_bias:
        q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    q = q.reshape(B, C, cfg.n_heads, cfg.hd)
    k = k.reshape(B, C, cfg.n_kv_heads, cfg.hd)
    v = v.reshape(B, C, cfg.n_kv_heads, cfg.hd)
    if cfg.qk_norm:
        q = L.rms_norm(q, lp["q_norm"], cfg.norm_eps)
        k = L.rms_norm(k, lp["k_norm"], cfg.norm_eps)
    q = L.rope(q, cpos, cfg.rope_theta)
    k = L.rope(k, cpos, cfg.rope_theta)
    out = L.ring_attention(q, k, v, tp.axis, n, window=window)
    y = out.reshape(B, C, cfg.n_heads * cfg.hd) @ lp["wo"]
    if not seq:
        y = L.ctx_exit(y, tp.axis, n)
    return x + y, None


def _attn(cfg: ModelConfig, lp, x, positions, mode, cache, window, tp=None):
    B = x.shape[0]
    tp_attn = tp is not None and tp.plan.attn
    seq = tp is not None and tp.plan.seq
    if (tp is not None and tp.plan.ctx > 1 and mode == "train"
            and window != 0
            and (x.shape[1] * (tp.size if seq else 1)) % tp.size == 0):
        return _attn_ctx(cfg, lp, x, positions, window, tp, seq)
    n_heads = cfg.n_heads // (tp.size if tp_attn else 1)
    n_kv = cfg.n_kv_heads // (tp.size if tp_attn else 1)
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    if seq:
        # sequence-parallel entry: assemble the full sequence (bwd:
        # psum_scatter of the shards' partial cotangents)
        h = L.tp_seq_gather(h, tp.axis, 1)
    elif tp_attn:
        h = L.tp_enter(h, tp.axis, _ring(cfg, tp))
    S = h.shape[1]
    q = h @ lp["wq"]
    k = h @ lp["wk"]
    v = h @ lp["wv"]
    if cfg.qkv_bias:
        q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    q = q.reshape(B, S, n_heads, cfg.hd)
    k = k.reshape(B, S, n_kv, cfg.hd)
    v = v.reshape(B, S, n_kv, cfg.hd)
    if cfg.qk_norm:
        q = L.rms_norm(q, lp["q_norm"], cfg.norm_eps)
        k = L.rms_norm(k, lp["k_norm"], cfg.norm_eps)
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    if cfg.attn_batch_shard and mode != "decode":
        from jax.sharding import PartitionSpec as _P
        bs = _P("model")
        q, k, v = (jax.lax.with_sharding_constraint(t, bs)
                   for t in (q, k, v))
    if mode == "decode":
        pos = positions[0, 0]
        size = cache["k"].shape[1]
        slot = pos % size if window is not None else pos
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"],
                                                      k.astype(cache["k"].dtype), slot, 1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"],
                                                      v.astype(cache["v"].dtype), slot, 1)
        out = L.decode_attention(q, k_cache, v_cache, pos, window=window)
        new_cache = {"k": k_cache, "v": v_cache}
    else:
        from repro.kernels import flash_attention as fa
        if (cfg.flash_attention and mode == "train"
                and not cfg.attn_batch_shard and window != 0
                and fa.supports(S, cfg.hd)):
            # blocked online-softmax kernel, custom-VJP backward: no S x S
            # score materialization in either pass.  Head counts here are
            # already TP-local; a seq plan entered above, so S is full.
            out = fa.flash_attention(
                q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
                causal=True, window=window,
                interpret=_flash_interpret()).swapaxes(1, 2)
        else:
            out = L.causal_attention(
                q, k, v, window=window, chunk=cfg.attn_chunk,
                scores_f32=cfg.attn_scores_f32 and not cfg.bf16_residency)
        if cfg.attn_batch_shard:
            from jax.sharding import PartitionSpec as _P
            out = jax.lax.with_sharding_constraint(out, _P("model"))
        new_cache = ({"k": k, "v": v} if mode == "prefill" else None)
    y = out.reshape(B, S, n_heads * cfg.hd) @ lp["wo"]
    if seq and tp_attn:
        y = L.tp_seq_scatter(y, tp.axis, 1)     # partials -> seq shards
    elif seq:
        # replicated-attention fallback under a seq plan: every position
        # computed the full (identical) output; keep this position's
        # sequence slice — the entry gather's psum_scatter assembles the
        # per-slice cotangent contributions on the way back
        s_loc = S // tp.size
        y = jax.lax.dynamic_slice_in_dim(y, tp.index * s_loc, s_loc, 1)
    elif tp_attn:
        y = L.tp_exit(y, tp.axis, _ring(cfg, tp))
    return x + y, new_cache


def _gated_mlp(h, w_gate, w_up, w_down):
    return (jax.nn.silu(h @ w_gate) * (h @ w_up)) @ w_down


def _ffn(cfg, lp, x, tp=None):
    tp_ffn = tp is not None and tp.plan.ffn
    seq = tp is not None and tp.plan.seq       # seq plans imply tp_ffn
    h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    if seq:
        h = L.tp_seq_gather(h, tp.axis, 1)
    elif tp_ffn:
        h = L.tp_enter(h, tp.axis, _ring(cfg, tp))
    y = _gated_mlp(h, lp["w_gate"], lp["w_up"], lp["w_down"])
    if seq:
        y = L.tp_seq_scatter(y, tp.axis, 1)
    elif tp_ffn:
        y = L.tp_exit(y, tp.axis, _ring(cfg, tp))
    return x + y


def _mamba(cfg, lp, x, mode, state, tp=None):
    """Selective-SSM branch (hybrid).  Returns (delta, new_state).

    Under a sharded-mixer plan the CHANNEL dim is split over the model
    axis: m_dt/m_A/m_D/m_ln/m_out hold local channels and the chunked
    scan runs fully local (state is per-channel).  m_in and m_bc stay
    replicated (their z/u and B/C halves straddle the split) with
    partial-grad psum; the per-channel slices of z/u are taken locally.
    The m_ln RMS norm is the one cross-shard statistic (psum'd mean of
    squares over the full channel width)."""
    B, S, D = x.shape
    tp_mix = tp is not None and tp.plan.mixer
    x_in = L.tp_push(x, tp.axis) if tp_mix else x
    zu = x_in @ lp["m_in"]
    z, u = jnp.split(zu, 2, axis=-1)
    dt = jax.nn.softplus(x_in @ lp["m_dt"])
    bc = x_in @ lp["m_bc"]
    Bm, Cm = jnp.split(bc, 2, axis=-1)
    if tp_mix:
        d_loc = dt.shape[-1]                   # m_dt is column-sharded
        z = jax.lax.dynamic_slice_in_dim(z, tp.index * d_loc, d_loc, -1)
        u = jax.lax.dynamic_slice_in_dim(u, tp.index * d_loc, d_loc, -1)
    u = jax.nn.silu(u)
    if mode == "decode":
        h_new, y = ssm_lib.ssm_decode_step(
            state, u[:, 0], dt[:, 0], Bm[:, 0], Cm[:, 0],
            lp["m_A"], lp["m_D"])
        y = y[:, None]
    else:
        y, h_new = ssm_lib.ssm_scan(u, dt, Bm, Cm, lp["m_A"], lp["m_D"],
                                    chunk=cfg.scan_chunk,
                                    scan_f32=cfg.ssm_scan_f32)
        h_new = h_new if mode == "prefill" else None
    if tp_mix:
        y = L.rms_norm_sharded(y, lp["m_ln"], cfg.norm_eps, tp.axis, D)
    else:
        y = L.rms_norm(y, lp["m_ln"], cfg.norm_eps)
    y = y * jax.nn.silu(z)
    out = y @ lp["m_out"]
    return (L.tp_pull(out, tp.axis) if tp_mix else out), h_new


def _mlstm(cfg, lp, x, mode, state, tp=None):
    B, S, D = x.shape
    tp_mix = tp is not None and tp.plan.mixer
    n_heads = cfg.n_heads // (tp.size if tp_mix else 1)
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    if tp_mix:
        # head-sharded mixer: xq/xk/xv and the i/f gates are
        # column-parallel on heads, xo row-parallel; the recurrent state
        # is per-head, so the whole chunked recurrence runs local
        h = L.tp_push(h, tp.axis)
    q = (h @ lp["xq"]).reshape(B, S, n_heads, cfg.hd)
    k = (h @ lp["xk"]).reshape(B, S, n_heads, cfg.hd)
    v = (h @ lp["xv"]).reshape(B, S, n_heads, cfg.hd)
    i_pre = h @ lp["w_i"] + lp["b_i"]
    f_pre = h @ lp["w_f"] + lp["b_f"]
    if cfg.attn_batch_shard and mode != "decode":
        from jax.sharding import PartitionSpec as _P
        bs = _P("model")
        q, k, v, i_pre, f_pre = (jax.lax.with_sharding_constraint(t, bs)
                                 for t in (q, k, v, i_pre, f_pre))
    if mode == "decode":
        new_state, out = ssm_lib.mlstm_decode_step(
            state, q[:, 0], k[:, 0], v[:, 0], i_pre[:, 0], f_pre[:, 0])
        out = out[:, None]
    else:
        out = ssm_lib.mlstm_parallel(q, k, v, i_pre, f_pre,
                                     chunk=cfg.attn_chunk,
                                     scores_f32=cfg.attn_scores_f32)
        new_state = None
        if mode == "prefill":
            # build the recurrent state by replaying the last step math:
            # run a cheap recurrent pass is O(T); instead fold the whole
            # prefix with the recurrence once (scan) — acceptable at
            # prefill time, states are tiny.
            def step(st, inp):
                qq, kk, vv, ii, ff = inp
                st, _ = ssm_lib.mlstm_decode_step(st, qq, kk, vv, ii, ff)
                return st, ()
            st0 = init_mlstm_state(cfg, B, x.dtype)
            elems = (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
                     i_pre.swapaxes(0, 1), f_pre.swapaxes(0, 1))
            new_state, _ = jax.lax.scan(step, st0, elems)
    y = out.reshape(B, S, n_heads * cfg.hd) @ lp["xo"]
    if tp_mix:
        y = L.tp_pull(y, tp.axis)
    return x + y, new_state


def init_mlstm_state(cfg, B, dtype=jnp.float32):
    H, hd = cfg.n_heads, cfg.hd
    return {"C": jnp.zeros((B, H, hd, hd), jnp.float32),
            "n": jnp.zeros((B, H, hd), jnp.float32),
            "m": jnp.full((B, H), -1e30, jnp.float32)}


def _block(cfg: ModelConfig, lp, x, positions, mode, cache, window, tp=None):
    aux = {}
    if cfg.family == "ssm":
        x, mix_state = _mlstm(cfg, lp, x, mode,
                              cache["mix"] if cache else None, tp)
        tp_ffn = tp is not None and tp.plan.ffn
        h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        if tp_ffn:                      # gated in-block projection pair
            h = L.tp_push(h, tp.axis)
        y = _gated_mlp(h, lp["p_gate"], lp["p_up"], lp["p_down"])
        if tp_ffn:
            y = L.tp_pull(y, tp.axis)
        x = x + y
        new_cache = {"mix": mix_state} if mode != "train" else None
        return x, new_cache, aux
    if cfg.family == "hybrid":
        attn_out, kv = _attn(cfg, lp, x, positions, mode,
                             cache.get("kv") if cache else None, window, tp)
        m_out, m_state = _mamba(cfg, lp, x, mode,
                                cache.get("ssm") if cache else None, tp)
        x = 0.5 * (attn_out + (x + m_out))       # parallel heads, averaged
        x = _ffn(cfg, lp, x, tp)
        new_cache = ({"kv": kv, "ssm": m_state} if mode != "train" else None)
        return x, new_cache, aux
    # dense / moe / audio / vlm
    x, kv = _attn(cfg, lp, x, positions, mode,
                  cache.get("kv") if cache else None, window, tp)
    if cfg.family == "moe":
        h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        y, aux = moe_lib.moe_ffn(h, lp["router"], lp["w_gate"], lp["w_up"],
                                 lp["w_down"], top_k=cfg.top_k,
                                 capacity_factor=cfg.capacity_factor,
                                 group=cfg.moe_group_size, tp=tp)
        x = x + y
    else:
        x = _ffn(cfg, lp, x, tp)
    new_cache = {"kv": kv} if mode != "train" else None
    return x, new_cache, aux


# ================================================================ forward
@jax.custom_vjp
def _dense_grad_lookup(table, ids):
    """table[ids] with a dense one-hot-matmul backward.  Value- and
    gradient-identical to the plain gather (the one-hot dot touches each
    cotangent row exactly once), but the transpose is a single MXU matmul
    instead of a scatter-add — which XLA CPU lowers to a serial while
    loop re-reading the full table every trip (it dominated the train
    step's HBM-traffic proxy)."""
    return table[ids]


def _dense_grad_lookup_fwd(table, ids):
    return table[ids], (table, ids)


def _dense_grad_lookup_bwd(res, ct):
    import numpy as np
    table, ids = res
    V = table.shape[0]
    oh = (jax.lax.broadcasted_iota(jnp.int32, (*ids.shape, V), ids.ndim)
          == ids[..., None]).astype(ct.dtype)
    dtable = jax.lax.dot_general(
        oh.reshape(-1, V), ct.reshape(-1, ct.shape[-1]),
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(table.dtype)
    return dtable, np.zeros(ids.shape, jax.dtypes.float0)


_dense_grad_lookup.defvjp(_dense_grad_lookup_fwd, _dense_grad_lookup_bwd)


def _embed_rows(params, cfg: ModelConfig, ids):
    if cfg.dense_embed_grad:
        return _dense_grad_lookup(params["embed"], ids)
    return params["embed"][ids]


def embed_inputs(params, cfg: ModelConfig, tokens,
                 frontend_embeds=None, tp=None):
    """Token embedding; VLM prepends projected patch embeddings.

    Under a vocab-parallel plan each shard holds vocab rows
    [index*V/tp, (index+1)*V/tp): out-of-range tokens look up zero and
    the psum (``tp_pull``) assembles the full embedding — the backward
    stays local (each shard accumulates only its own rows' grads)."""
    if tp is not None and tp.plan.vocab:
        v_loc = cfg.vocab // tp.size
        idx = tokens - tp.index * v_loc
        ok = (idx >= 0) & (idx < v_loc)
        x = jnp.where(ok[..., None],
                      _embed_rows(params, cfg, jnp.clip(idx, 0, v_loc - 1)),
                      0)
        if tp.plan.seq:
            # sequence-parallel residual stream: reduce-scatter the
            # vocab partials straight into (B, S/tp, D) shards
            x = L.tp_seq_scatter(x, tp.axis, 1)
        else:
            x = L.tp_exit(x, tp.axis, _ring(cfg, tp))
    else:
        x = _embed_rows(params, cfg, tokens)
    if cfg.frontend == "vlm":
        assert frontend_embeds is not None
        img = frontend_embeds.astype(x.dtype) @ params["proj_in"]
        x = jnp.concatenate([img, x], axis=1)
    return x


def _remat_policy(name: str):
    """Selective-remat policies for the layer-scan checkpoint.  ``full``
    is the historical blanket remat (save only the carry); the others
    keep matmul outputs resident so the backward re-runs only the cheap
    elementwise/softmax glue — HBM re-read traffic drops by the width of
    every recomputed GEMM input."""
    cp = jax.checkpoint_policies
    table = {
        "full": None,
        "dots": cp.dots_with_no_batch_dims_saveable,
        "dots_batch": cp.dots_saveable,
        "offload_dots": cp.offload_dot_with_no_batch_dims(
            "device", "pinned_host"),
    }
    if name not in table:
        raise ValueError(
            f"remat_policy {name!r}: want one of {sorted(table)} | none")
    return table[name]


def forward(params, cfg: ModelConfig, tokens, frontend_embeds=None,
            mode: str = "train", window: Optional[int] = None,
            remat: bool = True, tp: Optional[TPRuntime] = None,
            inputs_embeds=None):
    """Full-sequence forward.  Returns (logits, caches, aux).

    caches is the per-layer stacked decode state when mode == 'prefill'.
    With ``remat`` each layer is rematerialized in the backward pass
    (activation memory = one carry per layer instead of all residuals).
    With ``tp`` (inside a manual shard_map over tp.axis) params are the
    local shards of the TPPlan and, when the plan shards the vocab, the
    returned logits are vocab-sharded (B, S, V/tp) — ``loss_fn`` computes
    the cross-entropy without ever materializing full logits.  Under a
    sequence-parallel plan the residual stream between TP regions is
    (B, S/tp, D); the logits come back full-sequence (the unembed
    gathers), so the loss path is unchanged.

    ``inputs_embeds`` (B, S, D) bypasses the token-embedding lookup — the
    continuous-input hook the DLG gradient-inversion attack optimizes
    over (``repro.privacy``); ``tokens`` still supplies positions and CE
    targets.  Replicated path only (``tp`` must be None).
    """
    seq = tp is not None and tp.plan.seq
    if seq:
        s_full = tokens.shape[1]
        if s_full % tp.size != 0:
            raise ValueError(
                f"sequence-parallel plan needs seq_len divisible by the "
                f"model axis: {s_full} % {tp.size} != 0")
    if inputs_embeds is not None:
        if tp is not None:
            raise ValueError("inputs_embeds is a replicated-path hook "
                             "(attack/simulator side); tp must be None")
        x = inputs_embeds
    else:
        x = embed_inputs(params, cfg, tokens, frontend_embeds, tp)
    B = x.shape[0]
    S = x.shape[1] * (tp.size if seq else 1)    # full sequence length
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def body(carry, lp):
        h = carry
        h, cache, aux = _block(cfg, lp, h, positions, mode, None, window, tp)
        return h, (cache, aux.get("load_balance", jnp.zeros((), jnp.float32)))

    if remat and mode == "train" and cfg.remat_policy != "none":
        body = jax.checkpoint(body, prevent_cse=False,
                              policy=_remat_policy(cfg.remat_policy))
    x, (caches, lb) = jax.lax.scan(body, x, params["blocks"])
    # seq_ce (ssm/hybrid, whose residual stream stays replicated): run
    # the final-norm region on this position's sequence chunk — entered
    # with a slice whose backward ASSEMBLES the chunk cotangents
    # (ctx_enter), exited into the unembed through the seq conjugate
    # (all-gather fwd, psum_scatter bwd) so the vocab-partial dL/dx is
    # summed exactly once.  ln_f grads become partial (shard_plan).
    seq_ce = (tp is not None and tp.plan.seq_ce and not seq
              and mode == "train" and x.shape[1] % tp.size == 0)
    if seq_ce:
        x = L.ctx_enter(x, tp.axis, tp.size)
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    if tp is not None and tp.plan.vocab:
        # column-parallel unembed; a seq plan assembles the sequence here
        x = (L.tp_seq_gather(x, tp.axis, 1) if (seq or seq_ce)
             else L.tp_enter(x, tp.axis, _ring(cfg, tp)))
    logits = x @ head
    return logits, caches, {"load_balance": lb.mean()}


def _select_logit(pred, tgt):
    """pred[..., tgt] as a one-hot masked sum — value- and
    gradient-identical to take_along_axis (exactly one nonzero term per
    row), but both directions are dense fused elementwise ops: the gather
    transpose otherwise lowers to a serial scatter-add while loop on XLA
    CPU that re-reads the whole (B, S, V) buffer every trip (it was ~60%
    of the train step's HBM-traffic proxy)."""
    iota = jax.lax.broadcasted_iota(jnp.int32, pred.shape, pred.ndim - 1)
    return jnp.sum(jnp.where(iota == tgt[..., None], pred, 0), axis=-1)


def loss_fn(params, cfg: ModelConfig, batch, window=None,
            tp: Optional[TPRuntime] = None):
    """Causal LM loss.  batch: dict(tokens (B,S) [, frontend_embeds,
    loss_mask (B,S)]).  Next-token CE in f32 with logits sharded-friendly
    logsumexp.

    ``tp=None`` is the replicated path every simulator engine runs.  With
    a TPRuntime (inside the distributed runtime's manual shard_map) the
    forward computes on this position's parameter shards and, under a
    vocab-parallel plan, the CE runs on vocab-sharded logits: pmax/psum
    logsumexp plus a masked target-logit gather — the transposes stay
    local, so gradients are exact (not tp-times-counted)."""
    tokens = batch["tokens"]
    logits, _, aux = forward(params, cfg, tokens,
                             batch.get("frontend_embeds"), "train", window,
                             tp=tp, inputs_embeds=batch.get("inputs_embeds"))
    nll = _ce(cfg, logits, tokens, batch.get("loss_mask"), tp)
    if cfg.family == "moe":
        nll = nll + 0.01 * aux["load_balance"]
    return nll


def _ce(cfg: ModelConfig, logits, tokens, loss_mask, tp):
    """Masked next-token CE from (possibly vocab-sharded) logits — the
    shared tail of ``loss_fn`` and ``pipeline_loss_fn``."""
    # align: for VLM, logits cover [img; text]; predict text tokens only
    n_pre = cfg.n_frontend_tokens if cfg.frontend == "vlm" else 0
    logits = logits[:, n_pre:, :]
    targ = tokens[:, 1:]
    fp32_logits = cfg.loss_fp32_logits and not cfg.bf16_residency
    if tp is not None and tp.plan.vocab:
        # sharded-vocab CE: max over shards via pmax (stop-grad, like the
        # max-shift below), sum-of-exp and target logit assembled with
        # tp_pull so each shard's backward touches only its own columns
        v_loc = cfg.vocab // tp.size
        pred = logits[:, :-1]
        if fp32_logits:
            pred = pred.astype(jnp.float32)
        m = jax.lax.pmax(jax.lax.stop_gradient(pred.max(-1)), tp.axis)
        e = jnp.exp(pred - m[..., None])
        lse = m.astype(jnp.float32) + jnp.log(
            L.tp_exit(jnp.sum(e, axis=-1, dtype=jnp.float32), tp.axis,
                      _ring(cfg, tp)))
        idx = targ - tp.index * v_loc
        ok = (idx >= 0) & (idx < v_loc)
        ll_loc = _select_logit(pred, jnp.clip(idx, 0, v_loc - 1))
        ll = L.tp_exit(jnp.where(ok, ll_loc, 0).astype(jnp.float32),
                       tp.axis, _ring(cfg, tp))
    elif fp32_logits:
        pred = logits[:, :-1].astype(jnp.float32)
        lse = jax.nn.logsumexp(pred, axis=-1)
        ll = _select_logit(pred, targ)
    else:
        # avoid materializing an f32 copy of the (B,S,V) logits: max-shift
        # and exp in the compute dtype, accumulate the sum in f32
        pred = logits[:, :-1]
        m = jax.lax.stop_gradient(pred.max(-1))
        e = jnp.exp(pred - m[..., None])
        lse = m.astype(jnp.float32) + jnp.log(
            jnp.sum(e, axis=-1, dtype=jnp.float32))
        ll = _select_logit(pred, targ).astype(jnp.float32)
    nll = lse - ll
    if loss_mask is not None:
        m = loss_mask[:, 1:]
        nll = (nll * m).sum() / jnp.maximum(m.sum(), 1)
    else:
        nll = nll.mean()
    return nll


def pipeline_loss_fn(params, cfg: ModelConfig, batch, window=None,
                     tp: Optional[TPRuntime] = None, pipe=None):
    """Causal LM loss with the layer stack split into ``pipe.plan.size``
    contiguous stages and the batch into ``microbatches`` slices.

    Runs inside the manual shard_map train body with the pipe axis in
    scope: ``params["blocks"]`` leaves hold this stage's L/p layer rows
    (everything else replicated over pipe).  One differentiable
    ``lax.scan`` over the m + p - 1 wavefront ticks: each tick ppermutes
    the activation carry one stage forward while computing this stage's
    next resident microbatch — the boundary send overlaps the following
    microbatch's compute, and AD of the scan replays the wavefront in
    reverse, realizing the interleaved 1F1B order that
    ``shard_plan.pipeline_schedule`` enumerates.  Stage 0 injects the
    embedding of microbatch clip(t, 0, m-1); the last stage folds the CE
    of the microbatch that entered p - 1 ticks earlier; both are
    where/mask-selected so every pipe coordinate traces one identical
    program.  The returned loss is psum'd over pipe (identical on every
    coordinate) = the mean of the m per-microbatch mean-CEs, which
    equals ``loss_fn``'s full-batch mean when microbatches weigh equally
    (no loss_mask, B % m == 0).
    """
    if pipe is None or not pipe.plan.active:
        return loss_fn(params, cfg, batch, window, tp)
    p, m = pipe.plan.size, pipe.plan.microbatches
    tokens = batch["tokens"]
    B, S = tokens.shape
    if B % m != 0:
        raise ValueError(f"batch {B} not divisible by microbatches {m}")
    mb = B // m
    seq = tp is not None and tp.plan.seq
    tok_mb = tokens.reshape(m, mb, S)
    mask_mb = (batch["loss_mask"].reshape(m, mb, S)
               if batch.get("loss_mask") is not None else None)
    fe_mb = (batch["frontend_embeds"].reshape(
        m, mb, *batch["frontend_embeds"].shape[1:])
        if batch.get("frontend_embeds") is not None else None)
    n_pre = cfg.n_frontend_tokens if cfg.frontend == "vlm" else 0
    S_h = (S + n_pre) // (tp.size if seq else 1)   # carry seq length
    positions = jnp.broadcast_to(jnp.arange(S + n_pre), (mb, S + n_pre))
    perm = [(i, (i + 1) % p) for i in range(p)]
    stage = pipe.index
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]

    def block_body(carry, lp):
        h = carry
        h, _, aux = _block(cfg, lp, h, positions, "train", None, window, tp)
        return h, aux.get("load_balance", jnp.zeros((), jnp.float32))

    if cfg.remat_policy != "none":
        block_body = jax.checkpoint(block_body, prevent_cse=False,
                                    policy=_remat_policy(cfg.remat_policy))

    def tick(carry, t):
        x_prev, loss_sum, lb_sum = carry
        # boundary send: the activation computed last tick moves one
        # stage forward while this tick's compute proceeds below
        recv = jax.lax.ppermute(x_prev, pipe.axis, perm)
        j_in = jnp.clip(t, 0, m - 1)
        inj = embed_inputs(
            params, cfg, jax.lax.dynamic_index_in_dim(tok_mb, j_in, 0,
                                                      keepdims=False),
            (jax.lax.dynamic_index_in_dim(fe_mb, j_in, 0, keepdims=False)
             if fe_mb is not None else None), tp)
        x_in = jnp.where(stage == 0, inj, recv)
        x_out, lb = jax.lax.scan(block_body, x_in, params["blocks"])
        # stage s holds real data for microbatch t - s at ticks
        # s <= t < s + m
        valid_here = (t >= stage) & (t < stage + m)
        lb_sum = lb_sum + jnp.where(valid_here, lb.mean(), 0.0)
        # the microbatch leaving the LAST stage this tick entered the
        # pipe p - 1 ticks ago
        j_out = jnp.clip(t - (p - 1), 0, m - 1)
        h = x_out
        seq_ce = (tp is not None and tp.plan.seq_ce and not seq
                  and h.shape[1] % tp.size == 0)
        if seq_ce:
            h = L.ctx_enter(h, tp.axis, tp.size)
        h = L.rms_norm(h, params["ln_f"], cfg.norm_eps)
        if tp is not None and tp.plan.vocab:
            h = (L.tp_seq_gather(h, tp.axis, 1) if (seq or seq_ce)
                 else L.tp_enter(h, tp.axis, _ring(cfg, tp)))
        logits = h @ head
        nll = _ce(cfg, logits,
                  jax.lax.dynamic_index_in_dim(tok_mb, j_out, 0,
                                               keepdims=False),
                  (jax.lax.dynamic_index_in_dim(mask_mb, j_out, 0,
                                                keepdims=False)
                   if mask_mb is not None else None), tp)
        valid_out = (stage == p - 1) & (t >= p - 1) & (t < p - 1 + m)
        loss_sum = loss_sum + jnp.where(valid_out, nll, 0.0)
        return (x_out, loss_sum, lb_sum), None

    x0 = jnp.zeros((mb, S_h, cfg.d_model), jnp.dtype(cfg.dtype))
    (xf, loss_sum, lb_sum), _ = jax.lax.scan(
        tick, (x0, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        jnp.arange(m + p - 1))
    del xf
    # only the last stage accumulated loss; every stage accumulated its
    # own layers' load-balance aux — both assemble with the psum-forward
    # / identity-backward conjugate so every stage gets the SAME 1/m
    # cotangent and prices its own contribution exactly once (a plain
    # psum transposes to psum under the manual region's check_rep=False,
    # which would scale every gradient by the stage count)
    loss = L.tp_pull(loss_sum, pipe.axis) / m
    if cfg.family == "moe":
        loss = loss + 0.01 * L.tp_pull(lb_sum, pipe.axis) / (p * m)
    return loss


# ================================================================= decode
def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               window: Optional[int] = None, dtype=jnp.bfloat16):
    """Per-layer stacked decode caches for serve_step."""
    Lyr = cfg.n_layers
    if cfg.family == "ssm":
        st = init_mlstm_state(cfg, batch)
        return {"mix": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (Lyr, *x.shape)), st)}
    size = min(window, cache_len) if window else cache_len
    kv = {"k": jnp.zeros((Lyr, batch, size, cfg.n_kv_heads, cfg.hd), dtype),
          "v": jnp.zeros((Lyr, batch, size, cfg.n_kv_heads, cfg.hd), dtype)}
    if cfg.family == "hybrid":
        ssm = jnp.zeros((Lyr, batch, cfg.d_model, cfg.ssm_state), jnp.float32)
        return {"kv": kv, "ssm": ssm}
    return {"kv": kv}


def decode_step(params, cfg: ModelConfig, cache, token, pos,
                window: Optional[int] = None):
    """serve_step: one new token per sequence against the cache.

    token: (B, 1) int32; pos: scalar int32 absolute position.
    Returns (logits (B, 1, V), new_cache).
    """
    x = params["embed"][token]
    B = x.shape[0]
    positions = jnp.broadcast_to(pos, (B, 1))

    def body(carry, scanned):
        h = carry
        lp, layer_cache = scanned
        h, new_cache, _ = _block(cfg, lp, h, positions, "decode",
                                 layer_cache, window)
        return h, new_cache

    x, new_caches = jax.lax.scan(body, x, (params["blocks"], cache))
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head, new_caches


# ========================================================== paged decode
# The serving engine's cache is a global pool of fixed-size blocks
# (repro/serve/cache.py); each request owns a block table.  The decode
# step below is the batched per-request-position twin of ``decode_step``:
# every row carries its OWN absolute position (continuous batching mixes
# requests at different depths), K/V write through the block table, and
# attention gathers through it (the Pallas kernel in
# ``kernels/paged_attention`` or its jnp reference).

def paged_families() -> tuple:
    """Families the paged decode path serves (pure-KV caches; the
    recurrent ssm/hybrid states are per-request dense, not pageable)."""
    return ("dense", "moe", "audio", "vlm")


def init_paged_pools(cfg: ModelConfig, num_blocks: int, block_size: int,
                     dtype=jnp.bfloat16) -> dict:
    """Per-layer stacked K/V block pools: (L, N, KV, bs, hd)."""
    if cfg.family not in paged_families():
        raise ValueError(
            f"paged KV cache supports families {paged_families()}, not "
            f"{cfg.family!r} (recurrent state is per-request, not paged)")
    shape = (cfg.n_layers, num_blocks, cfg.n_kv_heads, block_size, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _attn_paged(cfg: ModelConfig, lp, x, positions, k_pool, v_pool,
                block_tables, ctx_lens, window, use_kernel, tp=None):
    """One layer's attention against the paged pools.  x: (B, 1, D);
    positions/ctx_lens: (B, 1)/(B,) — the new token's absolute position.
    Returns (x_out, k_pool, v_pool) with the new K/V scattered in.

    With ``tp`` (inside a manual shard_map serve body) the wq/wk/wv/wo
    shards and the pools' kv-head shard are this position's — the Pallas
    kernel sees local head counts, exactly the train path's contract."""
    from repro.kernels import paged_attention as pa
    tp_attn = tp is not None and tp.plan.attn
    n_heads = cfg.n_heads // (tp.size if tp_attn else 1)
    n_kv = cfg.n_kv_heads // (tp.size if tp_attn else 1)
    B = x.shape[0]
    bs = k_pool.shape[2]
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    q = h @ lp["wq"]
    k = h @ lp["wk"]
    v = h @ lp["wv"]
    if cfg.qkv_bias:
        q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    q = q.reshape(B, 1, n_heads, cfg.hd)
    k = k.reshape(B, 1, n_kv, cfg.hd)
    v = v.reshape(B, 1, n_kv, cfg.hd)
    if cfg.qk_norm:
        q = L.rms_norm(q, lp["q_norm"], cfg.norm_eps)
        k = L.rms_norm(k, lp["k_norm"], cfg.norm_eps)
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    # scatter the new K/V through the block table: logical position
    # ctx_lens[b] lives at (block_tables[b, ctx//bs], ctx%bs)
    pages = block_tables[jnp.arange(B), ctx_lens // bs]
    offs = ctx_lens % bs
    k_pool = k_pool.at[pages, :, offs].set(
        k[:, 0].astype(k_pool.dtype))
    v_pool = v_pool.at[pages, :, offs].set(
        v[:, 0].astype(v_pool.dtype))
    fn = (pa.paged_attention
          if use_kernel and pa.supports(n_heads, n_kv, cfg.hd)
          else pa.paged_attention_ref)
    out = fn(q[:, 0], k_pool, v_pool, block_tables, ctx_lens + 1,
             window=window, interpret=_flash_interpret())
    y = out.reshape(B, 1, n_heads * cfg.hd) @ lp["wo"]
    if tp_attn:
        y = jax.lax.psum(y, tp.axis)        # row-parallel wo partials
    return x + y, k_pool, v_pool


def paged_decode_step(params, cfg: ModelConfig, pools, block_tables,
                      context_lens, tokens,
                      window: Optional[int] = None,
                      use_kernel: bool = True,
                      tp: Optional[TPRuntime] = None):
    """One decode step for a batch of requests at DIFFERENT positions.

    tokens: (B, 1) int32 — each row's newest token
    context_lens: (B,) int32 — tokens already cached per row (the new
        token's absolute position); inactive rows pass 0 with a
        scratch-block table and produce garbage logits that the engine
        masks out
    pools: ``init_paged_pools`` tree; block_tables: (B, P) int32

    With ``tp`` (inside a manual shard_map serve body) params and the
    pools' kv-head dim are this position's shards; logits come back FULL
    (an all_gather over the model axis after the column-parallel unembed)
    so the engine's row-wise sampler is unchanged.

    Returns (logits (B, 1, V), new_pools).
    """
    x = embed_inputs(params, cfg, tokens, None, tp)
    B = x.shape[0]
    positions = jnp.broadcast_to(context_lens[:, None], (B, 1))

    def body(carry, scanned):
        h = carry
        lp, layer_pools = scanned
        h, kp, vp = _attn_paged(cfg, lp, h, positions,
                                layer_pools["k"], layer_pools["v"],
                                block_tables, context_lens, window,
                                use_kernel, tp)
        if cfg.family == "moe":
            hh = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
            y, _ = moe_lib.moe_ffn(hh, lp["router"], lp["w_gate"],
                                   lp["w_up"], lp["w_down"],
                                   top_k=cfg.top_k,
                                   capacity_factor=cfg.capacity_factor,
                                   group=cfg.moe_group_size, tp=tp)
            h = h + y
        else:
            h = _ffn(cfg, lp, h, tp)
        return h, {"k": kp, "v": vp}

    x, new_pools = jax.lax.scan(body, x, (params["blocks"], pools))
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    if tp is not None and tp.plan.vocab:
        logits = jax.lax.all_gather(logits, tp.axis, axis=2, tiled=True)
    return logits, new_pools
