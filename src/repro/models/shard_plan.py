"""Family-generic model-axis shard plans.

One subsystem decides what the ``model`` mesh axis shards for EVERY
architecture family in the config zoo.  Three objects:

* :class:`TPPlan` — the static per-config decision: which *regions*
  (attn / ffn / vocab / moe / mixer) shard, and whether the activations
  between regions are sequence-sharded (``seq``).
* :class:`TPRuntime` — the per-trace context (axis name, size, this
  position's coordinate, plan) threaded through ``transformer.forward``.
* :class:`TPSpec` — the per-parameter-leaf placement, derived from the
  role metadata each ``param_spec`` entry carries (see
  :data:`PARAM_ROLES`), not from architecture-specific code.

Regions by family (each wired through the conjugate collectives in
``models/layers``):

* ``attn``  — Megatron column/row pairing of wq/wk/wv ∘ wo (families
  with attention); requires heads AND kv-heads divisible.
* ``ffn``   — column/row pairing of the gated MLP: w_gate/w_up ∘ w_down
  (dense/audio/vlm/hybrid) or p_up/p_gate ∘ p_down (ssm family's
  in-block projection).
* ``vocab`` — vocab-parallel embedding + column-parallel unembed with
  the CE on vocab-sharded logits.
* ``moe``   — expert parallelism: the expert dimension of
  w_gate/w_up/w_down shards over ``model``; tokens are group-sharded
  inside the region and reach their experts through an explicit
  ``all_to_all`` dispatch/combine (``models/moe.moe_ffn``); the router
  stays replicated with partial-gradient psum.
* ``mixer`` — recurrent mixers run fully local: mLSTM shards heads
  (xq/xk/xv/xo + i/f gates), the hybrid selective SSM shards channels
  (m_dt/m_A/m_D/m_ln/m_out; m_in/m_bc stay replicated with partial
  grads).  State dims are per-head/per-channel, so the chunked scan
  needs zero extra collectives.

``seq`` (sequence parallelism, dense-family opt-in via
``ModelConfig.seq_parallel``) converts each region's psum pair into the
``psum_scatter``/``all_gather`` conjugates: the norm/residual regions
between matmul pairs hold (B, S/tp, D) activations — same collective
bytes on the wire, 1/tp the activation memory.  It requires ``ffn`` and
``vocab`` to shard (the CE path must run on vocab-sharded logits so the
unembed gather has column-parallel consumers); a replicated-attention
fallback region is entered with a gather and exited with this
position's sequence slice, which turns the attention leaves into
``partial``-gradient kind.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax


# ============================================================== TPPlan
@dataclasses.dataclass(frozen=True)
class TPPlan:
    """What the model axis shards for one config (static).

    Field order (size, attn, ffn, vocab) is stable API — callers build
    plans positionally.
    """

    size: int = 1
    attn: bool = False
    ffn: bool = False
    vocab: bool = False
    moe: bool = False        # expert-parallel MoE dispatch/combine
    mixer: bool = False      # head/channel-sharded recurrent mixer
    seq: bool = False        # sequence-sharded inter-region activations
    ctx: int = 1             # ring-attention factor of the model axis
    seq_ce: bool = False     # sequence-scatter the final norm (ssm/hybrid)

    @property
    def active(self) -> bool:
        return self.size > 1 and (self.attn or self.ffn or self.vocab
                                  or self.moe or self.mixer
                                  or self.ctx > 1)


class TPRuntime(NamedTuple):
    """Per-trace TP context threaded through forward/loss_fn.

    ``index`` is this position's model-axis coordinate (a traced scalar —
    ``axis_index`` lowers to an unsupported PartitionId under fully-manual
    SPMD, so the caller feeds it in as a sharded input instead)."""

    axis: str
    size: int
    index: jax.Array
    plan: TPPlan


# ======================================================== plan builders
def _attn_divides(cfg, size: int) -> bool:
    return cfg.n_heads % size == 0 and cfg.n_kv_heads % size == 0


def _ctx_factor(cfg, size: int, attn: bool) -> int:
    """Ring-attention factor: when Megatron head-sharding can't divide
    (odd head counts, GQA kv < tp) the attn region shards the SEQUENCE
    over the whole model axis instead — K/V chunks rotate through a
    ppermute ring with online-softmax accumulation.  Head counts are
    irrelevant to the ring, so any size qualifies; the runtime still
    falls back per-trace when S itself doesn't divide."""
    if attn or size <= 1 or cfg.attn_batch_shard:
        return 1
    return size


def _plan_dense(cfg, size: int) -> TPPlan:
    ffn = cfg.d_ff > 0 and cfg.d_ff % size == 0
    vocab = cfg.vocab % size == 0
    attn = _attn_divides(cfg, size)
    # seq parallelism needs the CE on vocab-sharded logits (so the
    # unembed gather has column-parallel consumers) and a sharded FFN;
    # the VLM frontend concat would break the uniform sequence shards
    seq = (cfg.seq_parallel and ffn and vocab and cfg.frontend == "none")
    return TPPlan(size, attn=attn, ffn=ffn, vocab=vocab, seq=seq,
                  ctx=_ctx_factor(cfg, size, attn))


def _plan_moe(cfg, size: int) -> TPPlan:
    attn = _attn_divides(cfg, size)
    return TPPlan(size, attn=attn,
                  vocab=cfg.vocab % size == 0,
                  moe=cfg.n_experts > 0 and cfg.n_experts % size == 0,
                  ctx=_ctx_factor(cfg, size, attn))


def _plan_ssm(cfg, size: int) -> TPPlan:
    # mixer = mLSTM heads; ffn = the gated in-block projection (2*D wide)
    vocab = cfg.vocab % size == 0
    return TPPlan(size, ffn=(2 * cfg.d_model) % size == 0,
                  vocab=vocab,
                  mixer=cfg.n_heads % size == 0,
                  seq_ce=cfg.seq_parallel and vocab)


def _plan_hybrid(cfg, size: int) -> TPPlan:
    attn = _attn_divides(cfg, size)
    vocab = cfg.vocab % size == 0
    return TPPlan(size, attn=attn,
                  ffn=cfg.d_ff > 0 and cfg.d_ff % size == 0,
                  vocab=vocab,
                  mixer=cfg.d_model % size == 0,
                  ctx=_ctx_factor(cfg, size, attn),
                  seq_ce=cfg.seq_parallel and vocab)


_PLAN_BUILDERS = {"dense": _plan_dense, "audio": _plan_dense,
                  "vlm": _plan_dense, "moe": _plan_moe,
                  "ssm": _plan_ssm, "hybrid": _plan_hybrid}


def build_plan(cfg, size: int) -> TPPlan:
    """The model-axis sharding plan for ``cfg`` at ``size`` shards.
    A family without a registered builder replicates (inactive plan) —
    new families degrade gracefully instead of crashing the runtime."""
    builder = _PLAN_BUILDERS.get(cfg.family)
    if size <= 1 or builder is None:
        return TPPlan(size=max(size, 1))
    return builder(cfg, size)


# `tp_plan` is the historical name (re-exported by models.transformer)
tp_plan = build_plan


# ============================================================== TPSpec
@dataclasses.dataclass(frozen=True)
class TPSpec:
    """Model-axis placement of one parameter leaf (stacked shapes).

    ``kind``:
      * ``col`` / ``row`` — Megatron column/row shard at ``dim``; the
        leaf's gradient is naturally shard-local.
      * ``expert`` — expert-parallel shard of the expert dimension;
        shard-local gradients like col/row (each position only ever
        computes its own experts).
      * ``vocab``   — vocab-parallel embedding rows (col shard of the
        unembed); shard-local gradients like col/row.
      * ``replicate`` — identical on every model position; the gradient
        comes out replicated (full) on each position.
      * ``partial`` — replicated VALUES consumed inside a TP region on
        local shards only (qk-norm scales over local heads, the MoE
        router over local token groups, seq-parallel norm scales over
        local sequence slices): each position's gradient is a partial
        sum, and the train body must ``psum`` it over the model axis
        (see ``dist.sharding.tp_grad_sync``).
    """

    dim: int = -1
    kind: str = "replicate"


_REP = TPSpec()
_PARTIAL = TPSpec(-1, "partial")

# Role metadata for every ``param_spec`` entry: leaf name ->
# (region, dim, kind).  The region names match TPPlan fields; a leaf
# shards iff its region is active in the plan.  Region "seq" marks
# leaves consumed on sequence-sharded activations (norm scales): they
# replicate their VALUES always, but their grads become partial sums
# when the plan sequence-shards.  Names are unique per family (the
# moe/hybrid ``w_gate`` collision is resolved by the family key).
_ATTN_ROLES = {"wq": ("attn", 2, "col"), "wk": ("attn", 2, "col"),
               "wv": ("attn", 2, "col"), "wo": ("attn", 1, "row"),
               "bq": ("attn", 1, "col"), "bk": ("attn", 1, "col"),
               "bv": ("attn", 1, "col"),
               "q_norm": ("attn", -1, "partial"),
               "k_norm": ("attn", -1, "partial")}

_FFN_ROLES = {"w_gate": ("ffn", 2, "col"), "w_up": ("ffn", 2, "col"),
              "w_down": ("ffn", 1, "row")}

PARAM_ROLES = {
    "dense": {**_ATTN_ROLES, **_FFN_ROLES},
    "moe": {**_ATTN_ROLES,
            "router": ("moe", -1, "partial"),
            "w_gate": ("moe", 1, "expert"), "w_up": ("moe", 1, "expert"),
            "w_down": ("moe", 1, "expert")},
    "ssm": {"xq": ("mixer", 2, "col"), "xk": ("mixer", 2, "col"),
            "xv": ("mixer", 2, "col"), "xo": ("mixer", 1, "row"),
            "w_i": ("mixer", 2, "col"), "w_f": ("mixer", 2, "col"),
            "b_i": ("mixer", 1, "col"), "b_f": ("mixer", 1, "col"),
            "p_up": ("ffn", 2, "col"), "p_gate": ("ffn", 2, "col"),
            "p_down": ("ffn", 1, "row")},
    "hybrid": {**_ATTN_ROLES, **_FFN_ROLES,
               "m_dt": ("mixer", 2, "col"), "m_A": ("mixer", 1, "col"),
               "m_D": ("mixer", 1, "col"), "m_ln": ("mixer", 1, "col"),
               "m_out": ("mixer", 1, "row"),
               "m_in": ("mixer", -1, "partial"),
               "m_bc": ("mixer", -1, "partial")},
}
PARAM_ROLES["audio"] = PARAM_ROLES["dense"]
PARAM_ROLES["vlm"] = PARAM_ROLES["dense"]

_NORM_LEAVES = ("ln1", "ln2")        # block norms consumed on seq shards


def _leaf_spec(plan: TPPlan, roles: dict, name: str) -> TPSpec:
    if name in _NORM_LEAVES:
        # block norm scales: replicated values; consumed on (B, S/tp, D)
        # residual shards under a seq plan => partial grads
        return _PARTIAL if plan.seq else _REP
    role = roles.get(name)
    if role is None:
        return _REP
    region, dim, kind = role
    if getattr(plan, region):
        return TPSpec(dim, kind)
    if region == "attn" and (plan.seq or plan.ctx > 1):
        # seq fallback: the region is entered with a gather whose
        # backward psum_scatters, so each position's attention-weight
        # grads cover only its sequence slice's cotangent.  Ring (ctx)
        # attention: weights are replicated but applied to this
        # position's sequence CHUNK only.  Either way: partial sums
        # over the model axis.
        return _PARTIAL
    return _REP


def tp_specs(cfg, size: int) -> Any:
    """Pytree of :class:`TPSpec` matching the parameter tree: every
    entry of ``models/transformer.param_spec`` mapped through its
    :data:`PARAM_ROLES` metadata under the family's plan."""
    from repro.models import transformer as tr
    plan = build_plan(cfg, size)
    roles = PARAM_ROLES.get(cfg.family, {})
    spec = tr.param_spec(cfg)
    out: dict[str, Any] = {}
    for name in spec:
        if name == "blocks":
            out["blocks"] = {bn: _leaf_spec(plan, roles, bn)
                             for bn in spec["blocks"]}
        elif name == "embed":
            out["embed"] = TPSpec(0, "vocab") if plan.vocab else _REP
        elif name == "lm_head":
            out["lm_head"] = TPSpec(1, "col") if plan.vocab else _REP
        elif name == "ln_f" and (plan.seq or plan.seq_ce):
            out["ln_f"] = _PARTIAL          # consumed on sequence shards
        else:                               # ln_f (non-seq), proj_in, ...
            out[name] = _REP
    return out


# ======================================================== PipelinePlan
@dataclasses.dataclass(frozen=True)
class PipelinePlan:
    """What the ``pipe`` mesh axis shards for one config (static).

    Layers partition into ``size`` contiguous stages of
    ``layers_per_stage`` each: stage s owns block-leaf rows
    [s*layers_per_stage, (s+1)*layers_per_stage) of the L-stacked
    parameter dim 0.  Non-block leaves (embed / lm_head / ln_f /
    proj_in / frontend) replicate over ``pipe`` — every stage embeds
    its own microbatch injection and the last stage computes the CE —
    so their grads psum over ``pipe`` (``dist.sharding.pipe_grad_sync``).

    The train body runs the microbatch grid as a single differentiable
    ``lax.scan`` over ``microbatches + size - 1`` ticks: each tick
    ppermutes the activation carry one stage forward while computing
    the next microbatch locally, so stage-boundary sends overlap the
    following microbatch's compute and AD of the scan replays the
    wavefront in reverse — the interleaved 1F1B order enumerated by
    :func:`pipeline_schedule`.
    """

    size: int = 1
    n_layers: int = 0
    microbatches: int = 1

    @property
    def active(self) -> bool:
        return self.size > 1

    @property
    def layers_per_stage(self) -> int:
        return self.n_layers // max(self.size, 1)

    @property
    def bubble_fraction(self) -> float:
        """Idle fraction of the microbatch-grid scan: (p-1)/(m+p-1)."""
        if self.size <= 1:
            return 0.0
        return (self.size - 1) / (self.microbatches + self.size - 1)


class PipeRuntime(NamedTuple):
    """Per-trace pipeline context threaded through the train body.
    ``index`` is this position's pipe-axis coordinate (fed in as a
    sharded input for the same manual-SPMD reason as TPRuntime)."""

    axis: str
    size: int
    index: jax.Array
    plan: PipelinePlan


# Every zoo family L-stacks its block leaves at dim 0, so contiguous
# stage slicing works uniformly; the map exists so a future family with
# non-uniform blocks can opt out without crashing the runtime.
PIPELINE_FAMILIES = ("dense", "audio", "vlm", "moe", "ssm", "hybrid")


def build_pipeline_plan(cfg, size: int, microbatches: int = 1) -> PipelinePlan:
    """The pipe-axis plan for ``cfg`` at ``size`` stages.  Inactive when
    the family is unknown or the layer count doesn't split into equal
    contiguous stages."""
    if (size <= 1 or cfg.family not in PIPELINE_FAMILIES
            or cfg.n_layers % size != 0):
        return PipelinePlan(size=1, n_layers=cfg.n_layers,
                            microbatches=max(microbatches, 1))
    if microbatches < 1:
        raise ValueError(f"microbatches must be >= 1, got {microbatches}")
    return PipelinePlan(size=size, n_layers=cfg.n_layers,
                        microbatches=microbatches)


def pipeline_schedule(size: int, microbatches: int) -> list:
    """The interleaved 1F1B order as an explicit (tick, stage, µb, dir)
    grid — the ground truth the scan's wavefront realizes, used by the
    schedule property test and the roofline's bubble accounting.

    Returns a list of (stage, microbatch, 'F'|'B') in global execution
    order.  Stage s warms up with ``min(size - s - 1, microbatches)``
    forwards, then alternates 1F1B until its microbatches drain, then
    cools down with the remaining backwards.
    """
    p, m = size, microbatches
    order: list = []
    # per-stage next-forward / next-backward microbatch cursors
    nf = [0] * p
    nb = [0] * p
    # earliest tick stage s can run forward µb i: i + s (wavefront);
    # backward µb i on stage s: (m + p - 1) + (p - 1 - s) + i of the
    # reversed wavefront.  Emitting by tick gives a legal global order.
    fwd_tick = {(s, i): i + s for s in range(p) for i in range(m)}
    bwd_tick = {(s, i): (m + p - 1) + (p - 1 - s) + i
                for s in range(p) for i in range(m)}
    events = ([(t, s, i, "F") for (s, i), t in fwd_tick.items()]
              + [(t, s, i, "B") for (s, i), t in bwd_tick.items()])
    for t, s, i, d in sorted(events):
        if d == "F":
            assert nf[s] == i
            nf[s] += 1
        else:
            assert nb[s] == i and nf[s] > i
            nb[s] += 1
        order.append((s, i, d))
    return order
