"""Recurrent sequence mixers: selective SSM (mamba-style, for hymba) and
mLSTM (xLSTM family).

Training/prefill use chunked parallel forms (memory-bounded, scan over
time chunks with rematerialization); decode uses O(1)-per-token recurrent
state.  Both are validated against naive step-recurrence oracles in
tests/test_ssm.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


# ----------------------------------------------------------- selective SSM
def ssm_scan(u, dt, B, C, A_log, D_skip, *, chunk: int = 128,
             scan_f32: bool = True):
    """Chunked selective state-space scan.

    u: (Bt, T, Di) inputs; dt: (Bt, T, Di) positive step sizes;
    B, C: (Bt, T, N) input/output maps; A_log: (Di, N) (A = -exp(A_log));
    D_skip: (Di,).  h_t = exp(dt A) h_{t-1} + dt * B_t * u_t ;
    y_t = C_t . h_t + D u_t.  Returns (y, h_final).
    """
    Bt, T, Di = u.shape
    N = B.shape[-1]
    A = -jnp.exp(A_log.astype(jnp.float32))                      # (Di, N)
    chunk = min(chunk, T)
    n_chunks = -(-T // chunk)
    Tp = n_chunks * chunk
    if Tp != T:
        # pad time with zeros: dt == 0 makes the padded steps identity
        # transitions (a = exp(0·A) = 1, b = 0), so h_final is exact and
        # the padded y rows are simply discarded
        u, dt, B, C = (jnp.pad(a, ((0, 0), (0, Tp - T), (0, 0)))
                       for a in (u, dt, B, C))

    def reshape_c(x):
        return x.reshape(Bt, n_chunks, chunk, *x.shape[2:]).swapaxes(0, 1)

    uc, dtc, Bc, Cc = map(reshape_c, (u, dt, B, C))

    el_dtype = jnp.float32 if scan_f32 else u.dtype

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def body(h, inp):
        ui, dti, Bi, Ci = inp                                     # (Bt,c,...)
        a = jnp.exp(dti.astype(jnp.float32)[..., None] * A)       # (Bt,c,Di,N)
        b = (dti * ui).astype(jnp.float32)[..., None] * \
            Bi.astype(jnp.float32)[..., None, :]                  # (Bt,c,Di,N)
        a = a.astype(el_dtype)
        b = b.astype(el_dtype)

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a2 * a1, a2 * b1 + b2

        a_cum, b_scan = jax.lax.associative_scan(combine, (a, b), axis=1)
        hseq = b_scan.astype(jnp.float32) + \
            a_cum.astype(jnp.float32) * h[:, None]                # (Bt,c,Di,N)
        y = jnp.einsum("bcdn,bcn->bcd", hseq, Ci.astype(jnp.float32))
        y = y + D_skip.astype(jnp.float32) * ui.astype(jnp.float32)
        return hseq[:, -1], y.astype(u.dtype)

    h0 = jnp.zeros((Bt, Di, N), jnp.float32)
    h_final, ys = jax.lax.scan(body, h0, (uc, dtc, Bc, Cc))
    return ys.swapaxes(0, 1).reshape(Bt, Tp, Di)[:, :T], h_final


def ssm_decode_step(h, u, dt, B, C, A_log, D_skip):
    """One recurrent step.  u/dt: (Bt, Di); B/C: (Bt, N); h: (Bt, Di, N)."""
    A = -jnp.exp(A_log.astype(jnp.float32))
    a = jnp.exp(dt.astype(jnp.float32)[..., None] * A)
    h_new = a * h + (dt * u).astype(jnp.float32)[..., None] * \
        B.astype(jnp.float32)[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h_new, C.astype(jnp.float32))
    y = y + D_skip.astype(jnp.float32) * u.astype(jnp.float32)
    return h_new, y.astype(u.dtype)


# ------------------------------------------------------------------- mLSTM
def _mlstm_decay(i_pre, f_pre):
    """Stabilized decay quantities.  i_pre/f_pre: (B, H, T) pre-activations.
    Returns (b, m) with b_s = i_s - F_s (log-space key weight) and
    m_t = F_t + cummax_s<=t(b_s) subsumed: we return F (cumulative log
    forget) and b; weights are exp(b_s - cummax(b)_t) for s <= t."""
    logf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
    F = jnp.cumsum(logf, axis=-1)                       # (B,H,T)
    b = i_pre.astype(jnp.float32) - F
    m = jax.lax.cummax(b, axis=b.ndim - 1)              # running max
    return F, b, m


def mlstm_parallel(q, k, v, i_pre, f_pre, *, chunk: int = 512,
                   scores_f32: bool = True):
    """Quadratic (attention-like) stabilized mLSTM forward.

    q,k,v: (B, T, H, hd); i_pre, f_pre: (B, T, H).
    Causal weights W_ts = exp(b_s - m_t) * (q_t . k_s)/sqrt(hd);
    h_t = sum_s W_ts v_s / max(|sum_s exp(b_s - m_t) q_t.k_s/sqrt(hd)|, 1).
    Query-chunked like attention; O(T^2) compute, O(T*chunk) memory.
    """
    B, T, H, hd = q.shape
    i_t = jnp.swapaxes(i_pre, 1, 2)                     # (B,H,T)
    f_t = jnp.swapaxes(f_pre, 1, 2)
    _, b, m = _mlstm_decay(i_t, f_t)
    scale = hd ** -0.5
    kpos = jnp.arange(T)
    chunk = min(chunk, T)
    n_chunks = -(-T // chunk)
    pad = n_chunks * chunk - T
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else q
    qc = qp.reshape(B, n_chunks, chunk, H, hd).swapaxes(0, 1)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def one_chunk(carry, inp):
        ci, qi = inp                                     # qi: (B,c,H,hd)
        qpos = ci * chunk + jnp.arange(chunk)
        m_q = m[..., jnp.clip(qpos, 0, T - 1)]           # (B,H,c)
        logits = jnp.einsum("bqhd,bshd->bhqs", qi, k).astype(jnp.float32)
        w = logits * scale * jnp.exp(b[:, :, None, :] - m_q[..., None])
        causal = kpos[None, :] <= qpos[:, None]
        w = jnp.where(causal[None, None], w, 0.0)
        den = jnp.abs(w.sum(-1))                         # (B,H,c)
        if not scores_f32:
            # decay weights are stabilized to <= 1, safe in f16; the
            # denominator above is still accumulated in f32
            w = w.astype(v.dtype)
        num = jnp.einsum("bhqs,bshd->bqhd", w,
                         v.astype(w.dtype)).astype(jnp.float32)
        h = num / jnp.maximum(den, 1.0)[..., None].swapaxes(1, 2)
        return carry, h.astype(q.dtype)

    _, outs = jax.lax.scan(one_chunk, (), (jnp.arange(n_chunks), qc))
    out = outs.swapaxes(0, 1).reshape(B, n_chunks * chunk, H, hd)
    return out[:, :T]


def mlstm_decode_step(state, q, k, v, i_pre, f_pre):
    """Recurrent mLSTM step.

    state: dict(C: (B,H,hd,hd), n: (B,H,hd), m: (B,H));
    q,k,v: (B,H,hd); i_pre,f_pre: (B,H).  Matches mlstm_parallel.
    """
    C, n, m = state["C"], state["n"], state["m"]
    hd = q.shape[-1]
    scale = hd ** -0.5
    logf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
    i32 = i_pre.astype(jnp.float32)
    m_new = jnp.maximum(logf + m, i32)
    f_eff = jnp.exp(logf + m - m_new)                    # (B,H)
    i_eff = jnp.exp(i32 - m_new)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    C_new = f_eff[..., None, None] * C + \
        i_eff[..., None, None] * (kf[..., :, None] * vf[..., None, :])
    n_new = f_eff[..., None] * n + i_eff[..., None] * kf
    qf = q.astype(jnp.float32) * scale
    num = jnp.einsum("bhd,bhde->bhe", qf, C_new)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n_new))
    h = num / jnp.maximum(den, 1.0)[..., None]
    return {"C": C_new, "n": n_new, "m": m_new}, h.astype(q.dtype)
