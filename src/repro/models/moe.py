"""Mixture-of-Experts FFN with grouped capacity-based dispatch.

TPU-native formulation (Switch/MaxText style): tokens are reshaped into
groups of ``group`` tokens; within each group the router's top-k choices
are turned into a one-hot dispatch tensor (group, E, capacity) so the
expert computation is three dense einsums with the expert dimension
shardable over the 'model' mesh axis.  Tokens beyond an expert's capacity
are dropped (standard capacity-factor semantics)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def moe_ffn(x, router_w, w_gate, w_up, w_down, *, top_k: int,
            capacity_factor: float = 1.25, group: int = 256,
            expert_shard_acts: bool = False):
    """x: (B, S, D); router_w: (D, E); w_gate/w_up: (E, D, F);
    w_down: (E, F, D).  Returns (B, S, D) plus aux losses dict."""
    B, S, D = x.shape
    E = router_w.shape[-1]
    T = B * S
    xt = x.reshape(T, D)
    group = min(group, T)
    n_groups = T // group
    assert n_groups * group == T, (T, group)
    xg = xt.reshape(n_groups, group, D)

    logits = jnp.einsum("gtd,de->gte", xg, router_w).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gate_vals, idx = jax.lax.top_k(probs, top_k)          # (g, t, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    cap = max(1, int(capacity_factor * top_k * group / E))
    # position of each (token, choice) within its expert's queue
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)      # (g, t, k, e)
    flat = onehot.reshape(n_groups, group * top_k, E)
    pos = jnp.cumsum(flat, axis=1) - flat                 # (g, t*k, e)
    pos = pos.reshape(n_groups, group, top_k, E)
    within_cap = pos < cap
    dispatch = (onehot * within_cap).astype(x.dtype)      # (g,t,k,e) 0/1
    pos_oh = jax.nn.one_hot(jnp.clip(pos, 0, cap - 1), cap, dtype=x.dtype)
    # (g, t, e, c): token t of group g goes to slot c of expert e
    disp = jnp.einsum("gtke,gtkec->gtec", dispatch.astype(x.dtype), pos_oh)
    comb = jnp.einsum("gtke,gtk,gtkec->gtec",
                      dispatch.astype(jnp.float32),
                      gate_vals, pos_oh.astype(jnp.float32)).astype(x.dtype)

    xe = jnp.einsum("gtec,gtd->gecd", disp, xg)           # (g, E, cap, D)
    if expert_shard_acts:
        # keep dispatched tokens sharded by EXPERT over 'model' so each
        # expert's FFN runs where its weights live (the collective becomes
        # an all-to-all of tokens instead of an all-gather of weights)
        from jax.sharding import PartitionSpec as _P
        espec = _P(None, "model")
        xe = jax.lax.with_sharding_constraint(xe, espec)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, w_gate)) * \
        jnp.einsum("gecd,edf->gecf", xe, w_up)
    ye = jnp.einsum("gecf,efd->gecd", h, w_down)          # (g, E, cap, D)
    if expert_shard_acts:
        ye = jax.lax.with_sharding_constraint(ye, espec)
    y = jnp.einsum("gtec,gecd->gtd", comb, ye)

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    density = onehot.astype(jnp.float32).sum(2).mean(1)   # (g, e) token frac
    p_mean = probs.mean(1)
    aux = {"load_balance": (E * (density * p_mean).sum(-1)).mean(),
           "dropped_frac": 1.0 - (dispatch.sum((2, 3)) > 0)
                                 .astype(jnp.float32).mean()}
    return y.reshape(B, S, D), aux
