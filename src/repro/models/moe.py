"""Mixture-of-Experts FFN with grouped capacity-based dispatch.

TPU-native formulation (Switch/MaxText style): tokens are reshaped into
groups of ``group`` tokens; within each group the router's top-k choices
are turned into a one-hot dispatch tensor (group, E, capacity) so the
expert computation is three dense einsums.  Tokens beyond an expert's
capacity are dropped (standard capacity-factor semantics); token counts
that don't divide the group size are padded with masked tokens that
never claim capacity and never combine output.

Under an expert-parallel plan (``tp.plan.moe``) the expert dimension of
w_gate/w_up/w_down is sharded over the ``model`` axis and tokens reach
their experts through an explicit ``all_to_all`` dispatch/combine:
token groups are sharded over the axis inside the region (entered with
``tp_push``, exited with a zero-padded ``tp_pull``), each position
routes its own groups with the replicated router (partial-grad psum,
see ``models/shard_plan``), and the dispatched (group, E, cap, D)
slots cross the axis so every expert computes where its weights live.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def route_tokens(xg, router_w, valid, *, top_k: int,
                 capacity_factor: float, total_valid: Optional[float] = None):
    """Group-local routing: top-k gates -> capacity-limited dispatch.

    xg: (g, t, D) grouped tokens; router_w: (D, E); valid: (g, t) bool —
    False rows (padding) never claim a capacity slot and never combine
    output.  ``total_valid`` is the number of real tokens ACROSS ALL
    groups (defaults to this call's valid count; the expert-parallel
    caller passes the global count so per-position aux terms sum to the
    replicated value).

    Returns ``(disp, comb, aux)``: ``disp`` (g, t, E, c) 0/1 dispatch,
    ``comb`` (g, t, E, c) combine weights (per-token sum over (E, c)
    <= 1, exactly 0 for dropped/invalid tokens), and aux loss terms
    computed over valid tokens only, each group weighted by its share of
    ``total_valid``.
    """
    n_groups, group, _ = xg.shape
    E = router_w.shape[-1]
    logits = jnp.einsum("gtd,de->gte", xg, router_w).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gate_vals, idx = jax.lax.top_k(probs, top_k)          # (g, t, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)
    vmask = valid.astype(jnp.float32)                     # (g, t)
    gate_vals = gate_vals * vmask[..., None]

    cap = max(1, int(capacity_factor * top_k * group / E))
    # position of each (token, choice) within its expert's queue;
    # invalid tokens carry a zero one-hot so they consume no capacity
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32) * \
        valid[..., None, None].astype(jnp.int32)          # (g, t, k, e)
    flat = onehot.reshape(n_groups, group * top_k, E)
    pos = jnp.cumsum(flat, axis=1) - flat                 # (g, t*k, e)
    pos = pos.reshape(n_groups, group, top_k, E)
    within_cap = pos < cap
    dispatch = onehot * within_cap                        # (g,t,k,e) 0/1
    pos_oh = jax.nn.one_hot(jnp.clip(pos, 0, cap - 1), cap, dtype=xg.dtype)
    # (g, t, e, c): token t of group g goes to slot c of expert e
    disp = jnp.einsum("gtke,gtkec->gtec", dispatch.astype(xg.dtype), pos_oh)
    comb = jnp.einsum("gtke,gtk,gtkec->gtec",
                      dispatch.astype(jnp.float32),
                      gate_vals, pos_oh.astype(jnp.float32)).astype(xg.dtype)

    # load-balance aux loss (Switch): E * sum_e f_e * p_e over VALID
    # tokens, each group weighted by its valid-token share so padded
    # groups contribute nothing and the masked value equals the unpadded
    gcount = jnp.maximum(vmask.sum(1), 1.0)               # (g,)
    density = onehot.astype(jnp.float32).sum(2).sum(1) / gcount[:, None]
    p_mean = (probs * vmask[..., None]).sum(1) / gcount[:, None]
    total = jnp.maximum(
        vmask.sum() if total_valid is None else total_valid, 1.0)
    w_g = vmask.sum(1) / total
    routed = (dispatch.sum((2, 3)) > 0).astype(jnp.float32) * vmask
    aux = {"load_balance": (w_g * (E * (density * p_mean).sum(-1))).sum(),
           "dropped_frac": (vmask.sum() - routed.sum()) / total}
    return disp, comb, aux


def _expert_ffn(xe, w_gate, w_up, w_down):
    """The three dense expert einsums on dispatched slots (g, E, c, D)."""
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, w_gate)) * \
        jnp.einsum("gecd,edf->gecf", xe, w_up)
    return jnp.einsum("gecf,efd->gecd", h, w_down)


def moe_ffn(x, router_w, w_gate, w_up, w_down, *, top_k: int,
            capacity_factor: float = 1.25, group: int = 256, tp=None):
    """x: (B, S, D); router_w: (D, E) — always the FULL expert count;
    w_gate/w_up: (E, D, F); w_down: (E, F, D) — the LOCAL expert shard
    (E/tp, ...) under an expert-parallel ``tp`` plan.  Returns (B, S, D)
    plus aux losses dict."""
    B, S, D = x.shape
    E = router_w.shape[-1]
    ep = tp is not None and tp.plan.moe
    tp_size = tp.size if ep else 1
    T = B * S
    group = min(group, T)
    # pad the token count to a multiple of group (x tp under expert
    # parallelism, so the group axis splits evenly); padded tokens are
    # masked out of dispatch, capacity, aux, and output
    tile = group * tp_size
    Tp = -(-T // tile) * tile
    xt = x.reshape(T, D)
    if Tp != T:
        xt = jnp.pad(xt, ((0, Tp - T), (0, 0)))
    n_groups = Tp // group
    xg = xt.reshape(n_groups, group, D)
    valid = (jnp.arange(Tp) < T).reshape(n_groups, group)

    if ep:
        from repro.models import layers as L
        gl = n_groups // tp_size
        xg = L.tp_push(xg, tp.axis)
        start = tp.index * gl
        xg = jax.lax.dynamic_slice_in_dim(xg, start, gl, axis=0)
        v_loc = jax.lax.dynamic_slice_in_dim(
            valid.astype(jnp.int32), start, gl, axis=0).astype(bool)
        disp, comb, aux = route_tokens(
            xg, router_w, v_loc, top_k=top_k,
            capacity_factor=capacity_factor, total_valid=float(T))
        xe = jnp.einsum("gtec,gtd->gecd", disp, xg)       # (gl, E, cap, D)
        # token dispatch: this position's slots for expert e travel to
        # e's owner; combine is the conjugate all_to_all
        xe = jax.lax.all_to_all(xe, tp.axis, split_axis=1, concat_axis=0,
                                tiled=True)               # (gl*tp, E/tp,..)
        ye = _expert_ffn(xe, w_gate, w_up, w_down)
        ye = jax.lax.all_to_all(ye, tp.axis, split_axis=0, concat_axis=1,
                                tiled=True)               # (gl, E, cap, D)
        y_loc = jnp.einsum("gtec,gecd->gtd", comb, ye)
        y = jnp.zeros((n_groups, group, D), y_loc.dtype)
        y = jax.lax.dynamic_update_slice_in_dim(y, y_loc, start, axis=0)
        y = L.tp_pull(y, tp.axis)
        # per-position aux terms are partial sums (group-weighted by the
        # GLOBAL token count) — one psum each assembles the full value
        aux = {k: L.tp_pull(v, tp.axis) for k, v in aux.items()}
    else:
        disp, comb, aux = route_tokens(xg, router_w, valid, top_k=top_k,
                                       capacity_factor=capacity_factor)
        xe = jnp.einsum("gtec,gtd->gecd", disp, xg)       # (g, E, cap, D)
        ye = _expert_ffn(xe, w_gate, w_up, w_down)
        y = jnp.einsum("gtec,gecd->gtd", comb, ye)

    return y.reshape(Tp, D)[:T].reshape(B, S, D), aux
