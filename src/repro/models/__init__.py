from repro.models.config import ModelConfig  # noqa: F401
from repro.models import transformer  # noqa: F401
