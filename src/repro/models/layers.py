"""Building blocks: RMSNorm, RoPE, chunked-causal GQA attention."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp


# ------------------------------------------------ tensor-parallel region
# Megatron's f/g conjugate pair as custom-vjp collectives.  A TP region is
#     y = tp_pull(partial(tp_push(x) @ W_col) @ W_row)
# tp_push marks the region entry: the forward is free (x is already
# replicated over the model axis) but each shard's backward contributes
# only ITS columns' share of dL/dx, so the cotangent is psum'd.  tp_pull
# marks the exit: the row-parallel partial products are psum'd forward,
# and the (replicated) cotangent passes through untouched.  Exactly two
# collectives per matmul pair, forward and backward — the naive psum
# transpose rule would instead compound a factor of tp per region.
@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def tp_push(x, axis):
    """Enter a TP region: identity forward, psum(cotangent) backward."""
    return x


def _tp_push_fwd(x, axis):
    return x, None


def _tp_push_bwd(axis, _, ct):
    return (jax.lax.psum(ct, axis),)


tp_push.defvjp(_tp_push_fwd, _tp_push_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def tp_pull(x, axis):
    """Exit a TP region: psum(partials) forward, identity backward."""
    return jax.lax.psum(x, axis)


def _tp_pull_fwd(x, axis):
    return jax.lax.psum(x, axis), None


def _tp_pull_bwd(axis, _, ct):
    return (ct,)


tp_pull.defvjp(_tp_pull_fwd, _tp_pull_bwd)


# --------------------------------------------- sequence-parallel region
# The psum_scatter/all_gather conjugates of the psum pair above.  Under a
# sequence-parallel plan the activations BETWEEN TP regions are sharded
# along the sequence dim: a region is entered by gathering the full
# sequence (tp_seq_gather: all-gather fwd, reduce-scatter bwd — each
# shard's cotangent is a partial sum over its columns/slice) and exited
# by reduce-scattering the row-parallel partials (tp_seq_scatter:
# psum_scatter fwd, all-gather bwd).  all_reduce == all_gather ∘
# reduce_scatter, so the wire bytes equal the psum pair's — but the
# norm/residual regions in between hold 1/tp of the activations.
@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def tp_seq_gather(x, axis, dim):
    """Enter a TP region from sequence shards: all-gather forward,
    psum_scatter(cotangent) backward."""
    return jax.lax.all_gather(x, axis, axis=dim, tiled=True)


def _tp_seq_gather_fwd(x, axis, dim):
    return jax.lax.all_gather(x, axis, axis=dim, tiled=True), None


def _tp_seq_gather_bwd(axis, dim, _, ct):
    return (jax.lax.psum_scatter(ct, axis, scatter_dimension=dim,
                                 tiled=True),)


tp_seq_gather.defvjp(_tp_seq_gather_fwd, _tp_seq_gather_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def tp_seq_scatter(x, axis, dim):
    """Exit a TP region to sequence shards: psum_scatter(partials)
    forward, all-gather(cotangent) backward."""
    return jax.lax.psum_scatter(x, axis, scatter_dimension=dim, tiled=True)


def _tp_seq_scatter_fwd(x, axis, dim):
    return (jax.lax.psum_scatter(x, axis, scatter_dimension=dim,
                                 tiled=True), None)


def _tp_seq_scatter_bwd(axis, dim, _, ct):
    return (jax.lax.all_gather(ct, axis, axis=dim, tiled=True),)


tp_seq_scatter.defvjp(_tp_seq_scatter_fwd, _tp_seq_scatter_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def tp_psum(x, axis):
    """psum forward AND backward — for reduction statistics whose output
    is consumed on every shard (e.g. the channel-sharded RMS-norm
    variance): every position's cotangent contributes to every
    position's operand, so the backward must itself sum over the axis.
    (Under the manual region's check_rep=False a plain ``jax.lax.psum``
    happens to transpose to psum as well, but spelling the pair out
    keeps the semantics independent of that implementation detail —
    see ``tp_pull`` for the identity-backward exit.)"""
    return jax.lax.psum(x, axis)


def _tp_psum_fwd(x, axis):
    return jax.lax.psum(x, axis), None


def _tp_psum_bwd(axis, _, ct):
    return (jax.lax.psum(ct, axis),)


tp_psum.defvjp(_tp_psum_fwd, _tp_psum_bwd)


# ------------------------------------- overlapped (ring) model collectives
# An all-reduce decomposed into 2(n-1) ppermute steps (reduce-scatter ring
# then all-gather ring), double-buffered: the payload is split into TWO
# interleaved chunk rings whose sends are issued back-to-back each step,
# so one ring's DMA overlaps the other ring's add — and, unlike the
# monolithic all-reduce, every step is an independent async send the
# scheduler can overlap with neighbouring matmuls.  Total wire bytes are
# identical to the all-reduce (2(n-1)/n of the payload per link);
# `benchmarks/roofline.py` credits collective-permute bytes as
# overlappable when scoring `terms_s`.
#
# Works inside the fully-manual shard_map train body: the device's ring
# position is recovered without `axis_index` (unsupported there on this
# jax pin) from a one-f32-per-device psum_scatter of an iota.
def _ring_index(axis, n):
    iot = jnp.arange(n, dtype=jnp.float32)
    return (jax.lax.psum_scatter(iot, axis, scatter_dimension=0,
                                 tiled=True) / n)[0].astype(jnp.int32)


def ring_all_reduce(x, axis, n: int, *, buffers: int = 2):
    """psum(x, axis) computed as double-buffered ppermute chunk rings.
    ``n`` is the static size of the mesh axis."""
    if n == 1:
        return x
    sh, dt = x.shape, x.dtype
    flat = x.reshape(-1)
    m = flat.shape[0]
    nchunks = n * buffers
    pad = (-m) % nchunks
    if pad:
        flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(nchunks, -1)
    idx = _ring_index(axis, n)
    perm = [(i, (i + 1) % n) for i in range(n)]

    def local(r, j):
        # ring r owns the contiguous row block [r*n, (r+1)*n)
        return jax.lax.dynamic_index_in_dim(chunks, r * n + j % n, 0,
                                            keepdims=False)

    # reduce-scatter phase: after n-1 steps device i holds the fully
    # reduced chunk i of every ring
    accs = [local(r, idx + n - 1) for r in range(buffers)]
    for step in range(n - 1):
        accs = [jax.lax.ppermute(a, axis, perm) for a in accs]
        accs = [a + local(r, idx + n - 2 - step)
                for r, a in enumerate(accs)]
    # all-gather phase: circulate the reduced chunks back around
    out = jnp.zeros_like(chunks)
    for r in range(buffers):
        out = jax.lax.dynamic_update_index_in_dim(out, accs[r],
                                                  r * n + idx, 0)
    bufs = accs
    for step in range(1, n):
        bufs = [jax.lax.ppermute(b, axis, perm) for b in bufs]
        for r in range(buffers):
            out = jax.lax.dynamic_update_index_in_dim(
                out, bufs[r], r * n + (idx - step) % n, 0)
    flat_out = out.reshape(-1)
    if pad:
        flat_out = flat_out[:m]
    return flat_out.reshape(sh).astype(dt)


# Ring-decomposed conjugates of the tp_push/tp_pull/tp_psum trio above —
# same contract, but every model-axis sum is the overlappable ring.  Kept
# as separate custom-vjp functions (``ring`` = static axis size) so the
# default psum pair stays byte-identical for existing configs.
@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def tp_push_ring(x, axis, ring):
    return x


def _tp_push_ring_fwd(x, axis, ring):
    return x, None


def _tp_push_ring_bwd(axis, ring, _, ct):
    return (ring_all_reduce(ct, axis, ring),)


tp_push_ring.defvjp(_tp_push_ring_fwd, _tp_push_ring_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def tp_pull_ring(x, axis, ring):
    return ring_all_reduce(x, axis, ring)


def _tp_pull_ring_fwd(x, axis, ring):
    return ring_all_reduce(x, axis, ring), None


def _tp_pull_ring_bwd(axis, ring, _, ct):
    return (ct,)


tp_pull_ring.defvjp(_tp_pull_ring_fwd, _tp_pull_ring_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def tp_psum_ring(x, axis, ring):
    return ring_all_reduce(x, axis, ring)


def _tp_psum_ring_fwd(x, axis, ring):
    return ring_all_reduce(x, axis, ring), None


def _tp_psum_ring_bwd(axis, ring, _, ct):
    return (ring_all_reduce(ct, axis, ring),)


tp_psum_ring.defvjp(_tp_psum_ring_fwd, _tp_psum_ring_bwd)


def tp_enter(x, axis, ring: int = 0):
    """tp_push, or its ring-overlapped variant when ``ring`` (the static
    model-axis size) is nonzero."""
    return tp_push_ring(x, axis, ring) if ring else tp_push(x, axis)


def tp_exit(x, axis, ring: int = 0):
    """tp_pull, or its ring-overlapped variant."""
    return tp_pull_ring(x, axis, ring) if ring else tp_pull(x, axis)


# ------------------------------------------- context-parallel (ring) region
# When Megatron head-sharding can't divide (odd heads, GQA kv < tp) the
# attention region shards the SEQUENCE over the model axis instead.  The
# region is entered by slicing this position's S/n chunk off the
# replicated activations and exited by gathering the chunks back; inside,
# K/V chunks rotate through a ppermute ring with online-softmax
# accumulation (the block recurrence of ``kernels/flash_attention``, one
# ring hop per block row).  The enter/exit conjugates are NOT
# tp_seq_gather/tp_seq_scatter: those assume partial-sum cotangents,
# whereas here the surrounding activations are replicated with
# replicated-complete cotangents — enter's backward ASSEMBLES the
# disjoint chunk cotangents (all-gather, no reduction) and exit's
# backward takes this position's slice of the replicated cotangent.
@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def ctx_enter(x, axis, n):
    """Enter a ring region: slice my sequence chunk forward, assemble
    the chunk cotangents (all-gather) backward.  x: (B, S, ...)."""
    c = x.shape[1] // n
    idx = _ring_index(axis, n)
    return jax.lax.dynamic_slice_in_dim(x, idx * c, c, 1)


def _ctx_enter_fwd(x, axis, n):
    c = x.shape[1] // n
    idx = _ring_index(axis, n)
    return jax.lax.dynamic_slice_in_dim(x, idx * c, c, 1), None


def _ctx_enter_bwd(axis, n, _, ct):
    return (jax.lax.all_gather(ct, axis, axis=1, tiled=True),)


ctx_enter.defvjp(_ctx_enter_fwd, _ctx_enter_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def ctx_exit(y, axis, n):
    """Exit a ring region: gather the chunks forward, slice my chunk of
    the (replicated) cotangent backward.  y: (B, S/n, ...)."""
    return jax.lax.all_gather(y, axis, axis=1, tiled=True)


def _ctx_exit_fwd(y, axis, n):
    return jax.lax.all_gather(y, axis, axis=1, tiled=True), None


def _ctx_exit_bwd(axis, n, _, ct):
    c = ct.shape[1] // n
    idx = _ring_index(axis, n)
    return (jax.lax.dynamic_slice_in_dim(ct, idx * c, c, 1),)


ctx_exit.defvjp(_ctx_exit_fwd, _ctx_exit_bwd)


def ring_attention(q, k, v, axis, n, *, window: Optional[int] = None):
    """Causal GQA attention over sequence chunks ring-rotated on ``axis``.

    q: (B, C, H, hd) — this position's query chunk (C = S/n, global
    offset ``ring_index * C``); k/v: (B, C, KV, hd) — this position's
    key/value chunk.  Each of the n-1 ring steps ppermutes the held K/V
    chunk one position forward and folds it into the flash-attention
    online-softmax recurrence (m/l/acc rescaling exactly as in
    ``kernels/flash_attention._fwd_kernel``, with one ring hop playing
    the role of one K-block iteration).  Plain differentiable jnp: AD of
    the unrolled ring transposes each ppermute back around the ring, so
    the backward needs no hand-written collectives.
    """
    B, C, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    idx = _ring_index(axis, n)
    qg = q.reshape(B, C, KV, G, hd)
    scale = hd ** -0.5
    qpos = idx * C + jnp.arange(C)
    perm = [(i, (i + 1) % n) for i in range(n)]

    m = jnp.full((B, KV, G, C), -1e30, jnp.float32)
    l = jnp.zeros((B, KV, G, C), jnp.float32)
    acc = jnp.zeros((B, KV, G, C, hd), jnp.float32)
    kh, vh = k, v
    for t in range(n):
        cidx = (idx - t) % n              # chunk held after t hops
        kpos = cidx * C + jnp.arange(C)
        s = jnp.einsum("bqkgh,bskh->bkgqs", qg, kh).astype(jnp.float32)
        s = s * scale
        mask = kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_cur = jnp.maximum(m, s.max(-1))
        alpha = jnp.exp(m - m_cur)
        p = jnp.exp(s - m_cur[..., None])
        l = l * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskh->bkgqh", p, vh.astype(jnp.float32))
        m = m_cur
        if t + 1 < n:
            kh = jax.lax.ppermute(kh, axis, perm)
            vh = jax.lax.ppermute(vh, axis, perm)
    out = acc / l[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, C, H, hd).astype(q.dtype)


def rms_norm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), -1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * scale


def rms_norm_sharded(x, scale, eps, axis, full_dim: int):
    """RMS norm whose normalized dim is sharded over ``axis``: the mean
    of squares is assembled with a (both-ways) psum over the model axis
    — the mixer's only cross-shard dependence, one scalar field per
    (batch, time) position."""
    ss = jnp.sum(jnp.square(x.astype(jnp.float32)), -1, keepdims=True)
    var = tp_psum(ss, axis) / full_dim
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * scale


def rope(x, positions, theta=10000.0):
    """Rotary embedding.  x: (..., S, H, hd), positions: (..., S)."""
    hd = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, hd, 2, dtype=jnp.float32) / hd)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                         # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., : hd // 2], x[..., hd // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def _attend_block(q, k, v, qpos, kpos, window, scores_f32=True):
    """q: (B, Cq, KV, G, hd); k/v: (B, Skv, KV, hd); returns (B,Cq,KV,G,hd).
    Causal + optional sliding-window masking by absolute positions.
    ``scores_f32=False`` keeps the (chunk x S) score tensor in the compute
    dtype — halves the dominant HBM traffic of materialized attention
    (softmax max-subtraction keeps f16 stable); the Pallas flash kernel
    removes the materialization entirely on real TPUs."""
    scale = q.shape[-1] ** -0.5
    sdt = jnp.float32 if scores_f32 else q.dtype
    neg = jnp.asarray(-1e30 if scores_f32 else -6e4, sdt)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k).astype(sdt) * \
        jnp.asarray(scale, sdt)
    mask = kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    scores = jnp.where(mask[None, None, None], scores, neg)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgqs,bskh->bqkgh", w, v)


def causal_attention(q, k, v, *, q_offset=0, window: Optional[int] = None,
                     chunk: int = 512, scores_f32: bool = True):
    """Query-chunked causal GQA attention (memory-efficient; each chunk is
    rematerialized in the backward pass).

    q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd); H = KV * G.
    Query i has absolute position q_offset + i; key j has position j.
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    kpos = jnp.arange(k.shape[1])

    if Sq <= chunk:
        qpos = q_offset + jnp.arange(Sq)
        out = _attend_block(qg, k, v, qpos, kpos, window, scores_f32)
        return out.reshape(B, Sq, H, hd)

    n_chunks = -(-Sq // chunk)
    pad = n_chunks * chunk - Sq
    if pad:
        qg = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    qc = qg.reshape(B, n_chunks, chunk, KV, G, hd).swapaxes(0, 1)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def one_chunk(carry, inp):
        ci, qi = inp
        qpos = q_offset + ci * chunk + jnp.arange(chunk)
        return carry, _attend_block(qi, k, v, qpos, kpos, window,
                                    scores_f32)

    _, outs = jax.lax.scan(one_chunk, (),
                           (jnp.arange(n_chunks), qc))
    out = outs.swapaxes(0, 1).reshape(B, n_chunks * chunk, KV, G, hd)
    return out[:, :Sq].reshape(B, Sq, H, hd)


def decode_attention(q, k_cache, v_cache, pos, *, window: Optional[int] = None):
    """Single-token attention against a (possibly ring-buffered) KV cache.

    q: (B, 1, H, hd); k_cache/v_cache: (B, S, KV, hd); pos: scalar int —
    the absolute position of the new token.  With a sliding window the
    cache is a ring buffer of size S=window holding absolute slots
    j mod window; validity is pos-window < j <= pos.
    """
    B, S, KV, hd = k_cache.shape
    H = q.shape[2]
    G = H // KV
    qg = q.reshape(B, 1, KV, G, hd)
    scale = hd ** -0.5
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k_cache)
    scores = scores.astype(jnp.float32) * scale
    slot = jnp.arange(S)
    if window is None:
        valid = slot <= pos
    else:
        # ring buffer: slot s holds the largest absolute position p <= pos
        # with p % S == s; valid iff that position has been written
        abs_pos = pos - (pos - slot) % S
        valid = abs_pos >= 0
    scores = jnp.where(valid[None, None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, -1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v_cache)
    return out.reshape(B, 1, H, hd)
