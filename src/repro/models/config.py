"""Model configuration system.

One ``ModelConfig`` describes any architecture in the assigned pool
(dense / moe / hybrid / ssm / audio / vlm).  Configs are registered by id
in ``repro.configs`` and selectable via ``--arch <id>`` in the launchers.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    # attention options
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None   # decode-time window (long_500k)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 256      # tokens per dispatch group
    # SSM / hybrid
    ssm_state: int = 0             # mamba N (hymba) / used as chunk hint
    # frontend stubs ([audio]/[vlm] carve-out)
    frontend: str = "none"         # none | vlm
    n_frontend_tokens: int = 0     # e.g. 256 ViT patches
    d_frontend: int = 0            # frontend embedding width
    # performance knobs (§Perf hillclimbing; defaults = paper-faithful
    # baseline, flips recorded in EXPERIMENTS.md)
    tp_head_aligned: bool = False   # shard attn projections only on whole
                                    # heads (replicate if heads % tp != 0)
    megatron_ffn: bool = False      # column-parallel w_gate/w_up +
                                    # row-parallel w_down
    loss_fp32_logits: bool = True   # False: CE with f16 logits + f32 accum
    ssm_scan_f32: bool = True       # False: associative-scan elems in f16
    attn_scores_f32: bool = True    # False: keep score chunks in f16
    seq_parallel: bool = False      # sequence-parallel activations between
                                    # TP regions (psum_scatter/all_gather
                                    # conjugates; needs ffn+vocab to shard)
    attn_batch_shard: bool = False  # context-parallel attention: shard the
                                    # (local) batch over 'model' instead of
                                    # splitting heads (for heads % tp != 0)
    flash_attention: bool = True    # blocked online-softmax train/prefill
                                    # attention (custom-VJP Pallas kernel;
                                    # falls back to chunked when the shape
                                    # doesn't tile — ``supports()``)
    remat_policy: str = "full"      # full | none | dots | dots_batch |
                                    # offload_dots — what jax.checkpoint
                                    # saves across the layer-scan body
    bf16_residency: bool = False    # keep scores/logits resident in the
                                    # compute dtype; f32 only inside matmul
                                    # accumulation epilogues
    overlap_collectives: bool = True  # decompose model-axis psums into
                                    # double-buffered ppermute chunk rings
                                    # (overlappable with compute)
    dense_embed_grad: bool = True   # one-hot matmul backward for the
                                    # embedding table (no serial
                                    # scatter-add loop on CPU/XLA)
    # numerics / structure
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    attn_chunk: int = 512          # query-chunked attention block
    scan_chunk: int = 128          # ssm/linear-attn time chunk
    # citation for the config (source paper / model card)
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.hd

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.hd

    # ------------------------------------------------------------- params
    def param_count(self) -> int:
        """Total parameter count (all experts)."""
        return _count(self, active_only=False)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k experts only) — the N in
        MODEL_FLOPS = 6·N_active·D."""
        return _count(self, active_only=True)

    # -------------------------------------------------------------- smoke
    def smoke(self) -> "ModelConfig":
        """Reduced same-family variant for CPU smoke tests
        (<=2 layers, d_model<=512, <=4 experts)."""
        d = 256
        heads = 4
        kv = max(1, min(self.n_kv_heads, 2))
        return dataclasses.replace(
            self, name=self.name + "-smoke", n_layers=2, d_model=d,
            n_heads=heads, n_kv_heads=kv, head_dim=d // heads,
            d_ff=(2 * d if self.d_ff else 0), vocab=512,
            n_experts=(4 if self.n_experts else 0),
            top_k=(min(2, self.top_k) if self.top_k else 0),
            moe_group_size=32,
            n_frontend_tokens=(8 if self.n_frontend_tokens else 0),
            d_frontend=(64 if self.d_frontend else 0),
            attn_chunk=32, scan_chunk=16, dtype="float32")


def _count(cfg: ModelConfig, active_only: bool) -> int:
    D, L, V = cfg.d_model, cfg.n_layers, cfg.vocab
    n = V * D                      # embed
    if not cfg.tie_embeddings:
        n += D * V                 # lm_head
    n += D                         # final norm
    if cfg.frontend == "vlm":
        n += cfg.d_frontend * D
    per_layer = 2 * D              # two norms
    if cfg.family != "ssm":
        per_layer += D * cfg.q_dim + 2 * D * cfg.kv_dim + cfg.q_dim * D
        if cfg.qkv_bias:
            per_layer += cfg.q_dim + 2 * cfg.kv_dim
        if cfg.qk_norm:
            per_layer += 2 * cfg.hd
    if cfg.family == "moe":
        e = cfg.top_k if active_only else cfg.n_experts
        per_layer += D * cfg.n_experts            # router
        per_layer += e * 3 * D * cfg.d_ff
    elif cfg.family == "ssm":
        # mLSTM mixer + gated projection block
        per_layer += 3 * D * cfg.q_dim + cfg.q_dim * D   # q,k,v,o
        per_layer += 2 * D * cfg.n_heads                 # i,f gates
        per_layer += 2 * D * 2 * D + 2 * D * D           # gated proj (up2x, gate, down)
    elif cfg.family == "hybrid":
        Di = D
        per_layer += D * 2 * Di + Di * D                 # mamba in/out
        per_layer += Di * (1 + 2 * cfg.ssm_state)        # dt, B, C proj (per ch)
        per_layer += Di * cfg.ssm_state + Di             # A, skip D
        per_layer += 3 * D * cfg.d_ff
    else:                          # dense / audio / vlm
        per_layer += 3 * D * cfg.d_ff
    return n + L * per_layer
