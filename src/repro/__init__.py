"""repro: production-grade JAX reproduction of ERIS (FSA + DSC serverless FL)."""
__version__ = "1.0.0"
