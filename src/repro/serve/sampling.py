"""Token selection: temperature/top-k/top-p sampling, greedy, beam.

``sample`` is row-wise and fully traced — temperature/top_k/top_p ride
in as per-row ARRAYS, so one jitted decode step serves every request's
sampling config simultaneously (no per-config recompiles), and each row
draws from its own PRNG key: a request's token stream depends only on
its own (key, logits) history, never on which batch or slot it shares —
the property behind the engine's batched-vs-unbatched token identity.

``beam_search`` is the offline twin on the dense ring cache
(``transformer.decode_step``): fixed-width beams carried through a
``lax.scan``, per-step cache reordering by parent beam, optional EOS
with length-penalized scores.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as tr

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """temperature == 0 selects greedy; top_k == 0 / top_p == 1 disable
    the respective filters."""
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"SamplingParams.temperature must be >= 0, "
                             f"got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"SamplingParams.top_k must be >= 0, "
                             f"got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"SamplingParams.top_p must be in (0, 1], "
                             f"got {self.top_p}")


def sample(keys: jax.Array, logits: jax.Array, temperature: jax.Array,
           top_k: jax.Array, top_p: jax.Array) -> jax.Array:
    """Per-row token selection.  keys: (B, 2) uint32; logits: (B, V);
    temperature/top_k/top_p: (B,) — all traced.  Filter order matches
    the usual serving stack: temperature scale -> top-k -> top-p ->
    categorical; temperature 0 short-circuits to argmax."""
    B, V = logits.shape
    greedy = jnp.argmax(logits, axis=-1)
    scaled = logits.astype(jnp.float32) / jnp.maximum(temperature,
                                                      1e-6)[:, None]
    order = jnp.argsort(-scaled, axis=-1)
    sorted_logits = jnp.take_along_axis(scaled, order, axis=-1)
    # top-k: keep sorted positions < k (k == 0 disables)
    k_eff = jnp.where(top_k > 0, jnp.clip(top_k, 1, V), V)
    keep = jnp.arange(V)[None, :] < k_eff[:, None]
    # top-p: keep the smallest prefix of the sorted distribution whose
    # mass reaches p (the first token always survives: cum - prob == 0)
    probs = jax.nn.softmax(jnp.where(keep, sorted_logits, NEG_INF), -1)
    cum = jnp.cumsum(probs, axis=-1)
    keep &= (cum - probs) < top_p[:, None]
    masked_sorted = jnp.where(keep, sorted_logits, NEG_INF)
    inv = jnp.argsort(order, axis=-1)
    filtered = jnp.take_along_axis(masked_sorted, inv, axis=-1)
    drawn = jax.vmap(jax.random.categorical)(keys, filtered)
    return jnp.where(temperature <= 0, greedy, drawn).astype(jnp.int32)


def sample_one(key: jax.Array, logits: jax.Array,
               params: SamplingParams) -> jax.Array:
    """Single-row convenience over :func:`sample`."""
    return sample(key[None], logits[None],
                  jnp.array([params.temperature], jnp.float32),
                  jnp.array([params.top_k], jnp.int32),
                  jnp.array([params.top_p], jnp.float32))[0]


# ============================================================ beam decode
def beam_search(params, cfg, prompt: jax.Array, *, n_beams: int = 4,
                max_new_tokens: int = 16, window: Optional[int] = None,
                eos_id: Optional[int] = None, length_penalty: float = 1.0,
                cache_dtype=jnp.float32):
    """Fixed-width beam decode of one prompt on the dense decode cache.

    prompt: (S,) int32.  Returns (tokens (max_new_tokens,), score) of
    the best beam — score is summed log-prob / len**length_penalty over
    generated tokens (finished beams stop accumulating at EOS).
    """
    prompt = jnp.asarray(prompt, jnp.int32)
    S = prompt.shape[0]
    total = S + max_new_tokens
    logits, caches, _ = tr.forward(params, cfg, prompt[None],
                                   mode="prefill", window=window)

    def beams(c):
        return jnp.repeat(c, n_beams, axis=1)

    if cfg.family == "ssm":
        cache = jax.tree.map(beams, caches)
    else:
        base = tr.init_cache(cfg, n_beams, total, window=window,
                             dtype=cache_dtype)
        # relocate the dense prefill cache into the decode (ring) layout:
        # absolute position j lives at slot j % size; with a window only
        # the last `size` positions survive (older ones are never valid)
        size = base["kv"]["k"].shape[2]
        lo = max(0, S - size)
        slots = jnp.arange(lo, S) % size
        kv = {n: base["kv"][n].at[:, :, slots].set(
                  beams(caches["kv"][n][:, :, lo:]).astype(cache_dtype))
              for n in ("k", "v")}
        cache = {"kv": kv}
        if cfg.family == "hybrid":
            cache["ssm"] = beams(caches["ssm"]).astype(
                base["ssm"].dtype)
    logp0 = jax.nn.log_softmax(logits[0, S - 1].astype(jnp.float32))
    first = jax.lax.top_k(logp0, n_beams)
    V = logp0.shape[0]

    def step(carry, pos):
        cache, toks, scores, alive, seqs = carry
        logits, cache = tr.decode_step(params, cfg, cache, toks[:, None],
                                       pos, window=window)
        logp = jax.nn.log_softmax(logits[:, 0].astype(jnp.float32))
        # finished beams extend only with EOS at zero cost — they keep
        # their score and compete unchanged
        if eos_id is not None:
            frozen = jnp.full((n_beams, V), NEG_INF
                              ).at[:, eos_id].set(0.0)
            logp = jnp.where(alive[:, None], logp, frozen)
        cand = scores[:, None] + logp                 # (beams, V)
        top_s, top_i = jax.lax.top_k(cand.reshape(-1), n_beams)
        parent = top_i // V
        tok = (top_i % V).astype(jnp.int32)
        cache = jax.tree.map(lambda c: c[:, parent], cache)
        seqs = seqs[parent].at[:, pos - S + 1].set(tok)
        alive = alive[parent]
        if eos_id is not None:
            alive &= tok != eos_id
        return (cache, tok, top_s, alive, seqs), ()

    seqs0 = jnp.zeros((n_beams, max_new_tokens), jnp.int32)
    seqs0 = seqs0.at[:, 0].set(first[1].astype(jnp.int32))
    alive0 = jnp.ones((n_beams,), bool)
    if eos_id is not None:
        alive0 &= first[1] != eos_id
    carry = (cache, first[1].astype(jnp.int32), first[0], alive0, seqs0)
    if max_new_tokens > 1:
        carry, _ = jax.lax.scan(step, carry,
                                jnp.arange(S, S + max_new_tokens - 1))
    _, _, scores, _, seqs = carry
    norm = scores / (max_new_tokens ** length_penalty)
    best = jnp.argmax(norm)
    return seqs[best], norm[best]
