"""Serving subsystem: continuous batching over a paged KV cache.

``ServeEngine`` (engine.py) is the request loop — admission, batched
decode, eviction — over the block-pool cache (cache.py), with
temperature/top-k/top-p/greedy sampling and beam decode (sampling.py).
The public surface re-exports through ``repro.launch.serve`` next to
``TrainSettings``' home in ``repro.launch.train``.
"""
from repro.serve.cache import (BlockAllocator, BlockBudgetExceeded,  # noqa
                               pages_for, write_prefill)
from repro.serve.engine import (Request, RequestOutput, ServeEngine,  # noqa
                                ServeSettings)
from repro.serve.sampling import SamplingParams, beam_search, sample  # noqa
