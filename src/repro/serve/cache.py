"""Paged KV cache: a global pool of fixed-size blocks + per-request
block tables.

The pools themselves are device arrays created by
``models.transformer.init_paged_pools`` — (L, N, KV, bs, hd) per layer.
This module owns the HOST side: the free-list :class:`BlockAllocator`
(block 0 is reserved as the scratch block — inactive engine slots'
tables point at it, so their masked decode writes land somewhere
harmless), and the jit-friendly prefill scatter that moves a dense
prefill cache into a request's blocks.

Invariants (property-tested in tests/test_paged_cache.py):
  * allocated blocks are unique, nonzero, and within the pool
  * used + free == num_blocks - 1 (the scratch block is neither)
  * ``used`` never exceeds the budget; ``peak_used`` records the max
  * free(alloc(n)) round-trips to the same free count
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax.numpy as jnp

SCRATCH_BLOCK = 0


class BlockBudgetExceeded(RuntimeError):
    """Raised by ``alloc(..., strict=True)`` when the pool is exhausted."""


def pages_for(n_tokens: int, block_size: int) -> int:
    """Blocks needed to hold n_tokens (at least one once tokens exist)."""
    return -(-n_tokens // block_size)


@dataclasses.dataclass
class BlockAllocator:
    """Free-list allocator over pool blocks [1, num_blocks) — block 0 is
    the reserved scratch block and is never handed out."""
    num_blocks: int
    block_size: int

    def __post_init__(self):
        if self.num_blocks < 2:
            raise ValueError(f"num_blocks must be >= 2 (block 0 is the "
                             f"scratch block), got {self.num_blocks}")
        if self.block_size < 1:
            raise ValueError(f"block_size must be >= 1, "
                             f"got {self.block_size}")
        self._free: List[int] = list(range(self.num_blocks - 1, 0, -1))
        self._used: set = set()
        self.peak_used: int = 0

    @property
    def capacity(self) -> int:
        return self.num_blocks - 1

    @property
    def used(self) -> int:
        return len(self._used)

    @property
    def available(self) -> int:
        return len(self._free)

    def alloc(self, n: int = 1, strict: bool = False) -> Optional[List[int]]:
        """n fresh blocks, or None when the pool can't supply them
        (``strict=True`` raises :class:`BlockBudgetExceeded` instead).
        All-or-nothing: a partial grab is never left allocated."""
        if n > len(self._free):
            if strict:
                raise BlockBudgetExceeded(
                    f"need {n} blocks, {len(self._free)} free "
                    f"(capacity {self.capacity}, used {self.used})")
            return None
        blocks = [self._free.pop() for _ in range(n)]
        self._used.update(blocks)
        self.peak_used = max(self.peak_used, len(self._used))
        return blocks

    def free(self, blocks: List[int]) -> None:
        for b in blocks:
            if b not in self._used:
                raise ValueError(f"double free / foreign block {b}")
            self._used.remove(b)
            self._free.append(b)


def write_prefill(pools: dict, k, v, pages, block_size: int) -> dict:
    """Scatter one request's dense prefill K/V into its blocks.

    k, v: (L, S, KV, hd) — the squeezed batch-1 prefill cache; pages:
    (ceil(S_bucket/bs),) int32 pool blocks (pad entries with the scratch
    block).  Positions past the request's true length land either beyond
    its context (masked by attention, overwritten as it grows) or in the
    scratch block — both harmless, so no length mask is needed.
    """
    S = k.shape[1]
    idx = jnp.arange(S)
    page_arr = pages[idx // block_size]
    off_arr = idx % block_size
    # pool (L, N, KV, bs, hd) indexed [:, pages, :, offs] puts the
    # advanced dims in front: values arrive as (S, L, KV, hd)
    return {
        "k": pools["k"].at[:, page_arr, :, off_arr].set(
            k.transpose(1, 0, 2, 3).astype(pools["k"].dtype)),
        "v": pools["v"].at[:, page_arr, :, off_arr].set(
            v.transpose(1, 0, 2, 3).astype(pools["v"].dtype)),
    }
