"""ServeEngine: continuous batching over the paged KV cache.

The engine owns ``max_concurrency`` decode slots.  Every ``step()``:

  1. *evict* — finished requests free their blocks and leave their slot
     (their table row resets to the scratch block so the now-inactive
     row's masked writes can't alias live blocks);
  2. *admit* — waiting requests (FIFO) take free slots while the
     allocator can cover their prompt: one bucketed-jit prefill writes
     the prompt K/V into fresh blocks and samples the first token;
  3. *grow* — active requests crossing a block boundary allocate their
     next block; when the pool is exhausted the YOUNGEST active request
     is preempted (blocks freed, prefix requeued — deterministic
     sampling keys make the replayed continuation identical);
  4. *decode* — ONE fixed-shape jitted step over all slots
     (``transformer.paged_decode_step``: per-row positions, block-table
     K/V scatter, the Pallas paged-attention kernel), then row-wise
     sampling with per-request keys.

Token streams are a function of (params, prompt, SamplingParams, seed)
only — never of slot, step, or co-resident requests — so serving 8
concurrent requests emits token-identical output to serving each alone
(the acceptance gate in tests/test_serve.py).

With a ``mesh`` the engine shards the pools' kv-heads over 'model' and
the slot dim of the per-step batch over the client axes
(``dist.sharding.paged_pool_shardings`` / ``serve_batch_shardings``).
When the slot count divides the client-axis product the decode step
runs as a fully-manual ``shard_map`` (the train step's idiom): params
enter at the TP-plan layout (``dist.sharding.tp_param_in_specs``), the
body threads a ``TPRuntime`` through ``paged_decode_step`` — local head
counts, a psum after the row-parallel ``wo``, an all_gather after the
vocab-parallel unembed — and samples its own slot shard.  Inside the
manual body a ``pallas_call`` is just per-shard code, so the paged
Pallas kernel engages under TP instead of falling back to the gather
reference (GSPMD cannot partition a ``pallas_call``, which is why the
non-manual mesh fallback keeps the naive path).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tr
from repro.models.config import ModelConfig
from repro.serve import cache as pc
from repro.serve.sampling import SamplingParams, sample


def _shard_map(f, mesh, in_specs, out_specs):
    """Fully-manual shard_map (every mesh axis manual), compatible with
    both the jax>=0.5 top-level API and the 0.4.x experimental one —
    the same shim ``launch/train.py`` uses for the train step."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


@dataclasses.dataclass(frozen=True)
class ServeSettings:
    """Serving configuration (the ``TrainSettings`` twin for the other
    end of the checkpoint handoff)."""
    max_concurrency: int = 8       # decode slots (the continuous batch)
    block_size: int = 16           # tokens per KV block
    num_blocks: int = 128          # pool budget incl. the scratch block
    max_model_len: int = 256       # prompt + generation cap per request
    prefill_bucket: int = 32       # prompts pad up to a bucket multiple
                                   # (one prefill compile per bucket)
    max_new_tokens: int = 32       # default generation budget
    cache_dtype: str = "bfloat16"
    decode_kernel: str = "auto"    # auto | pallas | naive
    window: Optional[int] = None   # sliding window (None: cfg's own)
    eos_id: Optional[int] = None
    sampling: SamplingParams = SamplingParams()
    seed: int = 0

    def __post_init__(self):
        if self.max_concurrency < 1:
            raise ValueError(f"ServeSettings.max_concurrency must be >= 1, "
                             f"got {self.max_concurrency}")
        if self.num_blocks < 2:
            raise ValueError(f"ServeSettings.num_blocks must be >= 2, "
                             f"got {self.num_blocks}")
        if self.block_size < 1:
            raise ValueError(f"ServeSettings.block_size must be >= 1, "
                             f"got {self.block_size}")
        if self.max_model_len < 1:
            raise ValueError(f"ServeSettings.max_model_len must be >= 1, "
                             f"got {self.max_model_len}")
        if self.prefill_bucket < 1:
            raise ValueError(f"ServeSettings.prefill_bucket must be >= 1, "
                             f"got {self.prefill_bucket}")
        if self.decode_kernel not in ("auto", "pallas", "naive"):
            raise ValueError(f"ServeSettings.decode_kernel must be "
                             f"auto|pallas|naive, got {self.decode_kernel}")

    @property
    def max_pages(self) -> int:
        return pc.pages_for(self.max_model_len, self.block_size)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    sampling: SamplingParams
    seed: int
    generated: List[int] = dataclasses.field(default_factory=list)
    blocks: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    submit_t: float = 0.0
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None
    finish_reason: str = ""
    preemptions: int = 0


@dataclasses.dataclass(frozen=True)
class RequestOutput:
    rid: int
    prompt: List[int]
    tokens: List[int]
    finish_reason: str          # stop | length
    ttft_s: float               # submit -> first token
    latency_s: float            # submit -> finish
    preemptions: int


class ServeEngine:
    """See module docstring.  ``submit`` + ``step`` for streaming use,
    ``run`` to drain a batch of prompts."""

    def __init__(self, cfg: ModelConfig, params,
                 settings: ServeSettings = ServeSettings(), mesh=None):
        if cfg.family not in tr.paged_families():
            raise ValueError(
                f"ServeEngine serves families {tr.paged_families()}; "
                f"{cfg.family!r} needs a dense per-request state "
                f"(use transformer.decode_step)")
        self.cfg = cfg
        self.settings = settings
        self.mesh = mesh
        self.window = (settings.window if settings.window is not None
                       else cfg.sliding_window)
        C, P = settings.max_concurrency, settings.max_pages
        dtype = jnp.dtype(settings.cache_dtype)
        pools = tr.init_paged_pools(cfg, settings.num_blocks,
                                    settings.block_size, dtype)
        self._manual = False
        self._pool_sh = None
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec
            from repro.dist import sharding as sh
            n_dev = int(np.prod(mesh.devices.shape))
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            model = int(sizes.get("model", 1))
            # decode-safe TP plan: one-token queries have no sequence to
            # shard, so the seq/ctx activation regions drop out — the
            # PARAM layout is untouched (those flags never move weights)
            self._tp_plan = dataclasses.replace(
                tr.tp_plan(cfg, model), seq=False, seq_ce=False, ctx=1)
            self._model_size = model
            # manual path: every client position must own a whole number
            # of decode slots for the slot dim to enter sharded
            self._manual = C % max(n_dev // model, 1) == 0
            self._batch_sh = sh.serve_batch_shardings(mesh)
            self._rep_sh = NamedSharding(mesh, PartitionSpec())
            if self._manual:
                pspecs = sh.tp_param_in_specs(cfg, mesh)
                params = jax.device_put(params, jax.tree.map(
                    lambda s: NamedSharding(mesh, s), pspecs,
                    is_leaf=lambda x: isinstance(x, PartitionSpec)))
                # plan.attn implies kv-head divisibility; without it the
                # pools replicate and each model shard runs full heads
                pool_spec = (PartitionSpec(None, None, "model", None, None)
                             if self._tp_plan.attn else PartitionSpec())
                self._pool_sh = {"k": NamedSharding(mesh, pool_spec),
                                 "v": NamedSharding(mesh, pool_spec)}
                pools = jax.device_put(pools, self._pool_sh)
                self._midx = jax.device_put(
                    jnp.arange(model, dtype=jnp.int32),
                    NamedSharding(mesh, PartitionSpec("model")))
                bspec = self._batch_sh.spec
                self._decode_body = _shard_map(
                    self._manual_decode_fn, mesh,
                    in_specs=(PartitionSpec("model"), pspecs,
                              {"k": pool_spec, "v": pool_spec},
                              bspec, bspec, bspec, bspec, bspec, bspec,
                              bspec),
                    out_specs=(bspec, {"k": pool_spec, "v": pool_spec}))
            else:
                params = jax.device_put(
                    params, sh.param_shardings(cfg, mesh, "use"))
                self._pool_sh = sh.paged_pool_shardings(cfg, mesh)
                pools = jax.device_put(pools, self._pool_sh)
        if settings.decode_kernel == "auto":
            # the kernel is fine meshless and inside the manual body; it
            # is only the GSPMD fallback that cannot partition it
            self._use_kernel = mesh is None or self._manual
        else:
            self._use_kernel = settings.decode_kernel == "pallas"
        self.params = params
        self.pools = pools
        self.allocator = pc.BlockAllocator(settings.num_blocks,
                                           settings.block_size)
        self.tables = np.zeros((C, P), np.int32)       # scratch block 0
        self.slots: List[Optional[Request]] = [None] * C
        self.waiting: Deque[Request] = collections.deque()
        self._next_rid = 0
        self._steps = 0
        self._tokens_out = 0
        self._t0: Optional[float] = None
        if self._manual:
            midx = self._midx
            body = self._decode_body
            self._decode = jax.jit(
                lambda params, pools, *rest: body(midx, params, pools,
                                                  *rest),
                donate_argnums=(1,))
        else:
            self._decode = jax.jit(self._decode_fn, donate_argnums=(1,))
        self._prefills: dict = {}

    # ------------------------------------------------------ device closures
    def _decode_fn(self, params, pools, tables, ctxs, toks, keys,
                   temps, tks, tps):
        logits, pools = tr.paged_decode_step(
            params, self.cfg, pools, tables, ctxs, toks,
            window=self.window, use_kernel=self._use_kernel)
        nxt = sample(keys, logits[:, 0], temps, tks, tps)
        return nxt, pools

    def _manual_decode_fn(self, midx, params, pools, tables, ctxs, toks,
                          keys, temps, tks, tps):
        """shard_map body: every array is this position's shard — params
        at their TP dims, pools at the local kv-heads, the slot batch at
        this client coordinate's rows.  ``midx`` is the model-axis
        coordinate fed in as a sharded arange (``axis_index`` is
        unsupported under fully-manual SPMD)."""
        tp_rt = (tr.TPRuntime("model", self._model_size, midx[0],
                              self._tp_plan)
                 if self._tp_plan.active else None)
        logits, pools = tr.paged_decode_step(
            params, self.cfg, pools, tables, ctxs, toks,
            window=self.window, use_kernel=self._use_kernel, tp=tp_rt)
        nxt = sample(keys, logits[:, 0], temps, tks, tps)
        return nxt, pools

    def _prefill_fn(self, params, pools, tokens, pages, last, key,
                    temp, tk, tp_):
        logits, caches, _ = tr.forward(params, self.cfg, tokens,
                                       mode="prefill", window=self.window)
        pools = pc.write_prefill(pools, caches["kv"]["k"][:, 0],
                                 caches["kv"]["v"][:, 0], pages,
                                 self.settings.block_size)
        first = sample(key[None], logits[0, last][None], temp[None],
                       tk[None], tp_[None])[0]
        return first, pools

    def _prefill(self, bucket: int):
        fn = self._prefills.get(bucket)
        if fn is None:
            if self.mesh is not None:
                # pin the pool output to the committed layout so the
                # decode step (whose specs assume it) never re-lowers
                fn = jax.jit(self._prefill_fn, donate_argnums=(1,),
                             out_shardings=(self._rep_sh, self._pool_sh))
            else:
                fn = jax.jit(self._prefill_fn, donate_argnums=(1,))
            self._prefills[bucket] = fn
        return fn

    # -------------------------------------------------------------- intake
    def submit(self, prompt: Sequence[int], *,
               max_new_tokens: Optional[int] = None,
               sampling: Optional[SamplingParams] = None,
               seed: Optional[int] = None) -> int:
        """Queue a request; returns its id.  ``seed`` defaults to the
        request id (folded with ``settings.seed``) — pass one explicitly
        to make a prompt's stream reproducible across engines."""
        prompt = list(map(int, prompt))
        if not prompt:
            raise ValueError("empty prompt")
        new = (max_new_tokens if max_new_tokens is not None
               else self.settings.max_new_tokens)
        if len(prompt) + new > self.settings.max_model_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens ({new}) exceeds "
                f"max_model_len ({self.settings.max_model_len})")
        if pc.pages_for(len(prompt) + new, self.settings.block_size) > \
                self.allocator.capacity:
            raise ValueError(
                f"request needs more blocks than the pool holds "
                f"(num_blocks={self.settings.num_blocks})")
        rid = self._next_rid
        self._next_rid += 1
        r = Request(rid=rid, prompt=prompt, max_new_tokens=new,
                    sampling=sampling or self.settings.sampling,
                    seed=self.settings.seed * 1_000_003 + (
                        seed if seed is not None else rid),
                    submit_t=time.monotonic())
        self.waiting.append(r)
        return rid

    # ------------------------------------------------------------ plumbing
    def _token_key(self, r: Request, i: int):
        return jax.random.fold_in(jax.random.PRNGKey(r.seed), i)

    def _active(self) -> List[Request]:
        return [r for r in self.slots if r is not None]

    def _ctx_len(self, r: Request) -> int:
        # tokens whose K/V is in cache: prompt + all generated but the
        # newest (the pending decode step writes that one)
        return len(r.prompt) + len(r.generated) - 1

    def _put_batch(self, x):
        if self.mesh is not None:
            return jax.device_put(x, self._batch_sh)
        return x

    def _evict(self, r: Request, reason: str) -> RequestOutput:
        self.allocator.free(r.blocks)
        r.blocks = []
        self.tables[r.slot, :] = pc.SCRATCH_BLOCK
        self.slots[r.slot] = None
        r.slot = -1
        r.finish_t = time.monotonic()
        r.finish_reason = reason
        return RequestOutput(
            rid=r.rid, prompt=r.prompt, tokens=list(r.generated),
            finish_reason=reason,
            ttft_s=(r.first_token_t or r.finish_t) - r.submit_t,
            latency_s=r.finish_t - r.submit_t, preemptions=r.preemptions)

    def _preempt_youngest(self) -> bool:
        """Free the most recently admitted active request and requeue its
        full prefix at the head of the line.  Its sampling keys are
        indexed by token position, so the replay continues the exact
        same stream."""
        victims = [r for r in self.slots if r is not None]
        if len(victims) <= 1:
            return False
        v = max(victims, key=lambda r: r.rid)
        self.allocator.free(v.blocks)
        v.blocks = []
        self.tables[v.slot, :] = pc.SCRATCH_BLOCK
        self.slots[v.slot] = None
        v.slot = -1
        v.preemptions += 1
        self.waiting.appendleft(v)
        return True

    def _admit(self, r: Request, slot: int) -> bool:
        """Prefill ``r``'s prefix (prompt + any pre-preemption tokens)
        into fresh blocks; samples token index len(generated)."""
        s = self.settings
        prefix = r.prompt + r.generated
        n_pages = pc.pages_for(len(prefix) + 1, s.block_size)
        blocks = self.allocator.alloc(n_pages)
        if blocks is None:
            return False
        bucket = -(-len(prefix) // s.prefill_bucket) * s.prefill_bucket
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :len(prefix)] = prefix
        # fixed-length page vector (stable jit shapes); pad entries point
        # at the scratch block, so the bucket's padded tail lands there
        pages = np.full((max(s.max_pages, pc.pages_for(bucket, s.block_size),
                             n_pages),), pc.SCRATCH_BLOCK, np.int32)
        pages[:n_pages] = blocks
        samp = r.sampling
        first, self.pools = self._prefill(bucket)(
            self.params, self.pools, jnp.asarray(toks), jnp.asarray(pages),
            len(prefix) - 1, self._token_key(r, len(r.generated)),
            jnp.float32(samp.temperature), jnp.int32(samp.top_k),
            jnp.float32(samp.top_p))
        r.generated.append(int(first))
        if r.first_token_t is None:
            r.first_token_t = time.monotonic()
        self._tokens_out += 1
        r.slot = slot
        r.blocks = blocks
        self.slots[slot] = r
        self.tables[slot, :] = pc.SCRATCH_BLOCK
        self.tables[slot, :n_pages] = blocks
        return True

    # ---------------------------------------------------------------- step
    def step(self) -> List[RequestOutput]:
        """One engine iteration: evict / admit / grow / batched decode.
        Returns the requests that finished during this step."""
        s = self.settings
        if self._t0 is None:
            self._t0 = time.monotonic()
        self._steps += 1
        finished: List[RequestOutput] = []

        # evict finished (incl. first-token-only completions from admit)
        for r in list(self._active()):
            if self._done(r):
                finished.append(self._evict(r, self._done(r)))

        # admit waiting into free slots
        for slot in range(s.max_concurrency):
            if not self.waiting or self.slots[slot] is not None:
                continue
            if not self._admit(self.waiting[0], slot):
                break
            r = self.waiting.popleft()
            if self._done(r):
                finished.append(self._evict(r, self._done(r)))

        # grow: the pending decode writes at position ctx — make sure its
        # page exists; preempt the youngest request when the pool is dry.
        # A preempted r (slot -1 — evicted by an earlier iteration's
        # preempt, possibly its own) drops out of the loop: it re-enters
        # through admission, not growth.
        for r in list(self._active()):
            while r.slot >= 0 and \
                    pc.pages_for(self._ctx_len(r) + 1, s.block_size) > \
                    len(r.blocks):
                nb = self.allocator.alloc(1)
                if nb is None:
                    if self._preempt_youngest():
                        continue
                    raise pc.BlockBudgetExceeded(
                        "pool exhausted with a single active request — "
                        "num_blocks cannot cover max_model_len")
                if r.slot < 0:
                    self.allocator.free(nb)     # r itself was preempted
                    break
                self.tables[r.slot, len(r.blocks)] = nb[0]
                r.blocks.extend(nb)

        active = self._active()
        if not active:
            return finished

        C = s.max_concurrency
        toks = np.zeros((C, 1), np.int32)
        ctxs = np.zeros((C,), np.int32)
        keys = np.zeros((C, 2), np.uint32)
        temps = np.zeros((C,), np.float32)
        tks = np.zeros((C,), np.int32)
        tps = np.ones((C,), np.float32)
        for r in active:
            toks[r.slot, 0] = r.generated[-1]
            ctxs[r.slot] = self._ctx_len(r)
            keys[r.slot] = np.asarray(self._token_key(r, len(r.generated)))
            temps[r.slot] = r.sampling.temperature
            tks[r.slot] = r.sampling.top_k
            tps[r.slot] = r.sampling.top_p
        nxt, self.pools = self._decode(
            self.params, self.pools,
            self._put_batch(jnp.asarray(self.tables)),
            self._put_batch(jnp.asarray(ctxs)),
            self._put_batch(jnp.asarray(toks)),
            self._put_batch(jnp.asarray(keys)),
            self._put_batch(jnp.asarray(temps)),
            self._put_batch(jnp.asarray(tks)),
            self._put_batch(jnp.asarray(tps)))
        nxt = np.asarray(nxt)
        now = time.monotonic()
        for r in active:
            r.generated.append(int(nxt[r.slot]))
            self._tokens_out += 1
            if r.first_token_t is None:
                r.first_token_t = now
            if self._done(r):
                finished.append(self._evict(r, self._done(r)))
        return finished

    def _done(self, r: Request) -> str:
        if self.settings.eos_id is not None and r.generated and \
                r.generated[-1] == self.settings.eos_id:
            return "stop"
        if len(r.generated) >= r.max_new_tokens:
            return "length"
        return ""

    def run(self, prompts: Optional[Sequence[Sequence[int]]] = None,
            **submit_kw) -> List[RequestOutput]:
        """Submit ``prompts`` (optional) and drain the engine.  Outputs
        are returned sorted by request id."""
        for p in prompts or ():
            self.submit(p, **submit_kw)
        outs: List[RequestOutput] = []
        while self.waiting or self._active():
            outs.extend(self.step())
        return sorted(outs, key=lambda o: o.rid)

    # ---------------------------------------------------------------- misc
    def stats(self) -> dict:
        elapsed = (time.monotonic() - self._t0) if self._t0 else 0.0
        return {
            "steps": self._steps,
            "tokens_out": self._tokens_out,
            "tokens_per_s": self._tokens_out / elapsed if elapsed else 0.0,
            "peak_blocks": self.allocator.peak_used,
            "block_capacity": self.allocator.capacity,
        }

    @classmethod
    def from_checkpoint(cls, path, cfg: ModelConfig,
                        settings: ServeSettings = ServeSettings(),
                        mesh=None) -> "ServeEngine":
        """Load a ``launch/train.py`` artifact (sharded msgpack dir or
        legacy single file) and serve it — the store->use handoff: the
        checkpoint holds the FSA store layout, ``device_put`` under the
        serve mesh's ``use`` shardings does the reshard."""
        import functools
        from repro.checkpoint import msgpack_ckpt as ck
        target = jax.eval_shape(
            functools.partial(tr.init_params, cfg=cfg),
            jax.random.PRNGKey(0))
        params = ck.restore_any(path, target)
        # __init__ device_puts under the serve mesh's "use" shardings —
        # that device_put IS the store->use reshard
        return cls(cfg, params, settings, mesh=mesh)
