"""Pallas TPU kernels: per-block stochastic int8 (de)quantization.

Beyond-paper wire compression: the FSA reduce-scatter payload drops from
2 B/coord (bf16) to ~1.03 B/coord (int8 + one f32 scale per 256 coords).
Quantization is unbiased (stochastic rounding), so it composes with the
paper's Definition 3.1 analysis as an omega-compressor.

Tiling: flat vector viewed as (n_blocks, 256); a grid step handles
(BLOCK_B, 256) = up to 1 MiB of f32 in VMEM, emitting int8 + scales.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import largest_divisor, uniform_from_index

QBLOCK = 256          # coords per scale
BLOCK_B = 1024        # quant blocks per grid step


def wire_payload_bytes(n: int, *, block: int = QBLOCK) -> int:
    """Exact bytes of the quantized wire payload for an n-coordinate
    vector: one int8 per (block-padded) coordinate plus one f32 scale per
    block — what the FSA all_to_all actually puts on the mesh, and what
    the byte-accounting tests/benchmarks compare against the bf16
    baseline (2n)."""
    padded = -(-n // block) * block
    return padded + 4 * (padded // block)


def _quant_kernel(x_ref, seed_ref, q_ref, scale_ref, *, qblock):
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)          # (bb, qblock)
    scale = jnp.max(jnp.abs(x), axis=1) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    y = x / safe[:, None]
    low = jnp.floor(y)
    frac = y - low
    base = i * x.shape[0] * qblock
    idx = (base + jax.lax.broadcasted_iota(jnp.uint32, x.shape, 0) * qblock
           + jax.lax.broadcasted_iota(jnp.uint32, x.shape, 1))
    u = uniform_from_index(idx, seed_ref[0])
    q = low + (u < frac).astype(jnp.float32)
    q_ref[...] = jnp.clip(q, -127, 127).astype(jnp.int8)
    scale_ref[...] = scale


def _dequant_kernel(q_ref, scale_ref, x_ref):
    x_ref[...] = q_ref[...].astype(jnp.float32) * scale_ref[...][:, None]


def quantize(x, seed, *, block_b: int = BLOCK_B, interpret: bool = False):
    """x: (n,) float.  Ragged n zero-pads to the next 256 multiple (the
    wire layout `wire_payload_bytes` accounts for; zeros quantize to 0
    deterministically and never move a block scale).  Returns
    (q int8 (padded_n,), scales (padded_n/256,)), matching the oracle's
    padded layout."""
    n = x.shape[0]
    pad = (-n) % QBLOCK
    if pad:
        x = jnp.pad(x, (0, pad))
    nb = (n + pad) // QBLOCK
    block_b = largest_divisor(nb, min(block_b, nb))
    x2 = x.reshape(nb, QBLOCK)
    seed_arr = jnp.asarray([seed], jnp.uint32) if jnp.ndim(seed) == 0 \
        else seed.astype(jnp.uint32)
    q, scale = pl.pallas_call(
        functools.partial(_quant_kernel, qblock=QBLOCK),
        grid=(nb // block_b,),
        in_specs=[pl.BlockSpec((block_b, QBLOCK), lambda i: (i, 0)),
                  pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=(pl.BlockSpec((block_b, QBLOCK), lambda i: (i, 0)),
                   pl.BlockSpec((block_b,), lambda i: (i,))),
        out_shape=(jax.ShapeDtypeStruct((nb, QBLOCK), jnp.int8),
                   jax.ShapeDtypeStruct((nb,), jnp.float32)),
        interpret=interpret,
    )(x2, seed_arr)
    return q.reshape(-1), scale


def dequantize(q, scale, *, block_b: int = BLOCK_B, interpret: bool = False):
    n = q.shape[0]
    assert n % QBLOCK == 0, n
    nb = n // QBLOCK
    block_b = largest_divisor(nb, min(block_b, nb))
    out = pl.pallas_call(
        _dequant_kernel,
        grid=(nb // block_b,),
        in_specs=[pl.BlockSpec((block_b, QBLOCK), lambda i: (i, 0)),
                  pl.BlockSpec((block_b,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block_b, QBLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, QBLOCK), jnp.float32),
        interpret=interpret,
    )(q.reshape(nb, QBLOCK), scale)
    return out.reshape(n)
