"""Pallas TPU kernel: fused DSC client update.

    v  = (g - s) * mask / p     (mask ~ Bernoulli(p), counter-based RNG)
    s' = s + gamma * v

This is the per-round hot loop every FL client runs over its full update
vector (n = model size).  Unfused it is 4 HBM sweeps (read g, read s,
write v, write s') plus a mask read; the fusion does exactly 2 reads +
2 writes with all arithmetic in VMEM — the op is purely memory-bound, so
the fusion is the roofline optimum.

Tiling: the flat vector is viewed as (rows, 1024) with 1024 = 8*128
lanes (f32 VMEM tile is (8, 128)); each grid step processes a
(BLOCK_ROWS, 1024) tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import largest_divisor, uniform_from_index

LANES = 1024          # 8 * 128
BLOCK_ROWS = 256      # (256, 1024) f32 tile = 1 MiB in / 2 MiB out of VMEM


def _kernel(g_ref, s_ref, seed_ref, v_ref, s_out_ref, *, p, gamma, lanes):
    i = pl.program_id(0)
    g = g_ref[...]
    s = s_ref[...]
    rows = g.shape[0]
    base = i * rows * lanes
    idx = (base + jax.lax.broadcasted_iota(jnp.uint32, g.shape, 0) * lanes
           + jax.lax.broadcasted_iota(jnp.uint32, g.shape, 1))
    u = uniform_from_index(idx, seed_ref[0])
    diff = g.astype(jnp.float32) - s
    v = jnp.where(u < p, diff * (1.0 / p), 0.0)
    v_ref[...] = v.astype(v_ref.dtype)
    s_out_ref[...] = s + gamma * v


def dsc_update(g, s, seed, *, p: float, gamma: float,
               block_rows: int = BLOCK_ROWS, interpret: bool = False):
    """g: (n,) any float dtype; s: (n,) float32; seed: uint32 scalar.
    Ragged n is zero-padded internally to a 1024 multiple: the padded
    tail has g = s = 0, so v = 0 and s' = 0 there regardless of the mask
    draw, and the first n coordinates match the unpadded oracle exactly
    (the RNG is indexed by the global flat position, which padding does
    not displace).  Returns (v, s'), both length n."""
    n = g.shape[0]
    pad = (-n) % LANES
    if pad:
        g = jnp.pad(g, (0, pad))
        s = jnp.pad(s, (0, pad))
    rows = (n + pad) // LANES
    block_rows = largest_divisor(rows, min(block_rows, rows))
    grid = (rows // block_rows,)
    g2 = g.reshape(rows, LANES)
    s2 = s.reshape(rows, LANES)
    seed_arr = jnp.asarray([seed], jnp.uint32) if jnp.ndim(seed) == 0 \
        else seed.astype(jnp.uint32)
    out_shapes = (jax.ShapeDtypeStruct((rows, LANES), g.dtype),
                  jax.ShapeDtypeStruct((rows, LANES), jnp.float32))
    v, s_new = pl.pallas_call(
        functools.partial(_kernel, p=p, gamma=gamma, lanes=LANES),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=(pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
                   pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))),
        out_shape=out_shapes,
        interpret=interpret,
    )(g2, s2, seed_arr)
    return v.reshape(-1)[:n], s_new.reshape(-1)[:n]
