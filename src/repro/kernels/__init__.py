"""Pallas TPU kernels for the compute hot spots (validated interpret=True
on CPU): fused DSC update, int8 wire quantization, flash attention."""
from repro.kernels import ops  # noqa: F401
