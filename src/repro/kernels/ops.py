"""Public jit'd wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the
kernel body runs op-by-op, validating the exact TPU program logic; on a
real TPU the same call sites compile to Mosaic."""
from __future__ import annotations

import functools

import jax

from repro.kernels import dsc_update as _dsc
from repro.kernels import flash_attention as _fa
from repro.kernels import quantize as _q

_ON_TPU = jax.default_backend() == "tpu"
_INTERPRET = not _ON_TPU


@functools.partial(jax.jit, static_argnames=("p", "gamma"))
def dsc_update(g, s, seed, *, p: float, gamma: float):
    return _dsc.dsc_update(g, s, seed, p=p, gamma=gamma,
                           interpret=_INTERPRET)


@jax.jit
def quantize(x, seed):
    return _q.quantize(x, seed, interpret=_INTERPRET)


@jax.jit
def dequantize(q, scale):
    return _q.dequantize(q, scale, interpret=_INTERPRET)


@functools.partial(jax.jit, static_argnames=("causal",))
def flash_attention(q, k, v, *, causal: bool = True):
    return _fa.flash_attention(q, k, v, causal=causal, interpret=_INTERPRET)
