"""Pallas TPU kernel: fused DSC -> int8 wire step, one VMEM pass.

    v    = (g - s) * mask / p            mask ~ Bernoulli(p)
    q, c = int8_quantize(v)              per-256-block stochastic round
    vhat = q * c                         (in-register dequantize)
    s'   = s + gamma * vhat              shift tracks the WIRE value

This replaces the two-kernel chain the int8+DSC rounds used to run
(`dsc_update` then `quantize` then `dequantize` for the round-trip):
read g, read s, write v, write s', read v, write q/scales, read q/scales,
write vhat — ~7 full HBM sweeps of the n-sized update vector.  The fusion
is exactly 2 f32 reads (g, s) + 1 f32 write (s') + the int8 payload out
(q + one f32 scale per 256 coords): the roofline optimum for the
per-round client hot loop, and the shift state sees precisely what
crosses the wire (the Int8RoundTrip composition of Definition 3.1
omega-compressors, so Theorem 3.2's contraction bookkeeping still holds).

Tiling: flat vector viewed as (n_blocks, 256); each grid step handles a
(BLOCK_B, 256) tile.  Both RNG draws are counter-based (murmur3 on the
global flat element index), identical to `ref.dsc_quantize_ref`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import largest_divisor, uniform_from_index
from repro.kernels.quantize import QBLOCK

BLOCK_B = 1024        # quant blocks per grid step -> (1024, 256) f32 tiles


def _kernel(g_ref, s_ref, seeds_ref, q_ref, scale_ref, s_out_ref, *,
            p, gamma, qblock):
    i = pl.program_id(0)
    g = g_ref[...].astype(jnp.float32)              # (bb, qblock)
    s = s_ref[...]
    base = i * g.shape[0] * qblock
    idx = (base + jax.lax.broadcasted_iota(jnp.uint32, g.shape, 0) * qblock
           + jax.lax.broadcasted_iota(jnp.uint32, g.shape, 1))
    # --- DSC sparsify (Algorithm 1 line 4) -------------------------------
    u_mask = uniform_from_index(idx, seeds_ref[0])
    v = jnp.where(u_mask < p, (g - s) * (1.0 / p), 0.0)
    # --- per-block stochastic int8 ---------------------------------------
    scale = jnp.max(jnp.abs(v), axis=1) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    y = v / safe[:, None]
    low = jnp.floor(y)
    u_round = uniform_from_index(idx, seeds_ref[1])
    q = jnp.clip(low + (u_round < (y - low)).astype(jnp.float32),
                 -127, 127)
    # --- shift update against the dequantized wire value -----------------
    vhat = q * scale[:, None]
    q_ref[...] = q.astype(jnp.int8)
    scale_ref[...] = scale
    s_out_ref[...] = s + gamma * vhat


def dsc_quantize(g, s, seed_mask, seed_round, *, p: float, gamma: float,
                 block_b: int = BLOCK_B, interpret: bool = False):
    """g: (n,) float; s: (n,) float32; seeds: uint32 scalars.  Ragged n is
    zero-padded internally to a 256 multiple (zero diff -> zero v -> the
    padded tail never perturbs scales or shift state).

    Returns (q int8 (padded_n,), scales f32 (padded_n/256,), s' f32 (n,)).
    q/scales keep the padded wire layout (what `wire_payload_bytes`
    accounts for); s' is sliced back to n."""
    n = g.shape[0]
    pad = (-n) % QBLOCK
    if pad:
        g = jnp.pad(g, (0, pad))
        s = jnp.pad(s, (0, pad))
    nb = (n + pad) // QBLOCK
    block_b = largest_divisor(nb, min(block_b, nb))
    g2 = g.reshape(nb, QBLOCK)
    s2 = s.reshape(nb, QBLOCK)
    seeds = jnp.stack([jnp.asarray(seed_mask, jnp.uint32).reshape(()),
                       jnp.asarray(seed_round, jnp.uint32).reshape(())])
    q, scale, s_new = pl.pallas_call(
        functools.partial(_kernel, p=p, gamma=gamma, qblock=QBLOCK),
        grid=(nb // block_b,),
        in_specs=[pl.BlockSpec((block_b, QBLOCK), lambda i: (i, 0)),
                  pl.BlockSpec((block_b, QBLOCK), lambda i: (i, 0)),
                  pl.BlockSpec((2,), lambda i: (0,))],
        out_specs=(pl.BlockSpec((block_b, QBLOCK), lambda i: (i, 0)),
                   pl.BlockSpec((block_b,), lambda i: (i,)),
                   pl.BlockSpec((block_b, QBLOCK), lambda i: (i, 0))),
        out_shape=(jax.ShapeDtypeStruct((nb, QBLOCK), jnp.int8),
                   jax.ShapeDtypeStruct((nb,), jnp.float32),
                   jax.ShapeDtypeStruct((nb, QBLOCK), jnp.float32)),
        interpret=interpret,
    )(g2, s2, seeds)
    return q.reshape(-1), scale, s_new.reshape(-1)[:n]
