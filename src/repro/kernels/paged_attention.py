"""Pallas TPU kernel: paged decode attention through a block table.

The serving hot spot.  The paged KV cache (``repro/serve/cache.py``)
stores keys/values in fixed-size blocks of a global pool; each request
owns a block table mapping its logical pages to pool blocks.  This
kernel computes one decode step of GQA attention for a batch of
requests WITHOUT gathering their K/V into contiguous buffers: the block
table rides in as a scalar-prefetch operand
(``pltpu.PrefetchScalarGridSpec``), so the BlockSpec index maps
themselves chase the page indirection — grid step (b, h, p) streams
pool block ``table[b, p]`` of kv-head ``h`` through VMEM.

Grid = (batch, kv_heads, pages); the online-softmax state (running max,
sum, accumulator for the G grouped query heads) lives in VMEM scratch
and accumulates across the page dimension — the flash-attention
recurrence over pages instead of key blocks.  Pages past a request's
context length are masked (their table entries may be stale or 0 — the
allocator's scratch block); sliding windows mask positions below
``ctx - window``.  A fully-masked request (ctx == 0, an inactive
engine slot) produces zeros.

The pool layout is ``(num_blocks, KV, block_size, hd)``; the kernel
views it as ``(num_blocks * KV, block_size, hd)`` so one index-map
expression ``table[b, p] * KV + h`` addresses the (block, kv-head) row.
Head counts are whatever the caller holds — under tensor parallelism
these are the TP-local heads; the kernel never communicates.

``supports()`` gates shapes onto :func:`paged_attention_ref`, the
jnp gather reference — numerically the same computation with the
(B, P*bs) score matrix materialized.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def supports(n_heads: int, n_kv_heads: int, head_dim: int) -> bool:
    """Shapes the Pallas kernel handles; anything else takes the naive
    gather path (same contract as ``flash_attention.supports``)."""
    return (n_heads % n_kv_heads == 0 and head_dim % 2 == 0
            and head_dim >= 8)


def _kernel(tbl_ref, ctx_ref, q_ref, k_ref, v_ref, o_ref,
            acc_ref, m_ref, l_ref, *, block_size, window, sm_scale):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    ctx = ctx_ref[b]
    q = q_ref[0].astype(jnp.float32) * sm_scale       # (G, hd)
    k = k_ref[0].astype(jnp.float32)                  # (bs, hd)
    v = v_ref[0].astype(jnp.float32)
    s = q @ k.T                                       # (G, bs)
    pos = p * block_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = pos < ctx
    if window is not None:
        valid &= pos >= ctx - window
    s = jnp.where(valid, s, NEG_INF)
    m_prev, l_prev, acc = m_ref[...], l_ref[...], acc_ref[...]
    m_cur = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    # zero masked lanes explicitly: when every key so far is masked,
    # m_cur == NEG_INF and exp(s - m_cur) would be 1, not 0 — an
    # inactive slot (ctx == 0) must come out all-zero, not mean(v)
    pexp = jnp.where(valid, jnp.exp(s - m_cur[:, None]), 0.0)
    l_cur = l_prev * alpha + pexp.sum(axis=1)
    acc = acc * alpha[:, None] + pexp @ v
    m_ref[...], l_ref[...], acc_ref[...] = m_cur, l_cur, acc

    @pl.when(p == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def paged_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                    block_tables: jax.Array, context_lens: jax.Array, *,
                    window: Optional[int] = None,
                    interpret: bool = False) -> jax.Array:
    """One decode step of paged GQA attention.

    q:            (B, H, hd)  — the new tokens' query heads
    k_pool/v_pool:(N, KV, bs, hd) — the global block pools
    block_tables: (B, P) int32 — pool block of each request's page p
                  (entries past the request's pages must still be valid
                  pool indices, e.g. 0)
    context_lens: (B,) int32 — valid positions per request INCLUDING the
                  token being decoded (its K/V already written)
    window:       sliding window — keys at ctx-window <= j < ctx attend

    Returns (B, H, hd) in q's dtype.
    """
    B, H, hd = q.shape
    N, KV, bs, _ = k_pool.shape
    P = block_tables.shape[1]
    if H % KV:
        raise ValueError(f"n_heads ({H}) must be a multiple of "
                         f"n_kv_heads ({KV})")
    G = H // KV
    qf = q.reshape(B, KV, G, hd).reshape(B * KV, G, hd)
    kf = k_pool.reshape(N * KV, bs, hd)
    vf = v_pool.reshape(N * KV, bs, hd)
    kernel = functools.partial(_kernel, block_size=bs, window=window,
                               sm_scale=hd ** -0.5)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, P),
        in_specs=[
            pl.BlockSpec((1, G, hd),
                         lambda b, h, p, tbl, ctx: (b * KV + h, 0, 0)),
            pl.BlockSpec((1, bs, hd),
                         lambda b, h, p, tbl, ctx: (tbl[b, p] * KV + h,
                                                    0, 0)),
            pl.BlockSpec((1, bs, hd),
                         lambda b, h, p, tbl, ctx: (tbl[b, p] * KV + h,
                                                    0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, G, hd), lambda b, h, p, tbl, ctx: (b * KV + h, 0, 0)),
        scratch_shapes=[pltpu.VMEM((G, hd), jnp.float32),
                        pltpu.VMEM((G,), jnp.float32),
                        pltpu.VMEM((G,), jnp.float32)],
    )
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * KV, G, hd), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), context_lens.astype(jnp.int32),
      qf, kf, vf)
    return out.reshape(B, H, hd)


def paged_attention_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                        block_tables: jax.Array, context_lens: jax.Array, *,
                        window: Optional[int] = None,
                        interpret: bool = False) -> jax.Array:
    """jnp reference / fallback: gather each request's pages from the
    pools, then masked softmax attention.  Same signature and semantics
    as :func:`paged_attention` (``interpret`` accepted and ignored)."""
    del interpret
    B, H, hd = q.shape
    N, KV, bs, _ = k_pool.shape
    P = block_tables.shape[1]
    G = H // KV
    tbl = block_tables.astype(jnp.int32)
    # (B, P, KV, bs, hd) -> (B, KV, P*bs, hd)
    ks = k_pool[tbl].transpose(0, 2, 1, 3, 4).reshape(B, KV, P * bs, hd)
    vs = v_pool[tbl].transpose(0, 2, 1, 3, 4).reshape(B, KV, P * bs, hd)
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,bksh->bkgs", qg.astype(jnp.float32),
                   ks.astype(jnp.float32)) * (hd ** -0.5)
    pos = jnp.arange(P * bs)
    valid = pos[None] < context_lens[:, None]
    if window is not None:
        valid &= pos[None] >= context_lens[:, None] - window
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    # a fully-masked row (inactive slot) must produce zeros, not mean(v):
    # with m == NEG_INF, exp(s - m) is 1 at masked lanes, so zero them
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - jax.lax.stop_gradient(m)) * valid[:, None, None]
    denom = jnp.maximum(e.sum(-1, keepdims=True), 1e-30)
    w = (e / denom).astype(vs.dtype)
    out = jnp.einsum("bkgs,bksh->bkgh", w, vs)
    return out.reshape(B, H, hd).astype(q.dtype)
