"""Shared kernel utilities: counter-based in-kernel PRNG.

The DSC / QSGD kernels need per-element random bits *inside* the kernel
(reading a pre-generated mask from HBM would double the memory traffic the
fusion exists to avoid).  We use a counter-based hash (murmur3 finalizer)
keyed on (seed, element index): identical in the Pallas kernel and the
pure-jnp oracle, so correctness tests are exact."""
from __future__ import annotations

import jax.numpy as jnp


def largest_divisor(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is <= ``cap`` — the canonical grid
    block-size chooser for every kernel call site (simulator stages and
    the distributed runtime must pick IDENTICAL grids, or their
    quantization draws drift)."""
    best = 1
    for d in range(1, min(n, cap) + 1):
        if n % d == 0:
            best = d
    return best


def hash_u32(x):
    """murmur3 fmix32 — high-quality 32-bit mixer (expressible in both
    Pallas and plain jnp)."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def uniform_from_index(idx, seed):
    """U(0,1) from a global element index and a uint32 seed."""
    bits = hash_u32(idx.astype(jnp.uint32) ^ seed.astype(jnp.uint32))
    return (bits >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))
