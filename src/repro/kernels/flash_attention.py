"""Pallas TPU kernel: blocked online-softmax (flash) causal attention.

The transformer hot spot for prefill.  Grid = (batch*heads, q_blocks);
each grid step streams K/V blocks through VMEM keeping running
(max, sum, accumulator) — O(S) memory instead of O(S^2), MXU-aligned
(BLOCK_Q x BLOCK_K x d matmuls with d a multiple of 128 ideally).

Supports self-attention with Sq == Skv (prefill) and causal masking.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_Q = 128
BLOCK_K = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, block_q, block_k, sm_scale,
            causal, seq_len):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * sm_scale       # (bq, d)
    q_offset = qi * block_q
    n_kb = seq_len // block_k

    def body(kb, carry):
        acc, m_prev, l_prev = carry
        k = k_ref[0, pl.ds(kb * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[0, pl.ds(kb * block_k, block_k), :].astype(jnp.float32)
        s = q @ k.T                                   # (bq, bk)
        if causal:
            qpos = q_offset + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = kb * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_cur = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_cur = l_prev * alpha + p.sum(axis=1)
        acc = acc * alpha[:, None] + p @ v
        return acc, m_cur, l_cur

    d = q.shape[-1]
    acc0 = jnp.zeros((block_q, d), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    if causal:
        # only k-blocks up to (and including) the diagonal contribute
        n_iter = (q_offset + block_q + block_k - 1) // block_k
    else:
        n_iter = n_kb
    acc, m, l = jax.lax.fori_loop(0, n_iter, body, (acc0, m0, l0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    block_q: int = BLOCK_Q, block_k: int = BLOCK_K,
                    interpret: bool = False):
    """q, k, v: (B, H, S, d).  Returns (B, H, S, d).  S % block == 0."""
    B, H, S, d = q.shape
    assert k.shape == v.shape == (B, H, S, d)
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0
    sm_scale = d ** -0.5
    qf = q.reshape(B * H, S, d)
    kf = k.reshape(B * H, S, d)
    vf = v.reshape(B * H, S, d)
    grid = (B * H, S // block_q)
    out = pl.pallas_call(
        functools.partial(_kernel, block_q=block_q, block_k=block_k,
                          sm_scale=sm_scale, causal=causal, seq_len=S),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, S, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, S, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, d), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, d)
