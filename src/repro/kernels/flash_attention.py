"""Pallas TPU kernels: blocked online-softmax (flash) causal attention,
forward AND backward.

The transformer hot spot for prefill and training.  Grid =
(batch*heads, q_blocks); each grid step streams K/V blocks through VMEM
keeping running (max, sum, accumulator) — O(S) memory instead of O(S^2),
MXU-aligned (BLOCK_Q x BLOCK_K x d matmuls with d a multiple of 128
ideally).

Training path (``jax.custom_vjp``): the forward additionally emits the
per-row log-sum-exp; the backward recomputes the score blocks from
(q, k, lse) tile-by-tile — two more blocked kernels (dq and dk/dv), so
the S x S score/probability matrices NEVER touch HBM in either pass.
HBM traffic per head: 3 reads + 1 write forward, ~5 reads + 3 writes
backward, all O(S*d) — versus O(S^2) materialized scores under the
blanket-remat chunked path.

Supports self-attention with Sq == Skv, causal masking, sliding
windows, and grouped-query heads (H a multiple of KV; K/V blocks are
indexed through the query-head -> kv-head map, no materialized repeat).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_Q = 128
BLOCK_K = 128
NEG_INF = -1e30


def _mask(s, qpos0, kpos0, block_q, block_k, causal, window):
    """Apply causal/window masking to a (block_q, block_k) score tile
    whose rows start at absolute position qpos0, columns at kpos0."""
    if not causal and window is None:
        return s
    qpos = qpos0 + jax.lax.broadcasted_iota(jnp.int32,
                                            (block_q, block_k), 0)
    kpos = kpos0 + jax.lax.broadcasted_iota(jnp.int32,
                                            (block_q, block_k), 1)
    ok = kpos <= qpos if causal else jnp.full_like(qpos, True, jnp.bool_)
    if window is not None:
        ok &= kpos > qpos - window
    return jnp.where(ok, s, NEG_INF)


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, block_q, block_k,
                sm_scale, causal, window, seq_len):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * sm_scale       # (bq, d)
    q_offset = qi * block_q
    n_kb = seq_len // block_k

    def body(kb, carry):
        acc, m_prev, l_prev = carry
        k = k_ref[0, pl.ds(kb * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[0, pl.ds(kb * block_k, block_k), :].astype(jnp.float32)
        s = q @ k.T                                   # (bq, bk)
        s = _mask(s, q_offset, kb * block_k, block_q, block_k,
                  causal, window)
        m_cur = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_cur = l_prev * alpha + p.sum(axis=1)
        acc = acc * alpha[:, None] + p @ v
        return acc, m_cur, l_cur

    d = q.shape[-1]
    acc0 = jnp.zeros((block_q, d), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    if causal:
        # only k-blocks up to (and including) the diagonal contribute
        hi = (q_offset + block_q + block_k - 1) // block_k
    else:
        hi = n_kb
    if window is not None:
        lo = jnp.maximum(0, (q_offset - window + 1) // block_k)
    else:
        lo = 0
    acc, m, l = jax.lax.fori_loop(lo, hi, body, (acc0, m0, l0))
    l_safe = jnp.maximum(l, 1e-30)
    o_ref[0] = (acc / l_safe[:, None]).astype(o_ref.dtype)
    lse_ref[0] = m + jnp.log(l_safe)


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, *,
               block_q, block_k, sm_scale, causal, window, seq_len):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * sm_scale
    do = do_ref[0].astype(jnp.float32)
    lse = lse_ref[0]                                   # (bq,)
    delta = delta_ref[0]
    q_offset = qi * block_q

    def body(kb, dq):
        k = k_ref[0, pl.ds(kb * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[0, pl.ds(kb * block_k, block_k), :].astype(jnp.float32)
        s = q @ k.T
        s = _mask(s, q_offset, kb * block_k, block_q, block_k,
                  causal, window)
        p = jnp.exp(s - lse[:, None])                  # masked -> exp(-inf)=0
        dp = do @ v.T
        ds = p * (dp - delta[:, None])
        return dq + ds @ k

    if causal:
        hi = (q_offset + block_q + block_k - 1) // block_k
    else:
        hi = seq_len // block_k
    if window is not None:
        lo = jnp.maximum(0, (q_offset - window + 1) // block_k)
    else:
        lo = 0
    d = q.shape[-1]
    dq = jax.lax.fori_loop(lo, hi, body, jnp.zeros((block_q, d),
                                                   jnp.float32))
    dq_ref[0] = (dq * sm_scale).astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, *, block_q, block_k, sm_scale, causal,
                window, seq_len):
    ki = pl.program_id(1)
    k = k_ref[0].astype(jnp.float32)                   # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    k_offset = ki * block_k

    def body(qb, carry):
        dk, dv = carry
        q = q_ref[0, pl.ds(qb * block_q, block_q), :].astype(jnp.float32) \
            * sm_scale
        do = do_ref[0, pl.ds(qb * block_q, block_q), :].astype(jnp.float32)
        lse = lse_ref[0, pl.ds(qb * block_q, block_q)]
        delta = delta_ref[0, pl.ds(qb * block_q, block_q)]
        s = q @ k.T                                    # (bq, bk)
        s = _mask(s, qb * block_q, k_offset, block_q, block_k,
                  causal, window)
        p = jnp.exp(s - lse[:, None])
        dv = dv + p.T @ do
        dp = do @ v.T
        ds = p * (dp - delta[:, None])
        return dk + ds.T @ q, dv

    n_qb = seq_len // block_q
    if causal:
        # only q-blocks at/after the diagonal see this k-block
        lo = k_offset // block_q
    else:
        lo = 0
    if window is not None:
        # query rows with qpos < kpos + window: last such block
        hi = jnp.minimum(n_qb,
                         (k_offset + block_k - 1 + window - 1) // block_q + 1)
    else:
        hi = n_qb
    d = k.shape[-1]
    z = jnp.zeros((block_k, d), jnp.float32)
    dk, dv = jax.lax.fori_loop(lo, hi, body, (z, z))
    # q was pre-scaled, so ds.T @ q already carries one sm_scale factor
    dk_ref[0] = dk.astype(dk_ref.dtype)
    dv_ref[0] = dv.astype(dv_ref.dtype)


def _kv_index(b, H, KV):
    """Query-head grid index -> kv-head row in the flattened (B*KV, S, d)
    K/V arrays (GQA: G = H // KV query heads share one kv head)."""
    G = H // KV
    return (b // H) * KV + (b % H) // G


def _check(q, k, v, block_q, block_k):
    B, H, S, d = q.shape
    KV = k.shape[1]
    assert k.shape == v.shape == (B, KV, S, d), (q.shape, k.shape, v.shape)
    assert H % KV == 0, (H, KV)
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    return B, H, KV, S, d, block_q, block_k


def _flash_fwd_call(q, k, v, causal, window, block_q, block_k, interpret):
    B, H, KV, S, d, block_q, block_k = _check(q, k, v, block_q, block_k)
    sm_scale = d ** -0.5
    qf = q.reshape(B * H, S, d)
    kf = k.reshape(B * KV, S, d)
    vf = v.reshape(B * KV, S, d)
    kv_map = functools.partial(_kv_index, H=H, KV=KV)
    out, lse = pl.pallas_call(
        functools.partial(_fwd_kernel, block_q=block_q, block_k=block_k,
                          sm_scale=sm_scale, causal=causal, window=window,
                          seq_len=S),
        grid=(B * H, S // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, S, d), lambda b, i, _m=kv_map: (_m(b), 0, 0)),
            pl.BlockSpec((1, S, d), lambda b, i, _m=kv_map: (_m(b), 0, 0)),
        ],
        out_specs=(pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),
                   pl.BlockSpec((1, block_q), lambda b, i: (b, i))),
        out_shape=(jax.ShapeDtypeStruct((B * H, S, d), q.dtype),
                   jax.ShapeDtypeStruct((B * H, S), jnp.float32)),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, d), lse


def _flash_bwd_call(q, k, v, o, lse, do, causal, window, block_q, block_k,
                    interpret):
    B, H, KV, S, d, block_q, block_k = _check(q, k, v, block_q, block_k)
    sm_scale = d ** -0.5
    qf = q.reshape(B * H, S, d)
    kf = k.reshape(B * KV, S, d)
    vf = v.reshape(B * KV, S, d)
    dof = do.reshape(B * H, S, d)
    # delta_i = sum_d do_i * o_i — one cheap fused elementwise reduce
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32),
                    axis=-1).reshape(B * H, S)
    kv_map = functools.partial(_kv_index, H=H, KV=KV)
    kw = dict(block_q=block_q, block_k=block_k, sm_scale=sm_scale,
              causal=causal, window=window, seq_len=S)
    dq = pl.pallas_call(
        functools.partial(_dq_kernel, **kw),
        grid=(B * H, S // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, S, d), lambda b, i, _m=kv_map: (_m(b), 0, 0)),
            pl.BlockSpec((1, S, d), lambda b, i, _m=kv_map: (_m(b), 0, 0)),
            pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_q), lambda b, i: (b, i)),
            pl.BlockSpec((1, block_q), lambda b, i: (b, i)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, d), q.dtype),
        interpret=interpret,
    )(qf, kf, vf, dof, lse, delta)
    # dk/dv per QUERY head (grid b spans B*H; K/V blocks via the GQA map);
    # group contributions are summed after the kernel — a fused reduce
    # over G, still O(S*d) traffic
    dk_h, dv_h = pl.pallas_call(
        functools.partial(_dkv_kernel, **kw),
        grid=(B * H, S // block_k),
        in_specs=[
            pl.BlockSpec((1, S, d), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda b, j, _m=kv_map: (_m(b), j, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda b, j, _m=kv_map: (_m(b), j, 0)),
            pl.BlockSpec((1, S, d), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, S), lambda b, j: (b, 0)),
            pl.BlockSpec((1, S), lambda b, j: (b, 0)),
        ],
        out_specs=(pl.BlockSpec((1, block_k, d), lambda b, j: (b, j, 0)),
                   pl.BlockSpec((1, block_k, d), lambda b, j: (b, j, 0))),
        out_shape=(jax.ShapeDtypeStruct((B * H, S, d), jnp.float32),
                   jax.ShapeDtypeStruct((B * H, S, d), jnp.float32)),
        interpret=interpret,
    )(qf, kf, vf, dof, lse, delta)
    G = H // KV
    dk = dk_h.reshape(B, KV, G, S, d).sum(2).astype(k.dtype)
    dv = dv_h.reshape(B, KV, G, S, d).sum(2).astype(v.dtype)
    return dq.reshape(B, H, S, d), dk, dv


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _flash(q, k, v, cfg):
    out, _ = _flash_fwd_call(q, k, v, *cfg)
    return out


def _flash_vjp_fwd(q, k, v, cfg):
    out, lse = _flash_fwd_call(q, k, v, *cfg)
    return out, (q, k, v, out, lse)


def _flash_vjp_bwd(cfg, res, do):
    q, k, v, out, lse = res
    return _flash_bwd_call(q, k, v, out, lse, do, *cfg)


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    block_q: int = BLOCK_Q, block_k: int = BLOCK_K,
                    interpret: bool = False):
    """q: (B, H, S, d); k, v: (B, KV, S, d) with H % KV == 0 (GQA).
    Returns (B, H, S, d).  S % block == 0 after blocks clamp to S.
    Differentiable (custom VJP, blocked recompute backward)."""
    if window is not None:
        assert causal, "sliding window implies causal masking"
    cfg = (bool(causal), None if window is None else int(window),
           int(block_q), int(block_k), bool(interpret))
    return _flash(q, k, v, cfg)


def supports(S: int, d: int, block_q: int = BLOCK_Q,
             block_k: int = BLOCK_K) -> bool:
    """Shape gate for the training integration: the kernels need the
    (possibly clamped) blocks to tile S exactly."""
    bq, bk = min(block_q, S), min(block_k, S)
    return S % bq == 0 and S % bk == 0
