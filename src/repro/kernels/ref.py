"""Pure-jnp oracles for every Pallas kernel (the ground truth the
interpret-mode kernels are asserted allclose against)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import uniform_from_index


# ------------------------------------------------------------- DSC update
def dsc_update_ref(g, s, seed, p: float, gamma: float):
    """Fused DSC client step (Algorithm 1 lines 4+7):
        v = (g - s) * mask / p          mask ~ Bernoulli(p)
        s' = s + gamma * v
    g: any shape (update leaf); s: same shape float32; seed: uint32 scalar.
    Returns (v, s')."""
    n = g.size
    idx = jnp.arange(n, dtype=jnp.uint32).reshape(g.shape)
    u = uniform_from_index(idx, seed)
    mask = u < p
    diff = g.astype(jnp.float32) - s
    v = jnp.where(mask, diff / p, 0.0)
    return v.astype(g.dtype), s + gamma * v


# --------------------------------------------------------- QSGD quantize
def quantize_ref(x, seed, block: int = 256):
    """Per-block stochastic int8 quantization (beyond-paper wire format).

    x is flattened into blocks of ``block``; each block gets scale =
    max|x| / 127 and values are stochastically rounded to int8.
    Returns (q int8 [n], scales f32 [n_blocks]).  Unbiased."""
    n = x.size
    xf = x.reshape(-1).astype(jnp.float32)
    pad = (-n) % block
    xp = jnp.pad(xf, (0, pad))
    xb = xp.reshape(-1, block)
    scale = jnp.max(jnp.abs(xb), axis=1) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    y = xb / safe[:, None]
    low = jnp.floor(y)
    frac = y - low
    idx = jnp.arange(xp.size, dtype=jnp.uint32).reshape(-1, block)
    u = uniform_from_index(idx, seed)
    q = low + (u < frac)
    q = jnp.clip(q, -127, 127).astype(jnp.int8)
    return q.reshape(-1)[: n + pad], scale


def dequantize_ref(q, scale, block: int = 256):
    qb = q.reshape(-1, block).astype(jnp.float32)
    return (qb * scale[:, None]).reshape(-1)


# ------------------------------------------------- fused DSC -> int8 wire
def dsc_quantize_ref(g, s, seed_mask, seed_round, *, p: float, gamma: float,
                     block: int = 256):
    """Oracle for the one-pass fused wire kernel: RandP mask-draw on the
    shifted difference, per-block stochastic int8 of the sparsified
    update, and a shift update that tracks the DEQUANTIZED value (so the
    shift state sees exactly what crosses the wire — the Int8RoundTrip
    composition of Definition 3.1 compressors).

    g, s: (n,) float32 with n % block == 0 (callers pad).
    Returns (q int8 (n,), scales f32 (n/block,), s_new f32 (n,))."""
    n = g.size
    idx = jnp.arange(n, dtype=jnp.uint32)
    u = uniform_from_index(idx, seed_mask)
    diff = g.astype(jnp.float32) - s
    v = jnp.where(u < p, diff / p, 0.0)
    q, scale = quantize_ref(v, seed_round, block)
    v_hat = dequantize_ref(q, scale, block)[:n]
    return q, scale, s + gamma * v_hat


# -------------------------------------------------------- flash attention
def flash_attention_ref(q, k, v, *, causal: bool = True, window=None):
    """Naive attention oracle.  q: (B, H, Sq, d); k/v: (B, KV, Skv, d)
    with H % KV == 0 (grouped-query); differentiable (pure jnp)."""
    B, H, Sq, d = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, Sq, d)
    scores = jnp.einsum("bkgqd,bksd->bkgqs", qg, k).astype(jnp.float32)
    scores = scores * (d ** -0.5)
    if causal or window is not None:
        qpos = jnp.arange(Sq)[:, None] + (Skv - Sq)
        kpos = jnp.arange(Skv)[None, :]
        mask = kpos <= qpos if causal else jnp.ones((Sq, Skv), bool)
        if window is not None:
            mask &= kpos > qpos - window
        scores = jnp.where(mask, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bkgqs,bksd->bkgqd", w, v.astype(jnp.float32)
                      ).reshape(B, H, Sq, d).astype(q.dtype)
