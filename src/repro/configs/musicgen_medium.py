"""MusicGen-medium: decoder-only over EnCodec tokens (audio backbone only;
the mel/conv codec frontend is the allowed stub — tokens are the input).
[arXiv:2306.05284]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, d_ff=6144,
    vocab=2048, source="arXiv:2306.05284")
