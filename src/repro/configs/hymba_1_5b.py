"""Hymba-1.5B: hybrid-head model — parallel attention + mamba heads per
layer, ssm_state=16.  [arXiv:2411.13676]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_ff=5504,
    vocab=32001, ssm_state=16, source="arXiv:2411.13676")
