"""GPT-Neo-1.3B-scale decoder — the paper's own largest model
(CNN/DailyMail experiments, Table 1).  [arXiv: Black et al. 2021]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="eris-gptneo-1.3b", family="dense",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=8192,
    vocab=50257, source="paper Sec. 4.1 / zenodo.5297715")
