"""Qwen3-32B: dense, qk_norm, GQA kv=8, head_dim=128 (Qwen3 family uses
explicit head_dim 128 independent of d_model/n_heads).  [hf:Qwen/Qwen3-8B]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8, d_ff=25600,
    vocab=151936, head_dim=128, qk_norm=True, source="hf:Qwen/Qwen3-8B")
