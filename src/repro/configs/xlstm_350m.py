"""xLSTM-350M: sLSTM + mLSTM blocks, 4 heads, no separate FFN (gated
in-block projection; d_ff=0 per the assignment).  [arXiv:2405.04517]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=50304, source="arXiv:2405.04517")
