"""InternVL2-26B language backbone (InternLM2-20B-style decoder).  The
InternViT vision encoder is the allowed stub: input_specs provides 256
precomputed patch embeddings (d=1024) per image, projected into d_model.
[arXiv:2404.16821]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab=92553, frontend="vlm", n_frontend_tokens=256, d_frontend=1024,
    source="arXiv:2404.16821")
