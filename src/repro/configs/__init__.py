"""Architecture registry: ``get_config(arch_id)`` / ``--arch <id>``.

Every assigned architecture from the public pool, plus the paper's own
GPT-Neo-1.3B-scale decoder (its largest evaluated model).
"""
from __future__ import annotations

import importlib

ARCHS = [
    "phi3_5_moe_42b", "musicgen_medium", "hymba_1_5b", "starcoder2_3b",
    "internvl2_26b", "olmoe_1b_7b", "starcoder2_15b", "qwen3_32b",
    "qwen2_0_5b", "xlstm_350m", "eris_gptneo_1_3b",
]

_ALIASES = {
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b",
    "musicgen-medium": "musicgen_medium",
    "hymba-1.5b": "hymba_1_5b",
    "starcoder2-3b": "starcoder2_3b",
    "internvl2-26b": "internvl2_26b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "starcoder2-15b": "starcoder2_15b",
    "qwen3-32b": "qwen3_32b",
    "qwen2-0.5b": "qwen2_0_5b",
    "xlstm-350m": "xlstm_350m",
    "eris-gptneo-1.3b": "eris_gptneo_1_3b",
}


def canonical(arch_id: str) -> str:
    key = arch_id.replace("_", "-").lower()
    return _ALIASES.get(key, arch_id.replace("-", "_").replace(".", "_"))


def get_config(arch_id: str):
    mod = importlib.import_module(f"repro.configs.{canonical(arch_id)}")
    return mod.CONFIG


def all_configs():
    return {a: get_config(a) for a in ARCHS}
