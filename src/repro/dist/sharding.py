"""Sharding policy for the distributed FSA runtime (Section 3.2.1 on a mesh).

The mesh has two kinds of axes:

* **client axes** (``pod``/``data``) — every position is one FSA
  *aggregator*: it owns a disjoint segment of each parameter ("store"
  layout), receives exactly that segment of every client update via
  ``psum_scatter`` (Eq. 2), and runs the shard-local optimizer on it.
* **model axis** — manual-collective tensor parallelism inside each
  client group under the family-generic shard plan
  (``models/shard_plan``): Megatron column/row pairs, vocab-parallel
  embed/unembed, expert-parallel MoE (expert-dim shards + token
  all_to_all), head-/channel-sharded recurrent mixers, and optional
  sequence parallelism.  :class:`TPSpec` (re-exported from the shard
  plan) maps every entry of ``models/transformer.param_spec`` to its
  model-axis shard dim (or replicate/partial); the serving path keeps
  its GSPMD "use" layout.

The segment-of-a-parameter choice is the *scatter dim*: for each leaf we
pick the rightmost dimension OF THE TP-LOCAL SHAPE divisible by the
number of aggregators; a leaf with no such dimension is replicated over
the client axes and aggregated with a full ``psum`` (always correct,
never sharded).  This mirrors the coordinate partition masks of
``repro.core.masks`` at tensor granularity: the set of (leaf, slice)
pairs owned by aggregator ``a`` IS the mask m_(a) — disjoint and
complete by construction (Theorem B.1 applies unchanged; with TP it
applies per model-axis shard).  The "store" layout composes both axes:
``model`` at the TP dim times the client axes at the scatter dim.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

QBLOCK = 256        # coords per int8-wire scale (kernels/quantize.QBLOCK)


# ------------------------------------------------------------------ axes
def client_axes(mesh: Mesh) -> tuple[str, ...]:
    """Mesh axes enumerating FSA aggregators (everything but the
    intra-model 'model' and 'pipe' axes)."""
    return tuple(a for a in mesh.axis_names if a not in ("model", "pipe"))


def client_count(mesh: Mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return int(np.prod([sizes[a] for a in client_axes(mesh)]))


def _caxis(mesh: Mesh):
    ca = client_axes(mesh)
    return ca if len(ca) > 1 else ca[0]


def _model_size(mesh: Mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return int(sizes.get("model", 1))


def _pipe_size(mesh: Mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return int(sizes.get("pipe", 1))


# ----------------------------------------------------------- scatter dims
def _abstract_params(cfg):
    import functools
    from repro.models import transformer as tr
    return jax.eval_shape(functools.partial(tr.init_params, cfg=cfg),
                          jax.random.PRNGKey(0))


# --------------------------------------------------- tensor-parallel spec
# The per-leaf placement (TPSpec) and the derivation from param_spec role
# metadata live in the family-generic shard-plan subsystem; re-exported
# here because the mesh-side geometry below (local shapes, split/merge,
# composite store specs, wire layouts) is expressed in terms of them.
from repro.models.shard_plan import TPSpec, tp_specs  # noqa: E402,F401


def tp_local_shape(shape: tuple[int, ...], spec: TPSpec,
                   tp: int) -> tuple[int, ...]:
    """The per-model-position shape of a leaf under ``spec``."""
    if spec.dim < 0 or tp <= 1:
        return tuple(shape)
    shape = list(shape)
    shape[spec.dim] //= tp
    return tuple(shape)


def tp_split_leaf(x: jax.Array, spec: TPSpec, tp: int) -> jax.Array:
    """Materialize the per-position TP shards of one leaf: stacked
    ``(tp, *local_shape)``, shard i = model position i's slice (the same
    contiguous chunking ``P('model' @ dim)`` produces)."""
    if spec.dim < 0 or tp <= 1:
        return jnp.stack([x] * max(tp, 1))
    return jnp.stack(jnp.split(x, tp, axis=spec.dim))


def tp_merge_leaf(shards: jax.Array, spec: TPSpec) -> jax.Array:
    """Inverse of :func:`tp_split_leaf` (replicated leaves: shard 0)."""
    if spec.dim < 0:
        return shards[0]
    return jnp.concatenate(list(shards), axis=spec.dim)


# --------------------------------------------------------- pipeline dims
# The pipe axis slices the leading L-stacked layer dim of every block
# leaf into contiguous stages (models/shard_plan.PipelinePlan); non-block
# leaves (embed / lm_head / ln_f / proj_in) replicate over pipe — every
# stage embeds its own microbatch injection and the last stage computes
# the CE, so their grads are per-stage partials that psum over 'pipe'.
def pipe_dims(cfg, pp: int) -> Any:
    """Per-leaf pipe slice dim (0 for block leaves when the pipe axis is
    real, else -1), a pytree of ints matching the param tree."""
    from repro.models import transformer as tr
    spec = tr.param_spec(cfg)
    out: dict[str, Any] = {}
    for name in spec:
        if name == "blocks":
            out["blocks"] = {bn: (0 if pp > 1 else -1)
                             for bn in spec["blocks"]}
        else:
            out[name] = -1
    return out


def pipe_local_shape(shape: tuple[int, ...], pdim: int,
                     pp: int) -> tuple[int, ...]:
    """The per-pipe-stage shape of a (TP-local) leaf."""
    if pdim < 0 or pp <= 1:
        return tuple(shape)
    shape = list(shape)
    shape[pdim] //= pp
    return tuple(shape)


def pipe_grad_sync(grads: Any, pdims: Any, axis) -> Any:
    """After ``value_and_grad`` of the pipeline loss: block-leaf grads
    are stage-local (each stage owns its layer rows outright) and pass
    through; pipe-replicated leaves carry per-stage partial sums — psum
    them over the pipe axis."""
    return jax.tree.map(
        lambda g, pd: g if pd >= 0 else jax.lax.psum(g, axis),
        grads, pdims)


def tp_grad_sync(grads: Any, specs: Any, axis) -> Any:
    """Inside the manual region, after ``value_and_grad``: ``partial``
    leaves (replicated params consumed shard-locally) carry per-position
    partial sums — psum them over the model axis.  col/row/vocab grads
    are shard-local and replicate-kind grads already replicated, so both
    pass through untouched."""
    return jax.tree.map(
        lambda g, s: jax.lax.psum(g, axis) if s.kind == "partial" else g,
        grads, specs)


def scatter_dim_for(shape: tuple[int, ...], n_client: int) -> int:
    """Rightmost dim divisible by n_client, else -1 (replicate + psum)."""
    for d in range(len(shape) - 1, -1, -1):
        if shape[d] >= n_client and shape[d] % n_client == 0:
            return d
    return -1


def fsa_scatter_dims(cfg, mesh: Mesh) -> Any:
    """Per-leaf scatter dim for the FSA reduce-scatter / shard-local
    optimizer (pytree of ints matching the param tree).  Computed on the
    PIPE- and TP-LOCAL shape: inside the manual region every leaf is
    already this position's stage/model shard, and the client
    segmentation divides that."""
    n_client = client_count(mesh)
    tp = _model_size(mesh)
    pp = _pipe_size(mesh)
    params = _abstract_params(cfg)
    specs = tp_specs(cfg, tp)
    pdims = pipe_dims(cfg, pp)
    return jax.tree.map(
        lambda p, s, pd: scatter_dim_for(
            pipe_local_shape(tp_local_shape(p.shape, s, tp), pd, pp),
            n_client), params, specs, pdims)


# -------------------------------------------------------------- shardings
def _spec_with(dim: int, axes) -> P:
    if dim < 0:
        return P()
    parts: list = [None] * (dim + 1)
    parts[dim] = axes
    return P(*parts)


def _as_tuple(axes) -> tuple:
    return axes if isinstance(axes, tuple) else (axes,)


def composite_store_spec(tp_dim: int, fsa_dim: int, caxis,
                         pipe_dim: int = -1) -> P:
    """'store' PartitionSpec of one leaf: ``model`` at the TP dim times
    the client axes at the (TP-local) FSA scatter dim, times ``pipe`` at
    the stage slice dim (block leaves' L-stack).  When factors land on
    the same dim the intra-model axes are major — pipe, then model, then
    the client segmentation within."""
    if tp_dim < 0 and fsa_dim < 0 and pipe_dim < 0:
        return P()
    parts: list = [None] * (max(tp_dim, fsa_dim, pipe_dim) + 1)
    if pipe_dim >= 0:
        parts[pipe_dim] = ("pipe",)
    if tp_dim >= 0:
        parts[tp_dim] = (tuple(parts[tp_dim] or ()) + ("model",))
    if fsa_dim >= 0:
        parts[fsa_dim] = (tuple(parts[fsa_dim] or ()) + _as_tuple(caxis))
    return P(*[p[0] if isinstance(p, tuple) and len(p) == 1 else p
               for p in parts])


def store_specs(cfg, mesh: Mesh) -> Any:
    """Pytree of 'store'-layout PartitionSpecs (the composite pipe x
    model x client placement) matching the parameter tree."""
    caxis = _caxis(mesh)
    tp = _model_size(mesh)
    pp = _pipe_size(mesh)
    dims = fsa_scatter_dims(cfg, mesh)
    specs = tp_specs(cfg, tp)
    pdims = pipe_dims(cfg, pp)
    return jax.tree.map(
        lambda d, s, pd: composite_store_spec(s.dim, d, caxis, pd),
        dims, specs, pdims)


def dsc_store_spec(tp_leaf: TPSpec, caxis, pipe_dim: int = -1) -> P:
    """Layout of one client-stacked DSC-reference leaf, global shape
    ``(n_client, *full_leaf_shape)``: client axes at the stacking dim 0,
    ``model`` at the leaf's TP dim (and ``pipe`` at the stage dim)
    shifted by the stack."""
    n = max(tp_leaf.dim + 1, pipe_dim + 1, 0)
    parts: list = [caxis] + [None] * n
    if pipe_dim >= 0:
        parts[pipe_dim + 1] = "pipe"
    if tp_leaf.dim >= 0:
        prev = parts[tp_leaf.dim + 1]
        parts[tp_leaf.dim + 1] = ("model" if prev is None
                                  else (prev, "model"))
    return P(*parts)


def buffer_spec_tree(cfg, mesh: Mesh, fsa: bool = True) -> dict:
    """PartitionSpec tree of the FedBuff-style async aggregation buffer
    (``repro.core.pipeline.BufferState`` on the mesh): the staleness-
    weighted accumulator ``u`` mirrors the parameters' layout — each
    aggregator buffers its OWN disjoint segment under FSA (the composite
    store placement), the TP broadcast layout under the FedAvg baseline —
    and the cumulative weight / round counter are replicated scalars
    (every position folds the identical arrival mass)."""
    u = store_specs(cfg, mesh) if fsa else tp_param_in_specs(cfg, mesh)
    return {"u": u, "w": P(), "t": P()}


def shift_state_dtype(name: str):
    """Residency dtype of the DSC shift state (s_clients / s_agg) — the
    one knob ``TrainSettings.shift_dtype`` threads through the store
    layout.  bf16 halves the resident shift bytes (2 full model copies
    per client position otherwise); the fused wire kernels widen to f32
    on the fly inside VMEM, so only the HBM store narrows."""
    dt = jnp.dtype(name)
    if dt not in (jnp.dtype(jnp.float32), jnp.dtype(jnp.bfloat16),
                  jnp.dtype(jnp.float16)):
        raise ValueError(f"shift_dtype must be a float store dtype, "
                         f"got {name!r}")
    return dt


def tp_param_in_specs(cfg, mesh: Mesh) -> Any:
    """shard_map in_specs for the parameter broadcast: sharded over
    ``model`` at each leaf's TP dim and ``pipe`` at the block leaves'
    L-stack, replicated over the client axes (the boundary all-gather is
    the FSA broadcast, Algorithm 1 line 14)."""
    tp = _model_size(mesh)
    pp = _pipe_size(mesh)

    def one(s: TPSpec, pd: int) -> P:
        hi = max(s.dim, pd)
        if hi < 0:
            return P()
        parts: list = [None] * (hi + 1)
        if pd >= 0:
            parts[pd] = "pipe"
        if s.dim >= 0:
            parts[s.dim] = "model"
        return P(*parts)

    return jax.tree.map(one, tp_specs(cfg, tp), pipe_dims(cfg, pp))


def _use_spec(shape: tuple[int, ...], model: int) -> P:
    """Tensor-parallel placement hint: rightmost dim divisible by the
    model-axis size (GSPMD inserts whatever collectives remain)."""
    if model <= 1:
        return P()
    for d in range(len(shape) - 1, -1, -1):
        if shape[d] >= model and shape[d] % model == 0:
            return _spec_with(d, "model")
    return P()


def param_shardings(cfg, mesh: Mesh, mode: str = "store") -> Any:
    """NamedShardings for the parameter tree.

    * ``store`` — FSA x TP layout: each leaf split over ``model`` at its
      TP dim (per :func:`tp_specs`) and over the client axes at its
      TP-local scatter dim (aggregator a owns segment a); leaves with
      neither replicated.
    * ``use``   — serving/compute layout: replicated over client axes,
      tensor-parallel over 'model' where divisible (GSPMD hints).
    """
    params = _abstract_params(cfg)
    if mode == "store":
        return jax.tree.map(lambda s: NamedSharding(mesh, s),
                            store_specs(cfg, mesh),
                            is_leaf=lambda x: isinstance(x, P))
    if mode == "use":
        model = _model_size(mesh)
        return jax.tree.map(
            lambda p: NamedSharding(mesh, _use_spec(p.shape, model)), params)
    raise ValueError(f"unknown param layout {mode!r}")


def batch_shardings(cfg, mesh: Mesh, batch: Any) -> Any:
    """Batch inputs: leading (batch) dim over the client axes — each
    aggregator position trains its own client group's shard."""
    caxis = _caxis(mesh)

    def one(leaf):
        if getattr(leaf, "ndim", 0) == 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(caxis))

    return jax.tree.map(one, batch)


def cache_shardings(cfg, mesh: Mesh, cache: Any) -> Any:
    """Decode caches: (layer, batch, ...) leaves shard batch (dim 1) over
    the client axes."""
    caxis = _caxis(mesh)

    def one(leaf):
        if getattr(leaf, "ndim", 0) < 2:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(None, caxis))

    return jax.tree.map(one, cache)


def mirror_state_specs(params_abs: Any, param_leaf_specs: list,
                       state_abs: Any, default: P) -> Any:
    """Specs for an optimizer-state tree that mirrors the parameter tree
    leaf-wise (e.g. Adam mu/nu).  State leaves are matched positionally —
    leaf i of each params-shaped sub-tree gets param spec i — and
    anything that doesn't mirror a parameter (step counters, scalars)
    gets ``default``."""
    p_shapes = [tuple(p.shape) for p in jax.tree.leaves(params_abs)]
    n = len(p_shapes)
    leaves, treedef = jax.tree.flatten(state_abs)
    out, i = [], 0
    for leaf in leaves:
        shape = tuple(getattr(leaf, "shape", ()))
        if n and shape == p_shapes[i % n]:
            out.append(param_leaf_specs[i % n])
            i += 1
        else:
            out.append(default)
    return jax.tree.unflatten(treedef, out)


# ------------------------------------------------------ int8 wire layouts
@dataclasses.dataclass(frozen=True)
class WireLayout:
    """Per-leaf layout of the int8 wire payload for the FSA exchange.

    A leaf with scatter dim ``dim >= 0`` is split into ``n_client``
    contiguous segments along ``dim``; each segment is flattened, padded
    to a multiple of QBLOCK, and quantized per-256-block (int8 values +
    one f32 scale per block).  The (block, scale) pair is what crosses
    the mesh.  ``dim == -1`` leaves (no divisible dimension) stay on the
    un-quantized psum path in the runtime's ``grad_dtype``.
    """

    dim: int              # scatter dim (-1 = replicated, full psum)
    shard_elems: int      # un-padded elements per aggregator segment
    padded_elems: int     # rounded up to a QBLOCK multiple
    n_blocks: int         # scales per segment (= padded_elems // QBLOCK)

    @property
    def wire_bytes(self) -> int:
        """Bytes one client sends for ONE segment: int8 blocks + scales."""
        return self.padded_elems + 4 * self.n_blocks


def wire_layout_for(shape: tuple[int, ...], n_client: int) -> WireLayout:
    """Layout of one leaf's int8 wire payload (the geometry
    ``launch/train.py`` quantizes and ``all_to_all``s with)."""
    dim = scatter_dim_for(shape, n_client)
    if dim < 0:
        return WireLayout(-1, 0, 0, 0)
    m = int(np.prod(shape)) // n_client
    padded = -(-m // QBLOCK) * QBLOCK
    return WireLayout(dim, m, padded, padded // QBLOCK)


def int8_wire_layouts(cfg, mesh: Mesh) -> Any:
    """Pytree of :class:`WireLayout` matching the parameter tree (wire
    geometry of the PIPE/TP-LOCAL leaf each mesh position exchanges)."""
    n_client = client_count(mesh)
    tp = _model_size(mesh)
    pp = _pipe_size(mesh)
    params = _abstract_params(cfg)
    specs = tp_specs(cfg, tp)
    pdims = pipe_dims(cfg, pp)
    return jax.tree.map(
        lambda p, s, pd: wire_layout_for(
            pipe_local_shape(tp_local_shape(p.shape, s, tp), pd, pp),
            n_client), params, specs, pdims)


def mesh_wire_bytes(cfg, mesh: Mesh, *, int8: bool,
                    grad_bytes: int = 2) -> int:
    """Bytes ONE client (mesh position) puts on the client axes per round
    under the FSA exchange: the sum over leaves of every transmitted
    segment (n_client - 1 remote segments + its own, counted once each,
    matching the collective's logical payload).  With a model axis, each
    position exchanges only its TP-local shard, so this is per-position;
    model-axis psum traffic is accounted separately (``hlo_analysis``
    per-axis breakdown).  ``int8=False`` accounts the ``grad_dtype``
    path."""
    n_client = client_count(mesh)
    tp = _model_size(mesh)
    pp = _pipe_size(mesh)
    params = _abstract_params(cfg)
    specs = tp_specs(cfg, tp)
    pdims = pipe_dims(cfg, pp)
    total = 0
    for p, s, pd, lay in zip(
            jax.tree.leaves(params), jax.tree.leaves(specs),
            jax.tree.leaves(pdims),
            jax.tree.leaves(int8_wire_layouts(cfg, mesh),
                            is_leaf=lambda x: isinstance(x, WireLayout))):
        elems = int(np.prod(pipe_local_shape(
            tp_local_shape(p.shape, s, tp), pd, pp)))
        if int8 and lay.dim >= 0:
            total += n_client * lay.wire_bytes
        else:
            total += elems * grad_bytes
    return total


def param_bytes_per_device(cfg, mesh: Mesh) -> int:
    """Resident parameter bytes per device in the COMPUTE layout (every
    leaf at its pipe/TP-local shape, client-replicated) — the number the
    ≥26B acceptance bound (total / (tp * pp) within the replicated-leaf
    slack) is checked against in ``benchmarks/tp_snapshot``."""
    tp = _model_size(mesh)
    pp = _pipe_size(mesh)
    params = _abstract_params(cfg)
    specs = tp_specs(cfg, tp)
    pdims = pipe_dims(cfg, pp)
    total = 0
    for p, s, pd in zip(jax.tree.leaves(params), jax.tree.leaves(specs),
                        jax.tree.leaves(pdims)):
        shape = pipe_local_shape(tp_local_shape(p.shape, s, tp), pd, pp)
        total += int(np.prod(shape)) * jnp.dtype(p.dtype).itemsize
    return total


def split_shards(x: jax.Array, dim: int, n_client: int) -> jax.Array:
    """Reorganize a leaf into its FSA segments: ``(n_client, m)`` rows,
    row a = the flattened contiguous segment of ``dim`` that aggregator a
    owns (identical chunking to ``psum_scatter(..., tiled=True)`` and the
    'store' layout slices — the rows ARE the masks m_(a))."""
    pre, post = x.shape[:dim], x.shape[dim + 1:]
    size = x.shape[dim] // n_client
    x = x.reshape(*pre, n_client, size, *post)
    x = jnp.moveaxis(x, len(pre), 0)
    return x.reshape(n_client, -1)


def merge_shards(rows: jax.Array, dim: int, shape: tuple[int, ...],
                 n_client: int) -> jax.Array:
    """Inverse of :func:`split_shards` — reassemble ``(n_client, m)`` rows
    into the full leaf of ``shape``."""
    pre, post = shape[:dim], shape[dim + 1:]
    size = shape[dim] // n_client
    rows = rows.reshape(n_client, *pre, size, *post)
    rows = jnp.moveaxis(rows, 0, len(pre))
    return rows.reshape(shape)


def opt_state_shardings(cfg, mesh: Mesh, opt, params_abs: Any) -> Any:
    """Global-view NamedShardings for the optimizer state (mirrors the
    'store' parameter layout; scalars replicated)."""
    store = param_shardings(cfg, mesh, "store")
    state_abs = jax.eval_shape(opt.init, params_abs)
    specs = mirror_state_specs(
        params_abs,
        [s.spec for s in jax.tree.leaves(
            store, is_leaf=lambda x: isinstance(x, NamedSharding))],
        state_abs, P())
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------- serving
def paged_pool_shardings(cfg, mesh: Mesh) -> Any:
    """NamedShardings for the serving engine's paged KV pools
    ((L, N, KV, bs, hd) per layer): kv-heads shard over 'model' when
    they divide it — the same store/use machinery decision rule as
    ``_use_spec`` — else the pools replicate.  The block dim N stays
    unsharded: any request's table may point anywhere in the pool."""
    model = _model_size(mesh)
    if model > 1 and cfg.n_kv_heads % model == 0:
        spec = P(None, None, "model", None, None)
    else:
        spec = P()
    sh = NamedSharding(mesh, spec)
    return {"k": sh, "v": sh}


def serve_batch_shardings(mesh: Mesh) -> NamedSharding:
    """Sharding for the engine's per-step slot-batched inputs (tokens,
    context lens, block tables, sampling vectors): leading slot dim over
    the client axes — the serving twin of ``batch_shardings``."""
    return NamedSharding(mesh, P(_caxis(mesh)))
