"""Distributed layout policy: mesh axes, FSA scatter dims, shardings."""
from repro.dist import sharding  # noqa: F401
