"""Trip-count-aware analysis of compiled (SPMD-partitioned) HLO text.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE —
with scan-over-layers models that undercounts FLOPs and collective bytes
by a factor of n_layers.  This module parses the HLO text, builds the
computation call graph (entry -> while bodies -> fusions), extracts while
trip counts from their condition comparisons, and propagates multipliers,
yielding:

  * ``flops``            — 2*M*N*K summed over every dot, x trip counts
  * ``collective_bytes`` — per-kind payload bytes, x trip counts
  * ``traffic_bytes``    — HBM-traffic proxy: operand+result bytes of
                           fusion/dot/collective/copy ops, x trip counts

All numbers are PER DEVICE (the HLO is the per-device SPMD program).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "s32": 4, "s16": 2, "s8": 1,
                "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+"
    r"([a-z][a-z0-9\-]*(?:-start|-done)?)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")
_PAIRS_RE = re.compile(r"source_target_pairs=\{((?:\{\d+,\d+\},?)+)\}")


def _first_group(line: str):
    """First replica group of a collective op as a list of device ids, or
    None when unparseable / absent (``replica_groups={}`` = all devices).
    Handles the explicit ``{{0,1,...},...}`` form and the iota form
    ``[G,S]<=[dims...]`` with optional transpose."""
    m = _GROUPS_RE.search(line)
    if m:
        return [int(x) for x in m.group(1).replace(" ", "").split(",") if x]
    m = _IOTA_RE.search(line)
    if m:
        import numpy as np
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",") if d]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            ids = ids.transpose([int(p) for p in m.group(4).split(",")])
        return list(ids.reshape(g, s)[0])
    return None


def _classify_axis(group, model_size: int, pipe_size: int = 1) -> str:
    """Mesh-axis label of one collective from its replica-group shape.

    The ``model`` axis is the minor-most mesh axis, so model-axis
    collectives run over ``model_size`` CONSECUTIVE device ids; the
    ``pipe`` axis (when real) sits one stride up (stride ==
    model_size, group length == pipe_size); client-axis collectives
    stride over everything below them (stride == model_size *
    pipe_size).  Anything else (or no groups = every device) is 'all'.
    """
    if not group:
        return "all"
    stride = group[1] - group[0] if len(group) > 1 else 1
    if model_size > 1 and len(group) == model_size and stride == 1:
        return "model"
    if (pipe_size > 1 and len(group) == pipe_size
            and stride == model_size):
        return "pipe"
    if stride == model_size * pipe_size or model_size * pipe_size == 1:
        return "client"
    return "all"


def _permute_stride(line: str):
    """Modal |target - source| id delta of a collective-permute's
    source-target cycle, or None when unparseable.  A ring over a mesh
    axis hops size(minor axes) ids n-1 times in one direction (delta
    +/-stride, sign by ring direction) and wraps once (delta of the
    opposite sign, magnitude stride*(n-1)), so the most common ABSOLUTE
    delta is the axis stride either way — a positive-only mode would
    misfile every reverse-direction ring (backward K/V rotation, the
    second half of a bidirectional chunk ring) under its wraparound."""
    m = _PAIRS_RE.search(line)
    if not m:
        return None
    deltas: dict[int, int] = {}
    for ms, mt in re.findall(r"\{(\d+),(\d+)\}", m.group(1)):
        d = abs(int(mt) - int(ms))
        if d > 0:
            deltas[d] = deltas.get(d, 0) + 1
    if not deltas:
        return None
    return max(deltas, key=lambda d: (deltas[d], -d))


def _classify_permute(stride, model_size: int, pipe_size: int = 1) -> str:
    """Mesh-axis label of one collective-permute from its cycle stride:
    ppermutes carry no replica_groups, so the ``_classify_axis`` path
    filed them all under 'all' (mispricing ring traffic at the full
    device count).  Stride 1 = the minor-most ``model`` ring (TP
    ring-all-reduce hops, context-parallel K/V rotation); stride ==
    model_size = the ``pipe`` boundary send; stride == model_size *
    pipe_size = a client-axis ring."""
    if stride is None:
        return "all"
    if model_size > 1 and stride == 1:
        return "model"
    if pipe_size > 1 and stride == model_size:
        return "pipe"
    if stride == model_size * pipe_size:
        return "client"
    return "all"


def _shape_elems_bytes(text: str):
    total_b = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total_b += n * _DTYPE_BYTES[dt]
    return total_b


class HloModule:
    def __init__(self, hlo_text: str):
        self.computations: dict[str, list[dict]] = {}
        self.op_shape: dict[str, str] = {}      # op name -> result type text
        self.constants: dict[str, int] = {}
        self._fusion_access_cache: dict[str, tuple] = {}
        self._parse(hlo_text)
        self.multipliers = self._propagate()

    # ------------------------------------------------------------- parse
    def _parse(self, text: str):
        cur = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line:
                continue
            mc = _COMP_RE.match(line.strip()) if line.endswith("{") else None
            if mc:
                cur = mc.group(1)
                self.computations[cur] = []
                continue
            if line.strip() == "}":
                cur = None
                continue
            mo = _OP_RE.match(line)
            if not mo or cur is None:
                continue
            name, rtype, kind, rest = mo.groups()
            self.op_shape[name] = rtype
            op = {"name": name, "type": rtype.strip(), "kind": kind,
                  "rest": rest, "line": line.strip()}
            self.computations[cur].append(op)
            if kind == "constant":
                mv = re.search(r"constant\((-?\d+)\)", line)
                if mv:
                    self.constants[name] = int(mv.group(1))

    # -------------------------------------------------- call graph + trips
    def _trip_count(self, cond_comp: str) -> int:
        """Extract the loop bound: the largest (sane) integer constant in
        the condition computation or computations it calls (canonical XLA
        counted loops compare the induction variable against it)."""
        best = 1
        comps = [cond_comp]
        for op in self.computations.get(cond_comp, []):
            for mcall in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)",
                                     op["line"]):
                comps.append(mcall.group(1))
        for comp in comps:
            for op in self.computations.get(comp, []):
                mv = re.search(r"constant\((\d+)\)", op["line"])
                if mv:
                    v = int(mv.group(1))
                    if 1 <= v <= 10_000_000:
                        best = max(best, v)
        return best

    def _propagate(self) -> dict[str, float]:
        """Multiplier per computation (entry = 1; while bodies x trips;
        fusions/calls inherit)."""
        edges = defaultdict(list)           # comp -> [(child_comp, factor)]
        self.fusion_bodies: set[str] = set()
        for comp, ops in self.computations.items():
            for op in ops:
                if op["kind"] in ("fusion", "reduce", "map", "sort",
                                  "scatter", "reduce-window",
                                  "select-and-scatter", "all-reduce",
                                  "reduce-scatter", "custom-call"):
                    for mcall in re.finditer(
                            r"(?:calls|to_apply)=%?([\w.\-]+)", op["line"]):
                        self.fusion_bodies.add(mcall.group(1))
                if op["kind"] == "while":
                    mb = re.search(r"body=%?([\w.\-]+)", op["line"])
                    mcnd = re.search(r"condition=%?([\w.\-]+)", op["line"])
                    if mb and mcnd:
                        trips = self._trip_count(mcnd.group(1))
                        edges[comp].append((mb.group(1), trips))
                        edges[comp].append((mcnd.group(1), trips))
                elif op["kind"] in ("fusion", "call", "custom-call",
                                    "reduce", "map", "sort", "scatter",
                                    "reduce-window", "select-and-scatter",
                                    "all-reduce", "reduce-scatter"):
                    for mcall in re.finditer(
                            r"(?:calls|to_apply)=%?([\w.\-]+)", op["line"]):
                        edges[comp].append((mcall.group(1), 1))
                elif op["kind"] == "conditional":
                    for mbr in re.finditer(
                            r"(?:branch_computations=\{([^}]*)\}|"
                            r"(?:true|false)_computation=%?([\w.\-]+))",
                            op["line"]):
                        names = (mbr.group(1) or mbr.group(2) or "")
                        for nm in re.findall(r"%?([\w.\-]+)", names):
                            edges[comp].append((nm, 1))
        # find entry: computation not referenced by anyone
        referenced = {c for kids in edges.values() for c, _ in kids}
        mult = defaultdict(float)
        roots = [c for c in self.computations if c not in referenced]
        for r in roots:
            mult[r] = max(mult[r], 1.0)
        # BFS propagate (call graph is a DAG)
        frontier = list(roots)
        seen_edges = set()
        while frontier:
            c = frontier.pop()
            for child, f in edges.get(c, []):
                key = (c, child)
                add = mult[c] * f
                # accumulate contributions from multiple call sites
                if key not in seen_edges:
                    mult[child] += add
                    seen_edges.add(key)
                    frontier.append(child)
        return dict(mult)

    # ----------------------------------------------------------- queries
    def _operand_bytes(self, rest: str) -> int:
        total = 0
        for nm in re.findall(r"%([\w.\-]+)", rest.split("),")[0]):
            if nm in self.op_shape:
                total += _shape_elems_bytes(self.op_shape[nm])
        return total

    def flops(self) -> float:
        """2*prod(out)*prod(contracting) per dot, trip-count weighted."""
        total = 0.0
        for comp, ops in self.computations.items():
            m = self.multipliers.get(comp, 1.0)
            for op in ops:
                if op["kind"] != "dot":
                    continue
                out_elems = 0
                for dt, dims in _SHAPE_RE.findall(op["type"]):
                    n = 1
                    for d in dims.split(","):
                        if d:
                            n *= int(d)
                    out_elems += n
                # contracting size: lhs elements / (lhs batch+free elems).
                lhs = re.findall(r"%([\w.\-]+)", op["rest"])
                k = 1
                if lhs and lhs[0] in self.op_shape:
                    lhs_elems = 0
                    for dt, dims in _SHAPE_RE.findall(self.op_shape[lhs[0]]):
                        n = 1
                        for d in dims.split(","):
                            if d:
                                n *= int(d)
                        lhs_elems += n
                    mdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}",
                                      op["line"])
                    lhs_shape = _SHAPE_RE.search(self.op_shape[lhs[0]])
                    if mdims and lhs_shape:
                        dims = [int(d) for d in
                                lhs_shape.group(2).split(",") if d]
                        for ci in mdims.group(1).split(","):
                            if ci:
                                k *= dims[int(ci)]
                total += m * 2.0 * out_elems * k
        return total

    def collective_bytes(self, model_axis_size: int = 1,
                         pipe_axis_size: int = 1) -> dict:
        """Payload bytes per collective kind, trip-count weighted.  The
        payload is max(operand bytes, result bytes) — i.e. the full
        logical tensor crossing the interconnect.

        Also reports ``dtypes`` — per-kind payload bytes broken down by
        element dtype (what ACTUALLY crosses the wire, e.g. ``s8`` for the
        int8 FSA exchange) — and ``wire_dtype``: the dominant dtype of the
        FSA reduce-scatter stage.  Quantized payloads cannot be summed in
        the collective, so the int8 lowering emits the scatter half as an
        ``all-to-all``; the reduce-scatter stage's dtype is therefore read
        from reduce-scatter ops when present and all-to-all ops otherwise.

        With ``model_axis_size`` (and ``pipe_axis_size`` when the mesh
        has a real pipe axis) the per-op replica groups — or, for
        collective-permutes, the source-target cycle stride — classify
        every collective onto its mesh axis — ``axes`` maps
        {model | pipe | client | all} -> {kind -> payload bytes},
        ``axis_counts`` the trip-weighted op counts, and ``axis_dtypes``
        the per-axis dtype split — separating the tensor-parallel
        traffic (Megatron psums, seq-parallel psum_scatter/all_gather
        conjugates, expert-parallel token all_to_alls) from the FSA
        client wire.  ``wire_dtype`` is derived from the CLIENT axis
        only: a model-axis reduce-scatter (sequence parallelism) or
        all-to-all (MoE dispatch) must not masquerade as the FSA
        exchange format.
        """
        out = {k: 0.0 for k in COLLECTIVES}
        counts = {k: 0 for k in COLLECTIVES}
        dtypes: dict[str, dict[str, float]] = {k: {} for k in COLLECTIVES}
        axes: dict[str, dict[str, float]] = {}
        axis_counts: dict[str, dict[str, int]] = {}
        axis_dtypes: dict[str, dict[str, dict[str, float]]] = {}
        for comp, ops in self.computations.items():
            m = self.multipliers.get(comp, 1.0)
            for op in ops:
                kind = op["kind"].replace("-start", "")
                if kind.endswith("-done") or kind not in COLLECTIVES:
                    continue
                result_b = _shape_elems_bytes(op["type"])
                operand_b = self._operand_bytes(op["rest"])
                out[kind] += m * max(result_b, operand_b)
                counts[kind] += int(m)
                if kind == "collective-permute":
                    # ppermutes carry source_target_pairs, not
                    # replica_groups: classify from the cycle stride
                    axis = _classify_permute(_permute_stride(op["line"]),
                                             model_axis_size,
                                             pipe_axis_size)
                else:
                    axis = _classify_axis(_first_group(op["line"]),
                                          model_axis_size, pipe_axis_size)
                ax = axes.setdefault(axis, {})
                ax[kind] = ax.get(kind, 0.0) + m * max(result_b, operand_b)
                axc = axis_counts.setdefault(axis, {})
                axc[kind] = axc.get(kind, 0) + int(m)
                # dtype breakdown of the SAME payload the total counts:
                # the operand side when it is the larger (reduce-scatter
                # consumes n_devices x its result), else the result side
                text = op["type"] if result_b >= operand_b else " ".join(
                    self.op_shape[nm] for nm in
                    re.findall(r"%([\w.\-]+)", op["rest"].split("),")[0])
                    if nm in self.op_shape)
                axd = axis_dtypes.setdefault(axis, {}).setdefault(kind, {})
                for dt, dims in _SHAPE_RE.findall(text):
                    n = 1
                    for d in dims.split(","):
                        if d:
                            n *= int(d)
                    dtypes[kind][dt] = dtypes[kind].get(dt, 0.0) \
                        + m * n * _DTYPE_BYTES[dt]
                    axd[dt] = axd.get(dt, 0.0) + m * n * _DTYPE_BYTES[dt]
        out["counts"] = counts
        out["dtypes"] = dtypes
        out["axes"] = axes
        out["axis_counts"] = axis_counts
        out["axis_dtypes"] = axis_dtypes
        out["wire_dtype"] = self._wire_dtype(
            axis_dtypes.get("client") or axis_dtypes.get("all") or {})
        return out

    @staticmethod
    def _wire_dtype(dtypes: dict) -> str:
        """Dominant payload dtype of the FSA reduce-scatter stage (the
        collective carrying the client updates over the CLIENT axes):
        reduce-scatter when the payload is summable on the wire, else
        the all-to-all scatter half of the quantized exchange."""
        for kind in ("reduce-scatter", "all-to-all"):
            if dtypes.get(kind):
                return max(dtypes[kind], key=dtypes[kind].get)
        return ""

    def _operand_shape(self, rest: str, idx: int) -> str:
        """Result-type text of the idx-th operand, '' when unparseable."""
        names = re.findall(r"%([\w.\-]+)", rest.split("),")[0])
        if idx < len(names) and names[idx] in self.op_shape:
            return self.op_shape[names[idx]]
        return ""

    def _fusion_access(self, body: str):
        """(per-parameter read-byte overrides, result write-byte override)
        for a fusion computation that addresses operands through dynamic
        (update) slices.

        A parameter consumed ONLY via ``dynamic-slice`` ops is read at
        the summed slice size, not its full extent; a ROOT
        ``dynamic-update-slice`` writes its update chunk and leaves the
        aliased buffer parameter in place (charged 0 when the buffer has
        no other use in the body).  Anything with a full-tensor use keeps
        the full charge — the override only kicks in when every access
        is windowed."""
        cached = self._fusion_access_cache.get(body)
        if cached is not None:
            return cached
        ops = self.computations.get(body, [])
        pidx: dict[str, int] = {}
        for op in ops:
            if op["kind"] == "parameter":
                mi = re.match(r"(\d+)", op["rest"])
                if mi:
                    pidx[op["name"]] = int(mi.group(1))
        root = ops[-1] if ops else None
        for op in ops:
            if op["line"].startswith("ROOT"):
                root = op
        sliced: dict[str, int] = {}
        full_use: set[str] = set()
        for op in ops:
            if op["kind"] == "parameter":
                continue
            names = re.findall(r"%([\w.\-]+)", op["rest"].split("),")[0])
            if op["kind"] == "dynamic-slice" and names and names[0] in pidx:
                sliced[names[0]] = (sliced.get(names[0], 0)
                                    + _shape_elems_bytes(op["type"]))
                names = names[1:]               # index operands: scalars
            elif (op is root and op["kind"] == "dynamic-update-slice"
                  and names and names[0] in pidx):
                names = names[1:]               # aliased in-place buffer
            for nm in names:
                if nm in pidx:
                    full_use.add(nm)
        reads = {pidx[nm]: b for nm, b in sliced.items()
                 if nm not in full_use}
        result = None
        if root is not None and root["kind"] == "dynamic-update-slice":
            upd = self._operand_shape(root["rest"], 1)
            buf = re.findall(r"%([\w.\-]+)",
                             root["rest"].split("),")[0])[:1]
            if upd:
                result = _shape_elems_bytes(upd)
                if buf and buf[0] in pidx and buf[0] not in full_use:
                    reads[pidx[buf[0]]] = 0
        self._fusion_access_cache[body] = (reads, result)
        return reads, result

    def traffic_bytes(self) -> float:
        """HBM traffic proxy: operands+results of materializing ops in
        NON-fusion-body computations (fusion internals live in VMEM).

        Dynamic (update) slices are charged at SLICE size — the read +
        write of the addressed chunk — never the full sliced-into
        operand: while-loop grid emulations (interpret-mode Pallas
        kernels) and double-buffered ring steps address ONE chunk per
        trip, and charging the whole buffer each trip multiplied the
        memory term by the trip count (the PR 6 leftover that inflated
        the ``opt`` entry's roofline).  The rule applies both to
        standalone dynamic-(update-)slice ops and THROUGH fusions: a
        fusion parameter consumed only via dynamic-slice is read at
        slice size, and a fusion rooted at dynamic-update-slice writes
        its update chunk, not the aliased full buffer
        (:meth:`_fusion_access`)."""
        total = 0.0
        mat = {"fusion", "dot", "copy", "dynamic-update-slice",
               "dynamic-slice", "gather", "scatter", "reduce", "broadcast",
               "transpose", "convert", "reshape", "concatenate", "slice",
               "pad", "iota", "select", "add", "multiply",
               *COLLECTIVES}
        for comp, ops in self.computations.items():
            if comp in self.fusion_bodies:
                continue
            m = self.multipliers.get(comp, 1.0)
            for op in ops:
                kind = op["kind"]
                if kind not in mat:
                    continue
                if kind == "fusion":
                    mcall = re.search(r"calls=%?([\w.\-]+)", op["line"])
                    reads, res_b = (self._fusion_access(mcall.group(1))
                                    if mcall else ({}, None))
                    if reads or res_b is not None:
                        names = re.findall(r"%([\w.\-]+)",
                                           op["rest"].split("),")[0])
                        rb = 0
                        for i, nm in enumerate(names):
                            if nm not in self.op_shape:
                                continue
                            full = _shape_elems_bytes(self.op_shape[nm])
                            rb += min(reads[i], full) if i in reads else full
                        wb = (res_b if res_b is not None
                              else _shape_elems_bytes(op["type"]))
                        total += m * (rb + wb)
                        continue
                if kind == "dynamic-slice":
                    # read the addressed chunk, write the result: 2x the
                    # slice, not slice + full operand
                    total += m * 2 * _shape_elems_bytes(op["type"])
                    continue
                if kind == "dynamic-update-slice":
                    # in-place (aliased) update: read + write the update
                    # chunk (operand 1), not the whole buffer
                    upd = self._operand_shape(op["rest"], 1)
                    if upd:
                        total += m * 2 * _shape_elems_bytes(upd)
                        continue
                    # unparseable update operand: conservative old charge
                total += m * (_shape_elems_bytes(op["type"]) +
                              self._operand_bytes(op["rest"]))
        return total


def analyze(hlo_text: str, model_axis_size: int = 1,
            pipe_axis_size: int = 1) -> dict:
    mod = HloModule(hlo_text)
    return {"flops": mod.flops(),
            "collective_bytes": mod.collective_bytes(model_axis_size,
                                                     pipe_axis_size),
            "traffic_bytes": mod.traffic_bytes()}
