"""Dry-run sweep driver: every (arch x shape x mesh) via subprocesses.

Each combo runs in a fresh process (fresh XLA flags, no compile-cache
bleed).  Artifacts land in experiments/dryrun/*.json; a summary table is
appended to experiments/dryrun/sweep.log.

    PYTHONPATH=src python -m repro.launch.sweep [--archs a,b] [--shapes s]
        [--meshes 16x16,2x16x16] [--extra "--dsc"] [--timeout 900]
"""
from __future__ import annotations

import argparse
import subprocess
import sys
import time
from pathlib import Path

ALL_ARCHS = [
    "phi3.5-moe-42b-a6.6b", "musicgen-medium", "hymba-1.5b",
    "starcoder2-3b", "internvl2-26b", "olmoe-1b-7b", "starcoder2-15b",
    "qwen3-32b", "qwen2-0.5b", "xlstm-350m", "eris-gptneo-1.3b",
]
ALL_SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", default=",".join(ALL_ARCHS))
    ap.add_argument("--shapes", default=",".join(ALL_SHAPES))
    ap.add_argument("--meshes", default="16x16,2x16x16")
    ap.add_argument("--extra", default="")
    ap.add_argument("--tag", default="")
    ap.add_argument("--timeout", type=int, default=900)
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    log = out / "sweep.log"
    fails = []
    combos = [(a, s, m) for a in args.archs.split(",")
              for s in args.shapes.split(",")
              for m in args.meshes.split(",")]
    t_start = time.time()
    for i, (arch, shape, mesh) in enumerate(combos):
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--out", args.out]
        if mesh == "2x16x16":
            cmd.append("--multi-pod")
        if args.tag:
            cmd += ["--tag", args.tag]
        cmd += [c for c in args.extra.split() if c]
        t0 = time.time()
        try:
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout)
            ok = r.returncode == 0
            line = (r.stdout.strip().splitlines() or ["(no output)"])[-1]
            if not ok:
                line = "FAIL " + (r.stderr.strip().splitlines() or ["?"])[-1][:300]
        except subprocess.TimeoutExpired:
            ok, line = False, f"FAIL timeout {args.timeout}s"
        stamp = (f"[{i+1}/{len(combos)} {time.time()-t_start:7.0f}s "
                 f"{time.time()-t0:5.0f}s] {arch} {shape} {mesh}: {line}")
        print(stamp, flush=True)
        with log.open("a") as f:
            f.write(stamp + "\n")
        if not ok:
            fails.append((arch, shape, mesh))
    print(f"DONE {len(combos) - len(fails)}/{len(combos)} ok; fails: {fails}")
    with log.open("a") as f:
        f.write(f"DONE fails={fails}\n")
    return 1 if fails else 0


if __name__ == "__main__":
    sys.exit(main())
