"""Assigned input shapes + ShapeDtypeStruct input specs (no allocation).

  train_1k       seq_len=  1,024  global_batch= 256  (training; CI-sized
                                                      lowering regressions)
  train_4k       seq_len=  4,096  global_batch= 256  (training)
  prefill_32k    seq_len= 32,768  global_batch=  32  (inference-prefill)
  decode_32k     seq_len= 32,768  global_batch= 128  (inference-decode)
  long_500k      seq_len=524,288  global_batch=   1  (long-context-decode)

Decode shapes lower ``serve_step`` (ONE new token + KV cache of seq_len).
``long_500k`` uses the sub-quadratic path: recurrent state for ssm/hybrid,
sliding-window (8192) ring cache for full-attention archs (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import transformer as tr
from repro.models.config import ModelConfig

LONG_WINDOW = 8192


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES = {
    "train_1k": InputShape("train_1k", 1024, 256, "train"),
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def decode_window(cfg: ModelConfig, shape: InputShape) -> int | None:
    """Sliding-window policy: long_500k uses a ring cache for attention
    archs; ssm archs have no KV cache at all."""
    if shape.name == "long_500k" and cfg.family != "ssm":
        return LONG_WINDOW
    return None


def token_spec(cfg: ModelConfig, batch: int, seq: int):
    n_pre = cfg.n_frontend_tokens if cfg.frontend == "vlm" else 0
    spec = {"tokens": jax.ShapeDtypeStruct((batch, seq - n_pre), jnp.int32)}
    if cfg.frontend == "vlm":
        spec["frontend_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_frontend_tokens, cfg.d_frontend), jnp.float16)
    return spec


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of the step that
    this (arch, shape) pair lowers — weak-type-correct, shardable, no
    device allocation."""
    shape = SHAPES[shape_name]
    if shape.kind in ("train", "prefill"):
        return token_spec(cfg, shape.global_batch, shape.seq_len)
    # decode: one token + cache
    window = decode_window(cfg, shape)
    cache = jax.eval_shape(
        lambda: tr.init_cache(cfg, shape.global_batch, shape.seq_len,
                              window=window, dtype=jnp.float16))
    return {"token": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
            "cache": cache}
