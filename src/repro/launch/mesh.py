"""Production mesh definitions.

Defined as FUNCTIONS so importing this module never touches jax device
state.  Target hardware: TPU v5e, 256 chips/pod (16x16), optionally 2 pods.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if data is None:
        data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


# hardware constants for the roofline model (TPU v5e)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link (~per chip per direction)
HBM_BYTES = 16 * 1024**3          # 16 GiB per chip
