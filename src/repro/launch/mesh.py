"""Production mesh definitions.

Defined as FUNCTIONS so importing this module never touches jax device
state.  Target hardware: TPU v5e, 256 chips/pod (16x16), optionally 2 pods.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, pipe: int = 1):
    """The 512-device (2 pods) / 256-device production mesh.  ``pipe``
    carves pipeline stages out of the DATA dimension (16 % pipe == 0)
    so 'model' stays minor-most: TP rings ride the fastest stride-1
    links, pipe boundary ppermutes one stride up, and the client axes
    keep the slowest (cross-pod) hops."""
    if pipe < 1 or 16 % pipe != 0:
        raise ValueError(f"pipe={pipe} must divide the 16-wide data dim")
    if pipe == 1:
        shape = (2, 16, 16) if multi_pod else (16, 16)
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
        return jax.make_mesh(shape, axes)
    shape = ((2, 16 // pipe, pipe, 16) if multi_pod
             else (16 // pipe, pipe, 16))
    axes = (("pod", "data", "pipe", "model") if multi_pod
            else ("data", "pipe", "model"))
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int | None = None, model: int = 1,
                   pipe: int = 1):
    """Small (data[, pipe], model) mesh over whatever devices exist
    (tests / examples).  Validates the factorization up front —
    ``jax.make_mesh`` would otherwise silently build a mesh over a
    subset (or fail deep in device assignment) when the axis sizes don't
    divide the host devices."""
    n = len(jax.devices())
    if pipe < 1:
        raise ValueError(f"pipe axis size {pipe} must be >= 1")
    inner = model * pipe
    if model < 1 or inner < 1 or n % inner != 0:
        raise ValueError(
            f"model axis size {model} x pipe {pipe} must divide the {n} "
            f"available device(s) (n % (model*pipe) == "
            f"{n % inner if inner else 'undef'}); "
            f"pick --model-axis/--pp from the divisors of {n}, or raise "
            f"the device count via XLA_FLAGS=--xla_force_host_platform_"
            f"device_count=<n>")
    if data is None:
        data = n // inner
    if data < 1 or data * inner != n:
        raise ValueError(
            f"mesh ({data} data x {pipe} pipe x {model} model) needs "
            f"{data * inner} devices but {n} are available; leave "
            f"data=None to infer data = n // (model*pipe) = {n // inner}")
    if pipe == 1:
        return jax.make_mesh((data, model), ("data", "model"))
    return jax.make_mesh((data, pipe, model), ("data", "pipe", "model"))


# hardware constants for the roofline model (TPU v5e)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link (~per chip per direction)
HBM_BYTES = 16 * 1024**3          # 16 GiB per chip
