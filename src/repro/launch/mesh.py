"""Production mesh definitions.

Defined as FUNCTIONS so importing this module never touches jax device
state.  Target hardware: TPU v5e, 256 chips/pod (16x16), optionally 2 pods.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int | None = None, model: int = 1):
    """Small (data, model) mesh over whatever devices exist (tests /
    examples).  Validates the factorization up front — ``jax.make_mesh``
    would otherwise silently build a mesh over a subset (or fail deep in
    device assignment) when the axis sizes don't divide the host devices.
    """
    n = len(jax.devices())
    if model < 1 or n % model != 0:
        raise ValueError(
            f"model axis size {model} must divide the {n} available "
            f"device(s) (n % model == {n % model if model else 'undef'}); "
            f"pick --model-axis from the divisors of {n}, or raise the "
            f"device count via XLA_FLAGS=--xla_force_host_platform_"
            f"device_count=<n>")
    if data is None:
        data = n // model
    if data < 1 or data * model != n:
        raise ValueError(
            f"mesh ({data} data x {model} model) needs {data * model} "
            f"devices but {n} are available; leave data=None to infer "
            f"data = n // model = {n // model}")
    return jax.make_mesh((data, model), ("data", "model"))


# hardware constants for the roofline model (TPU v5e)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link (~per chip per direction)
HBM_BYTES = 16 * 1024**3          # 16 GiB per chip
