"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

Proves the distribution config is coherent without real hardware:
``.lower().compile()`` must succeed on the 16x16 single-pod mesh and the
2x16x16 multi-pod mesh; records memory_analysis / cost_analysis /
collective bytes (parsed from HLO) into a JSON artifact per combo.

Usage:
    python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k \
        [--multi-pod] [--dsc] [--out experiments/dryrun]
"""
# The dry-run (and ONLY the dry-run) needs 512 placeholder devices — set
# before ANY other import; jax locks the device count on first init.
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
from pathlib import Path # noqa: E402

import jax               # noqa: E402
import numpy as np       # noqa: E402


# --------------------------------------------------------------- dry run
def run_one(arch: str, shape_name: str, multi_pod: bool,
            use_dsc: bool = False, fsa: bool = True,
            grad_dtype: str = "float16", int8_wire: bool = False,
            save_hlo: bool = False, out_dir: str = "experiments/dryrun",
            tag: str = "", opt: str = "", pp: int = 1,
            microbatches: int = 1) -> dict:
    import dataclasses
    from repro.configs import get_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch.shapes import SHAPES
    from repro.launch import train as train_lib
    from repro.launch import serve as serve_lib
    from repro.models import shard_plan as sp_lib

    cfg = get_config(arch)
    # XLA *CPU* aborts on bf16 all-reduce (AllReducePromotion pass bug).
    # float16 has the same byte width, so every roofline quantity (bytes,
    # collective payloads, memory) is identical; real TPU runs use bf16.
    if cfg.dtype == "bfloat16":
        cfg = dataclasses.replace(cfg, dtype="float16")
    # perf-iteration knobs: --opt k=v,k=v (ModelConfig field overrides)
    if opt:
        kw = {}
        for item in opt.split(","):
            k, v = item.split("=")
            kw[k] = {"true": True, "false": False}.get(
                v.lower(), int(v) if v.isdigit() else v)
        cfg = dataclasses.replace(cfg, **kw)
    mesh = make_production_mesh(multi_pod=multi_pod, pipe=pp)
    shape = SHAPES[shape_name]
    n_dev = int(np.prod(mesh.devices.shape))
    t0 = time.time()
    if shape.kind == "train":
        settings = train_lib.TrainSettings(use_dsc=use_dsc, fsa=fsa,
                                           grad_dtype=grad_dtype,
                                           int8_wire=int8_wire,
                                           microbatches=microbatches)
        lowered = train_lib.lower_train_step(cfg, mesh, shape_name, settings)
    else:
        lowered = serve_lib.lower_step(cfg, mesh, shape_name)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):          # older jax: one dict per partition
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_size = sizes.get("model", 1)
    pipe_size = sizes.get("pipe", 1)
    from repro.launch import hlo_analysis
    deep = hlo_analysis.analyze(hlo, model_axis_size=int(model_size),
                                pipe_axis_size=int(pipe_size))

    from repro.models.transformer import (active_param_count, param_count,
                                          tp_plan)
    plan = tp_plan(cfg, int(model_size))
    from repro.dist import sharding as sh_lib
    n_tp_sharded = sum(s.dim >= 0 for s in jax.tree_util.tree_leaves(
        sh_lib.tp_specs(cfg, int(model_size))))
    pipe_plan = sp_lib.build_pipeline_plan(cfg, int(pipe_size),
                                           microbatches)
    record = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(d) for d in mesh.devices.shape),
        "devices": n_dev, "kind": shape.kind,
        "fsa": fsa, "use_dsc": use_dsc, "grad_dtype": grad_dtype,
        "int8_wire": int8_wire,
        "wire_dtype": deep["collective_bytes"].get("wire_dtype", ""),
        "tp": {"size": int(model_size), "attn": plan.attn,
               "ffn": plan.ffn, "vocab": plan.vocab, "moe": plan.moe,
               "mixer": plan.mixer, "seq": plan.seq,
               "ctx": plan.ctx, "seq_ce": plan.seq_ce,
               "sharded_leaves": int(n_tp_sharded)} if shape.kind == "train"
        else {"size": int(model_size)},
        "pp": {"size": int(pipe_size),
               "microbatches": int(microbatches),
               "layers_per_stage": pipe_plan.layers_per_stage,
               "bubble_fraction": pipe_plan.bubble_fraction},
        "param_bytes_per_device": sh_lib.param_bytes_per_device(cfg, mesh),
        "tag": tag,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "params": param_count(cfg),
        "active_params": active_param_count(cfg),
        # trip-count-aware HLO analysis (per device)
        "flops_per_device": deep["flops"],
        "bytes_accessed_per_device": deep["traffic_bytes"],
        "collective_bytes_per_device": deep["collective_bytes"],
        # raw XLA numbers (loop bodies counted once) for reference
        "xla_cost_flops": float(cost.get("flops", 0.0)),
        "xla_cost_bytes": float(cost.get("bytes accessed", 0.0)),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
        },
    }
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    suffix = ("_mp" if multi_pod else "") \
        + (f"_pp{pp}" if pp > 1 else "") + (f"_{tag}" if tag else "")
    fname = out / f"{arch.replace('.', '_')}__{shape_name}{suffix}.json"
    fname.write_text(json.dumps(record, indent=1))
    if save_hlo:
        (out / (fname.stem + ".hlo.txt")).write_text(hlo)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--dsc", action="store_true")
    ap.add_argument("--no-fsa", action="store_true",
                    help="FedAvg baseline layout (replicated optimizer)")
    ap.add_argument("--grad-dtype", default="float16")
    ap.add_argument("--int8-wire", action="store_true",
                    help="int8 blocks + f32 scales as the FSA wire format")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--opt", default="",
                    help="ModelConfig overrides, e.g. "
                         "seq_parallel=true,vocab=50176")
    ap.add_argument("--pp", type=int, default=1,
                    help="pipe axis size (carved out of the data dim)")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="1F1B microbatch count (train shapes)")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    rec = run_one(args.arch, args.shape, args.multi_pod, args.dsc,
                  fsa=not args.no_fsa, grad_dtype=args.grad_dtype,
                  int8_wire=args.int8_wire,
                  save_hlo=args.save_hlo, out_dir=args.out, tag=args.tag,
                  opt=args.opt, pp=args.pp, microbatches=args.microbatches)
    mem_gib = rec["memory"]["peak_bytes"] / 2**30
    print(f"OK {rec['arch']} {rec['shape']} mesh={rec['mesh']} "
          f"compile={rec['compile_s']}s peak={mem_gib:.2f}GiB/dev "
          f"flops/dev={rec['flops_per_device']:.3e} "
          f"coll={ {k: f'{v:.2e}' for k, v in rec['collective_bytes_per_device'].items() if isinstance(v, float) and v} }")


def _self_test():
    """Quick sanity of the record fields on the smallest arch."""
    rec = run_one("qwen2-0.5b", "train_4k", multi_pod=False)
    assert rec["flops_per_device"] > 0
    print(rec)


if __name__ == "__main__":
    main()
