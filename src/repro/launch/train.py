"""Distributed train step: FSA expressed as explicit TPU collectives.

The step is ONE fully-manual ``shard_map`` over ALL mesh axes — the
partial-auto mode (manual client axes + GSPMD model axis) trips a
jax-0.4.37 SPMD-partitioner check (``IsManualSubgroup``) on the 512-device
configs, so the lowering keeps nothing automatic:

  1. *FSA broadcast* — stored parameters are sharded over the client axes
     (each position = one aggregator's disjoint segment, Sec. 3.2.1); the
     shard_map in_spec requests them replicated, so XLA inserts the
     all-gather: x^t = sum_a m_(a) . x^t_(a)   (Algorithm 1 line 14).
  2. *Local update* — each client-axis position computes gradients on its
     own client group's batch shard.  The ``model`` axis runs
     manual-collective tensor parallelism under the family-generic shard
     plan (``models/shard_plan``): Megatron column/row pairs (QKV∘wo,
     gate/up∘down) wired through the ``tp_push``/``tp_pull`` conjugates,
     vocab-parallel embedding + unembed with the cross-entropy on
     vocab-sharded logits, expert-parallel MoE (expert-dim-sharded
     w_gate/w_up/w_down + token ``all_to_all`` dispatch/combine,
     replicated router with partial-grad psum), head-/channel-sharded
     recurrent mixers (mLSTM / hybrid mamba; the chunked scans run fully
     local), and optional sequence parallelism
     (``ModelConfig.seq_parallel``: the psum pairs become
     ``psum_scatter``/``all_gather`` conjugates so inter-region
     activations hold (B, S/tp, D)).  A config with NO shardable region
     falls back to the previous behavior: the model axis
     data-parallelizes the group batch when it divides, else replicates
     the group's computation.
  3. *DSC (optional)* — each client group shift-compresses its update
     v_k = C(g_k - s_k), s_k += gamma v_k, before transmission.
  4. *FSA aggregation* — the reduce-scatter stage.  Two wire formats:
       * ``grad_dtype`` (default bf16): ``psum_scatter`` over the client
         axes — each aggregator receives and reduces ONLY its disjoint
         shard (Theorem B.1: all_reduce == all_gather . reduce_scatter).
       * ``int8_wire``: each segment is quantized per-256-block
         (stochastic int8 + f32 scales, the Pallas ``kernels/quantize``
         pair), the int8 blocks + scales cross the mesh via ``all_to_all``
         (a sum cannot be performed in the quantized domain, so the
         reduce-scatter lowers to its scatter half; the reduction happens
         aggregator-side after dequantization).  With DSC, the shift
         references update from the quantized round trip — exactly the
         simulator's composed ``Int8RoundTrip`` compressor.
  5. *Shard-local optimizer* — aggregator a updates x_(a); optimizer state
     lives sharded (never materialized globally, ZeRO-style).

With ``fsa=False`` the baseline FedAvg schedule is emitted instead:
``pmean`` (all-reduce) of gradients + replicated optimizer.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import baselines as bl
from repro.core import secure_agg as sa_lib
from repro.core.compressors import RandP
from repro.core.eris import ROLE_SALTS
from repro.core.pipeline import (ARRIVAL_SALT, PAIRWISE_SALT, ArrivalModel,
                                 CohortSample, DSCCompress, split_round_keys)
from repro.core.settings import AsyncSettings, resolve_async
from repro.dist import sharding as sh
from repro.launch import shapes as shp
from repro.models import shard_plan as sp
from repro.models import transformer as tr
from repro.models.config import ModelConfig
from repro.optim import Optimizer, adam


@dataclasses.dataclass(frozen=True)
class TrainSettings:
    grad_dtype: str = "bfloat16"     # wire dtype for the un-quantized path
    int8_wire: bool = False          # int8 blocks + f32 scales on the mesh
    use_dsc: bool = False            # client-side shifted rand-p compression
    dsc_p: float = 0.1
    dsc_gamma: float = 0.5
    fused_wire: bool = True          # int8+DSC leaves through the one-pass
                                     # kernels/dsc_quantize kernel (mask,
                                     # shift-subtract, quantize, shift
                                     # update in a single VMEM sweep)
    shift_dtype: str = "float32"     # DSC shift-state residency (bf16
                                     # halves the resident s_k/s_agg bytes;
                                     # kernels widen to f32 on the fly)
    microbatches: int = 1            # 1F1B microbatch count when the mesh
                                     # has a real 'pipe' axis (the
                                     # wavefront scan runs m + p - 1 ticks;
                                     # bubble fraction (p-1)/(m+p-1))
    remat: bool = True
    fsa: bool = True                 # False => FedAvg all-reduce baseline
    capture_views: bool = False      # adversary-view tap: return, per
                                     # aggregator, the REAL observed wire
                                     # payload (dequantized int8 segments /
                                     # grad_dtype rows) as round output
    # ---- FedBuff-style buffered async aggregation (core.pipeline's
    # BufferedAggregate/ArrivalModel semantics on the mesh): arrivals fold
    # staleness-weighted updates into a per-segment buffer riding the
    # dsc-style state tree; params/optimizer apply every buffer_cadence
    # rounds.  Trivial arrivals + cadence 1 == the synchronous step
    # bit-exactly.
    # The flat fields are the deprecated spelling of
    # core.settings.AsyncSettings (shared with FLConfig); prefer
    # attaching one via ``async_``.  A knob set in BOTH places to
    # different values raises with the conflicting field named.
    async_buffer: bool = False
    buffer_cadence: int = 1
    staleness_alpha: float = 1.0
    delay_max: int = 0
    client_dropout: float = 0.0
    async_: Optional[AsyncSettings] = None
    # ---- composed-defense / failure knobs (the rounds.scenarios matrix
    # on the real mesh wire):
    ldp_eps: float = 0.0             # >0: per-client L2 clip + Gaussian
    ldp_delta: float = 1e-5          # noise BEFORE transmission (the
    ldp_clip: float = 1.0            # simulator's LDPNoise stage)
    secure_mask: bool = False        # Bonawitz pairwise wire masking
    agg_dropout: float = 0.0         # aggregator dropout (Appendix F.5)
    link_failure: float = 0.0        # client->aggregator link failure

    def async_settings(self) -> AsyncSettings:
        """The resolved async-runtime knobs (shared with FLConfig)."""
        return resolve_async("TrainSettings", self.async_, self)

    def arrival_model(self) -> ArrivalModel:
        return self.async_settings().arrival_model()

    def ldp_config(self) -> Optional[bl.LDPConfig]:
        if self.ldp_eps <= 0:
            return None
        return bl.LDPConfig(eps=self.ldp_eps, delta=self.ldp_delta,
                            clip=self.ldp_clip)


def dsc_stage(settings: TrainSettings) -> DSCCompress:
    """The simulator's DSC compression stage, shared verbatim by the
    distributed runtime (one DSC implementation, zero drift)."""
    return DSCCompress(compressor=RandP(p=settings.dsc_p),
                       gamma=settings.dsc_gamma)


def cohort_batch(batch, key: jax.Array, population: int, n_client: int):
    """Population-scale cohort selection for the distributed runtime: the
    SAME keyed :class:`CohortSample` draw the simulator/scan engines run
    inside their rounds, applied to population-leading batch arrays so
    the step's client-axis shard is the drawn cohort.  Returns
    ``(cohort_ids, gathered_batch)``."""
    cs = CohortSample(population=population, cohort=n_client)
    return cs.gather(split_round_keys(key), batch)


def dsc_spec_tree(cfg: ModelConfig, mesh: Mesh, settings: TrainSettings):
    """PartitionSpec tree of the DSC shift state — the ONE definition the
    shard_map specs, the jit in_shardings and ``init_dsc_state`` all
    derive from.  ``s_clients`` leaves are client-stacked on dim 0
    (each position holds its own s_k), TP-sharded over 'model' at the
    leaf's shifted TP dim; ``s_agg`` lives in the params' layout (store
    under FSA — each aggregator compensates its own segment — else the
    TP broadcast layout).  Without DSC: a replicated-scalar placeholder
    tree."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp_spec_tree = sh.tp_specs(cfg, int(sizes.get("model", 1)))
    pdim_tree = sh.pipe_dims(cfg, int(sizes.get("pipe", 1)))
    if not settings.use_dsc:
        specs = jax.tree.map(lambda s: P(), tp_spec_tree)
    else:
        ca = sh.client_axes(mesh)
        caxis = ca if len(ca) > 1 else ca[0]
        specs = {
            "s_clients": jax.tree.map(
                lambda s, pd: sh.dsc_store_spec(s, caxis, pd),
                tp_spec_tree, pdim_tree),
            "s_agg": (sh.store_specs(cfg, mesh) if settings.fsa
                      else sh.tp_param_in_specs(cfg, mesh)),
        }
    if settings.async_buffer:
        # the FedBuff buffer rides the same state tree: the accumulator
        # lives in the aggregators' segment layout (each position buffers
        # its own disjoint shard); weight/round counters are replicated
        return {"dsc": specs,
                "buffer": sh.buffer_spec_tree(cfg, mesh, fsa=settings.fsa)}
    return specs


def _client_size(mesh: Mesh) -> int:
    return sh.client_count(mesh)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _shard_map(f, mesh: Mesh, in_specs, out_specs):
    """Fully-manual shard_map (every mesh axis manual), compatible with
    both the jax>=0.5 top-level API and the 0.4.x experimental one."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def _quant_block_b(n_blocks: int) -> int:
    from repro.kernels import quantize as q_kernel
    from repro.kernels.common import largest_divisor
    return largest_divisor(n_blocks, q_kernel.BLOCK_B)


def _int8_wire_exchange(v: jax.Array, dim: int, seed: jax.Array,
                        caxis, n_client: int,
                        need_round_trip: bool, omega=None, rx_w=None):
    """The int8 reduce-scatter stage for one leaf.

    Splits ``v`` into its n_client FSA segments, quantizes each segment
    per-256-block, sends the int8 blocks + f32 scales over the client
    axes (``all_to_all`` — segment a of every client lands on aggregator
    a), dequantizes aggregator-side and reduces.  Returns
    ``(my_segment_mean f32, v_hat, rx_rows)`` where ``v_hat`` is the
    local quantized round trip of the FULL leaf (what the aggregators
    actually received) for DSC shift updates, or None when not
    requested, and ``rx_rows`` is the (n_client, m) matrix of dequantized
    per-client segments this aggregator received — the literal
    honest-but-curious adversary view of this leaf (the adversary-view
    tap; dead code unless captured, XLA drops it).
    """
    from repro.kernels import quantize as q_kernel
    lay = sh.wire_layout_for(v.shape, n_client)      # the (block, scale)
    m, mp = lay.shard_elems, lay.padded_elems        # geometry on the wire
    rows = sh.split_shards(v.astype(jnp.float32), dim, n_client)
    rows = jnp.pad(rows, ((0, 0), (0, mp - m)))
    block_b = _quant_block_b(n_client * lay.n_blocks)
    q, scale = q_kernel.quantize(rows.reshape(-1), seed, block_b=block_b,
                                 interpret=_interpret())
    q = q.reshape(n_client, mp)
    scale = scale.reshape(n_client, lay.n_blocks)

    def deq(qq, ss):
        out = q_kernel.dequantize(qq.reshape(-1), ss.reshape(-1),
                                  block_b=block_b, interpret=_interpret())
        return out.reshape(n_client, mp)[:, :m]

    v_hat = None
    if need_round_trip:
        v_hat = sh.merge_shards(deq(q, scale), dim, v.shape, n_client)
    # --- the wire: int8 blocks + f32 scales cross the client axes -------
    q_rx = jax.lax.all_to_all(q, caxis, 0, 0, tiled=True)
    s_rx = jax.lax.all_to_all(scale, caxis, 0, 0, tiled=True)
    rx_rows = deq(q_rx, s_rx)                         # (n_client, m) views
    if rx_w is not None:
        # failure-injected receive: rows weighted by live links,
        # renormalized by the live count (already folded into rx_w)
        my = jnp.einsum("k,km->m", rx_w, rx_rows)
    elif omega is None:
        my = rx_rows.mean(0)                          # aggregator-side sum
    else:
        # staleness/dropout-weighted arrivals (async buffer): each row is
        # one client's segment, discounted by its arrival weight
        my = jnp.einsum("k,km->m", omega, rx_rows) / n_client
    shard_shape = list(v.shape)
    shard_shape[dim] //= n_client
    return my.reshape(shard_shape), v_hat, rx_rows


def _fused_wire_exchange(g: jax.Array, s: jax.Array, dim: int,
                         seed_mask: jax.Array, seed_round: jax.Array,
                         caxis, n_client: int, p: float, gamma: float,
                         rx_w=None):
    """The int8+DSC wire stage for one leaf through the one-pass
    ``kernels/dsc_quantize`` kernel.

    Splits gradient AND shift state into the n_client FSA segments, runs
    mask-draw / shift-subtract / per-256-block stochastic int8 / shift
    update in a single VMEM sweep per segment batch (2 reads + the int8
    payload + 1 write, vs the compressor->quantize->dequantize chain's ~7
    HBM sweeps of the leaf), then ships the int8 blocks + f32 scales over
    the client axes exactly like :func:`_int8_wire_exchange`.  The shift
    state tracks the dequantized wire value in-register (the simulator's
    ``Int8RoundTrip`` composition).  Returns
    ``(my_segment_mean f32, s_new, rx_rows)``.
    """
    from repro.kernels import dsc_quantize as dq_kernel
    from repro.kernels import quantize as q_kernel
    lay = sh.wire_layout_for(g.shape, n_client)
    m, mp = lay.shard_elems, lay.padded_elems
    g_rows = jnp.pad(sh.split_shards(g.astype(jnp.float32), dim, n_client),
                     ((0, 0), (0, mp - m)))
    s_rows = jnp.pad(sh.split_shards(s.astype(jnp.float32), dim, n_client),
                     ((0, 0), (0, mp - m)))
    block_b = _quant_block_b(n_client * lay.n_blocks)
    q, scale, s_new_flat = dq_kernel.dsc_quantize(
        g_rows.reshape(-1), s_rows.reshape(-1), seed_mask, seed_round,
        p=p, gamma=gamma, block_b=block_b, interpret=_interpret())
    q = q.reshape(n_client, mp)
    scale = scale.reshape(n_client, lay.n_blocks)
    s_new = sh.merge_shards(s_new_flat.reshape(n_client, mp)[:, :m],
                            dim, g.shape, n_client).astype(s.dtype)
    # --- the wire: int8 blocks + f32 scales cross the client axes -------
    q_rx = jax.lax.all_to_all(q, caxis, 0, 0, tiled=True)
    s_rx = jax.lax.all_to_all(scale, caxis, 0, 0, tiled=True)
    rx = q_kernel.dequantize(q_rx.reshape(-1), s_rx.reshape(-1),
                             block_b=block_b, interpret=_interpret())
    rx_rows = rx.reshape(n_client, mp)[:, :m]
    shard_shape = list(g.shape)
    shard_shape[dim] //= n_client
    my = (rx_rows.mean(0) if rx_w is None
          else jnp.einsum("k,km->m", rx_w, rx_rows))
    return my.reshape(shard_shape), s_new, rx_rows


def make_train_step(cfg: ModelConfig, mesh: Mesh, opt: Optimizer,
                    settings: TrainSettings = TrainSettings()):
    """Returns (train_step, shardings dict)."""
    # GSPMD placement hints are meaningless (and illegal) inside the
    # fully-manual region — the model axis is manual like every other.
    if cfg.attn_batch_shard:
        cfg = dataclasses.replace(cfg, attn_batch_shard=False)
    if settings.async_buffer and settings.use_dsc:
        raise ValueError(
            "async_buffer does not compose with use_dsc: the Eq. 4 shift "
            "state tracks per-round aggregator receipts, which a cadence-"
            "delayed buffered apply breaks (int8_wire is the stateless "
            "wire format that does compose)")
    # one validation surface for the async knobs (shared with FLConfig):
    # raises naming the offending/conflicting field
    async_cfg = settings.async_settings()
    ca = sh.client_axes(mesh)
    caxis = ca if len(ca) > 1 else ca[0]
    n_client = _client_size(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_size = int(sizes.get("model", 1))
    tp_plan = tr.tp_plan(cfg, model_size)
    use_tp = tp_plan.active
    tp_spec_tree = sh.tp_specs(cfg, model_size)
    pipe_size = int(sizes.get("pipe", 1))
    pipe_plan = sp.build_pipeline_plan(cfg, pipe_size, settings.microbatches)
    use_pipe = pipe_plan.active
    if pipe_size > 1 and not use_pipe:
        raise ValueError(
            f"mesh has a pipe axis of size {pipe_size} but no pipeline "
            f"plan applies to family={cfg.family!r} with "
            f"n_layers={cfg.n_layers} (layers must split into equal "
            f"contiguous stages) — drop the pipe axis or pick a "
            f"divisible stage count")
    if settings.capture_views and pipe_size > 1:
        raise ValueError(
            "capture_views does not compose with a pipe axis yet: the "
            "adversary-view tap concatenates wire segments over 'model' "
            "only, so stage-sliced block leaves would alias")
    # ---- scenario-pack validation (rounds.scenarios on the mesh) --------
    ldp = settings.ldp_config()
    failures = settings.agg_dropout > 0 or settings.link_failure > 0
    if (ldp is not None or settings.secure_mask or failures) \
            and not settings.fsa:
        raise ValueError(
            "ldp/secure_mask/agg_dropout/link_failure are FSA wire "
            "compositions; fsa=False has no per-aggregator wire to "
            "defend or fail")
    if (ldp is not None or settings.secure_mask) and (use_tp or use_pipe):
        raise ValueError(
            "ldp/secure_mask need each client's FULL local gradient "
            "(global-L2 clip / whole-leaf mask rows); run them on a "
            "client-axes-only mesh (model=pipe=1)")
    if settings.secure_mask:
        if settings.use_dsc or settings.int8_wire:
            raise ValueError(
                "secure_mask composes with the plain f32 wire only: DSC "
                "shifts and int8 quantization transform each client's "
                "payload independently, so the pairwise masks would no "
                "longer cancel in the cross-client sum")
        if settings.grad_dtype != "float32":
            raise ValueError(
                "secure_mask needs grad_dtype='float32': the fixed-point "
                "pairwise masks cancel exactly in f32 partial sums; a "
                "bf16 wire would round them into O(1) noise")
        if failures or async_cfg.arrival_model().dropout > 0:
            raise ValueError(
                "secure_mask cannot compose with failures/client dropout: "
                "pairwise masks cancel only in the full-cohort sum (the "
                "simplified protocol has no dropout-recovery round)")
    if failures and settings.async_buffer:
        raise ValueError(
            "agg_dropout/link_failure compose with the synchronous FSA "
            "step; the async buffered runtime models client dropout "
            "through its ArrivalModel instead")
    pipe_dim_tree = sh.pipe_dims(cfg, pipe_size)
    scatter_dims = sh.fsa_scatter_dims(cfg, mesh) if settings.fsa else None
    store = sh.param_shardings(cfg, mesh, "store" if settings.fsa else "use")

    def loss_fn(params, batch, tp=None, pipe=None):
        if pipe is not None:
            return tr.pipeline_loss_fn(params, cfg, batch, tp=tp, pipe=pipe)
        return tr.loss_fn(params, cfg, batch, tp=tp)

    # ---------------- the manual (per-mesh-position) body -----------------
    def fsa_body(aidx_arr, midx_arr, pidx_arr, params, opt_state, dsc_ref,
                 batch, key, *, model_split):
        # params arrive as this position's pipe-stage x TP shards,
        # replicated over the client axes (the all-gather / broadcast
        # happened at the shard_map boundary); batch is this client
        # group's shard, further split over the model axis only when
        # model_split (the non-TP fallback).  aidx_arr/midx_arr/pidx_arr
        # are this position's slices of arange(n_client)/arange(model)/
        # arange(pipe) — the aggregator id and model/pipe coordinates
        # (axis_index lowers to an unsupported PartitionId under manual
        # SPMD, so all three ride in as sharded inputs instead).
        aidx = aidx_arr[0]
        buf_ref = None
        if settings.async_buffer:
            buf_ref, dsc_ref = dsc_ref["buffer"], dsc_ref["dsc"]
        # async arrivals: the SAME ArrivalModel draw the simulator's
        # BufferedAggregate runs, keyed on the replicated round key (no
        # aidx fold — every mesh position must agree on who arrived)
        arrival = async_cfg.arrival_model()
        alive = omega = w_round = None
        if settings.async_buffer and not arrival.trivial:
            _, alive, omega = arrival.draw(
                jax.random.fold_in(key, ARRIVAL_SALT), n_client)
            w_round = omega.mean()
        # failure injection (Appendix F.5 on the mesh): draws keyed on the
        # replicated round key salted with the eris engine's fail role —
        # every mesh position must agree on which aggregators/links died.
        # link_alive is [client k, aggregator a]; a dead link zeroes k's
        # contribution to a's segment, the aggregator renormalizes by its
        # live-receipt count, and a dead aggregator freezes its segment.
        # Leaves with no FSA scatter dim ride the healthy all-reduce —
        # only the per-aggregator wire can fail.
        agg_alive = link_alive = link_cnt = None
        if failures:
            ka, kl = jax.random.split(
                jax.random.fold_in(key, ROLE_SALTS["fail"]))
            agg_alive = jax.random.bernoulli(
                ka, 1.0 - settings.agg_dropout, (n_client,)
                ).astype(jnp.float32)
            link_alive = jax.random.bernoulli(
                kl, 1.0 - settings.link_failure, (n_client, n_client)
                ).astype(jnp.float32)
            link_cnt = jnp.maximum(link_alive.sum(0), 1.0)
        if use_tp or use_pipe:
            tp_rt = (tr.TPRuntime("model", model_size, midx_arr[0], tp_plan)
                     if use_tp else None)
            pipe_rt = (sp.PipeRuntime("pipe", pipe_size, pidx_arr[0],
                                      pipe_plan) if use_pipe else None)
            loss_val, grads = jax.value_and_grad(loss_fn)(
                params, batch, tp_rt, pipe_rt)
            # partial-kind leaves (replicated values consumed on local
            # shards, e.g. qk-norm scales) sum their grads over 'model'
            if use_tp:
                grads = sh.tp_grad_sync(grads, tp_spec_tree, "model")
            if use_pipe:
                # pipe-replicated leaves (embed/head/ln_f) accumulated
                # only where their stage touched them — sum over 'pipe';
                # stage-sliced block leaves are already complete locally
                grads = sh.pipe_grad_sync(grads, pipe_dim_tree, "pipe")
            loss_val = jax.lax.pmean(loss_val, caxis)
        else:
            loss_val, grads = jax.value_and_grad(loss_fn)(params, batch)
            loss_axes = (*ca, "model") if model_split else caxis
            loss_val = jax.lax.pmean(loss_val, loss_axes)
            if model_split:
                # model axis = intra-group data parallelism: the group's
                # update is the mean over its model-axis micro-shards
                grads = jax.tree.map(lambda g: jax.lax.pmean(g, "model"),
                                     grads)

        leaves, treedef = jax.tree.flatten(grads)
        if ldp is not None:
            # LDP stage (the simulator's LDPNoise, client-side on the
            # mesh): clip this position's FULL gradient to ldp.clip in
            # global L2, then add the calibrated Gaussian leaf-wise.
            # Noise keys fold the eris noise-role salt + leaf index +
            # aidx so every client draws independent noise.
            gn2_c = sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves)
            clip_s = jnp.minimum(
                1.0, ldp.clip / jnp.maximum(jnp.sqrt(gn2_c), 1e-12))
            sigma = bl.gaussian_sigma(ldp.eps, ldp.delta, ldp.clip)
            leaves = [
                (l.astype(jnp.float32) * clip_s
                 + sigma * jax.random.normal(
                     jax.random.fold_in(jax.random.fold_in(
                         key, ROLE_SALTS["noise"] + i), aidx), l.shape)
                 ).astype(l.dtype)
                for i, l in enumerate(leaves)]
        stage = dsc_stage(settings) if settings.use_dsc else None
        refs = (jax.tree.leaves(dsc_ref["s_clients"]) if settings.use_dsc
                else [None] * len(leaves))
        dims = (jax.tree.leaves(scatter_dims) if settings.fsa
                else [-1] * len(leaves))
        capture = settings.capture_views and settings.fsa

        # --- compress + FSA aggregation, leaf-wise ------------------------
        def wire_seed(i):
            k = jax.random.fold_in(jax.random.fold_in(key, 0x3177 + i), aidx)
            return jax.random.bits(k, dtype=jnp.uint32)

        def tap(rx):
            # adversary view of this leaf's received rows: a dropped
            # client transmitted nothing, so its captured row is zeroed
            if alive is not None:
                rx = rx * alive[:, None].astype(rx.dtype)
            return rx[None]

        def fail_tap(rx):
            # failure view: rows over dead links never arrived, and a
            # dead aggregator observed nothing at all
            if link_alive is not None:
                rx = rx * (link_alive[:, aidx] * agg_alive[aidx]
                           )[:, None].astype(rx.dtype)
            return rx

        # failure-weighted receive: aggregator aidx weights each received
        # row by its live links, renormalizes by the live count, and
        # zeroes out entirely when it died itself (replacing the uniform
        # 1/n_client mean of the healthy path)
        rx_w = None
        if link_alive is not None:
            rx_w = link_alive[:, aidx] * agg_alive[aidx] / link_cnt[aidx]

        out_leaves, refs_new, views = [], [], {}
        for i, (g, s_stk, dim) in enumerate(zip(leaves, refs, dims)):
            int8 = settings.int8_wire and settings.fsa and dim >= 0
            if settings.secure_mask:
                # Bonawitz pairwise wire masking: this position adds ITS
                # row of the fixed-point mask grid (key replicated — row
                # identity comes from aidx), so every row an aggregator
                # receives is masked while the masks cancel EXACTLY in
                # the f32 cross-client sum; the aggregate differs from
                # the unmasked wire only by the f32 absorption error of
                # adding O(mask-scale) values to O(grad) values.
                mk = jax.random.fold_in(
                    jax.random.fold_in(key, PAIRWISE_SALT), i)
                mrow = sa_lib.pairwise_mask_row(mk, aidx, n_client,
                                                int(g.size))
                g = g + mrow.reshape(g.shape).astype(g.dtype)
            if stage is not None:
                # client-side shifted compression (Sec. 3.2.2) — the SAME
                # DSCCompress stage the simulator pipeline runs, leaf-wise.
                # s_clients leaves are client-stacked (n_client, *shape),
                # so each client-axis position holds its OWN s_k ((1,)).
                k = jax.random.fold_in(jax.random.fold_in(key, i), aidx)
                s = s_stk[0]
                if int8 and settings.fused_wire:
                    # one-pass kernel: mask-draw + shift-subtract +
                    # quantize + round-trip shift update in a single VMEM
                    # sweep of the leaf (the wire payload and Eq. 4
                    # semantics are identical to the chain below)
                    agg, s_new, rx = _fused_wire_exchange(
                        g, s, dim, jax.random.bits(k, dtype=jnp.uint32),
                        wire_seed(i), caxis, n_client,
                        p=settings.dsc_p, gamma=settings.dsc_gamma,
                        rx_w=rx_w)
                    refs_new.append(s_new[None])
                    out_leaves.append(agg)
                    if capture:
                        views[str(i)] = fail_tap(rx)[None]
                    continue
                if int8:
                    # wire format INSIDE the shifted compressor: s_k must
                    # update with what the aggregators actually receive
                    # (the simulator's Int8RoundTrip(inner=RandP)).
                    v = stage.compressor(k, g.astype(s.dtype) - s)
                    agg, v_hat, rx = _int8_wire_exchange(
                        v, dim, wire_seed(i), caxis, n_client,
                        need_round_trip=True, rx_w=rx_w)
                    refs_new.append((s + stage.gamma * v_hat
                                     ).astype(s.dtype)[None])
                    out_leaves.append(agg)
                    if capture:
                        views[str(i)] = fail_tap(rx)[None]
                    continue
                v, s_new = stage.apply_leaf(k, g, s)
                refs_new.append(s_new[None])
                g = v.astype(g.dtype)
            if int8:
                agg, _, rx = _int8_wire_exchange(
                    g, dim, wire_seed(i), caxis, n_client,
                    need_round_trip=False, omega=omega, rx_w=rx_w)
                out_leaves.append(agg)
                if capture:
                    views[str(i)] = tap(fail_tap(rx))
                continue
            # un-quantized path: reduce-scatter in grad_dtype
            if omega is not None and not (capture and dim >= 0):
                # arrival-weighted FSA without the view tap: discount the
                # own contribution BEFORE the reduce (each client-axis
                # position is one client; the collective sums the
                # weighted rows)
                g = g * omega[aidx].astype(g.dtype)
            g = g.astype(settings.grad_dtype)
            if settings.fsa and dim >= 0:
                if capture:
                    # the tap needs the PER-CLIENT segments, so the
                    # reduce-scatter lowers to its scatter half (exactly
                    # like the int8 wire) and the reduction runs
                    # aggregator-side — same result, exposed payload
                    rows = sh.split_shards(g, dim, n_client)
                    rx = jax.lax.all_to_all(rows, caxis, 0, 0, tiled=True)
                    views[str(i)] = tap(fail_tap(rx)).astype(jnp.float32)
                    shard_shape = list(g.shape)
                    shard_shape[dim] //= n_client
                    if rx_w is not None:
                        agg_row = jnp.einsum("k,km->m",
                                             rx_w.astype(rx.dtype), rx)
                    elif omega is None:
                        agg_row = rx.mean(0)
                    else:
                        agg_row = jnp.einsum(
                            "k,km->m", omega.astype(rx.dtype), rx
                            ) / n_client
                    out_leaves.append(agg_row.reshape(shard_shape))
                    continue
                if link_alive is not None:
                    # failure-injected reduce-scatter: client aidx scales
                    # segment a by link_alive[aidx, a]/cnt_a BEFORE the
                    # collective, so the sum lands as the renormalized
                    # mean over live receipts; a dead aggregator's
                    # segment then freezes (zero update).
                    rows = sh.split_shards(g, dim, n_client)
                    w_l = (link_alive[aidx] / link_cnt).astype(g.dtype)
                    g = sh.merge_shards(rows * w_l[:, None], dim, g.shape,
                                        n_client)
                    g = jax.lax.psum_scatter(g, caxis,
                                             scatter_dimension=dim,
                                             tiled=True)
                    out_leaves.append(g * agg_alive[aidx].astype(g.dtype))
                    continue
                g = jax.lax.psum_scatter(g, caxis, scatter_dimension=dim,
                                         tiled=True)
            else:
                g = jax.lax.psum(g, caxis)
            out_leaves.append(g / n_client)

        grads = jax.tree.unflatten(treedef, out_leaves)
        if settings.use_dsc:
            # Eq. 4 aggregator-side shift compensation, on this
            # aggregator's own segment (every term it needs is local):
            # u = s_agg + mean_k v_k ;  s_agg <- s_agg + gamma mean_k v_k
            # — the DSCAggregate/FSASharded(use_dsc) composition the
            # simulator runs; without it the model update would miss the
            # mean-shift the clients subtracted.
            s_agg = dsc_ref["s_agg"]
            grads = jax.tree.map(lambda s, m: s + m.astype(s.dtype),
                                 s_agg, grads)
            s_agg = jax.tree.map(
                lambda s, u: s + settings.dsc_gamma * (u - s), s_agg, grads)
            dsc_ref = {"s_clients": jax.tree.unflatten(treedef, refs_new),
                       "s_agg": s_agg}

        # --- FedBuff buffer fold + cadence gate (async runtime) ----------
        do_apply = None
        if settings.async_buffer:
            # fold this round's arrival-weighted aggregate into the
            # buffer; the effective update is the buffer mean on apply
            # rounds and exactly zero in between.  Trivial arrivals +
            # cadence 1 make every step here an IEEE-exact identity
            # (0 + 1.0*u, u / 1.0), so the synchronous trajectory is
            # reproduced bit-for-bit.
            w_r = jnp.ones(()) if w_round is None else w_round
            u_acc = jax.tree.map(
                lambda b, g: b + w_r * g.astype(b.dtype),
                buf_ref["u"], grads)
            w_acc = buf_ref["w"] + w_r
            t_new = buf_ref["t"] + 1
            do_apply = (t_new % async_cfg.buffer_cadence) == 0
            grads = jax.tree.map(
                lambda u: jnp.where(do_apply,
                                    u / jnp.maximum(w_acc, 1e-12), 0.0),
                u_acc)
            buf_ref = {"u": jax.tree.map(
                           lambda u: jnp.where(do_apply, 0.0, u), u_acc),
                       "w": jnp.where(do_apply, 0.0, w_acc),
                       "t": t_new}

        # --- shard-local optimizer on this aggregator's segment ----------
        def my_shard(p, dim):
            if not settings.fsa or dim < 0:
                return p
            size = p.shape[dim] // n_client
            return jax.lax.dynamic_slice_in_dim(p, aidx * size, size,
                                                axis=dim)

        params_shard = (jax.tree.map(my_shard, params, scatter_dims)
                        if settings.fsa else params)
        grads = jax.tree.map(lambda g, p: g.astype(p.dtype), grads,
                             params_shard)
        delta, opt_state_new = opt.update(grads, opt_state, params_shard)
        params_new = jax.tree.map(jnp.add, params_shard, delta)
        if settings.async_buffer and async_cfg.buffer_cadence > 1:
            # the server consumes the buffer only on cadence rounds:
            # params and optimizer state hold still in between
            params_new = jax.tree.map(
                lambda a, b: jnp.where(do_apply, a, b),
                params_new, params_shard)
            opt_state_new = jax.tree.map(
                lambda a, b: jnp.where(do_apply, a, b),
                opt_state_new, opt_state)
        params_shard, opt_state = params_new, opt_state_new

        sq = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(grads)]
        if use_tp or use_pipe:
            # TP-sharded leaves are disjoint over 'model' and block leaves
            # disjoint over 'pipe'; replicated ones must not be
            # double-counted by either axis sum — bucket each leaf by the
            # axes it is actually sharded over and psum per bucket
            tps = [s.dim >= 0 and use_tp
                   for s in jax.tree.leaves(tp_spec_tree)]
            pps = [pd >= 0 and use_pipe
                   for pd in jax.tree.leaves(pipe_dim_tree)]
            zero = jnp.zeros((), jnp.float32)
            buckets: dict = {}
            for x, t, pl in zip(sq, tps, pps):
                axes = (("model",) if t else ()) + (("pipe",) if pl else ())
                buckets[axes] = buckets.get(axes, zero) + x
            gn2 = zero
            for axes, tot in buckets.items():
                gn2 = gn2 + (jax.lax.psum(tot, axes) if axes else tot)
        else:
            gn2 = sum(sq)
        gnorm = jax.lax.psum(gn2, caxis) ** 0.5 \
            if settings.fsa else jnp.sqrt(gn2)
        metrics = {"loss": loss_val.astype(jnp.float32), "grad_norm": gnorm}
        state_out = ({"dsc": dsc_ref, "buffer": buf_ref}
                     if settings.async_buffer else dsc_ref)
        if capture:
            return params_shard, opt_state, state_out, metrics, views
        return params_shard, opt_state, state_out, metrics

    # ------------------------- shard_map specs ---------------------------
    params_abs = jax.eval_shape(
        functools.partial(tr.init_params, cfg=cfg), jax.random.PRNGKey(0))
    # params enter TP-sharded over 'model', replicated over client axes
    # (the boundary all-gather is the FSA broadcast); they leave in the
    # composite store layout (model @ TP dim x client axes @ scatter dim)
    param_in_specs = sh.tp_param_in_specs(cfg, mesh)
    if settings.fsa:
        param_specs = sh.store_specs(cfg, mesh)
    else:
        param_specs = param_in_specs
    opt_abs_local = jax.eval_shape(opt.init, params_abs)
    # opt state mirrors params leaf-wise (positional; scalars replicated)
    opt_specs = sh.mirror_state_specs(
        params_abs,
        jax.tree.leaves(param_specs, is_leaf=lambda x: isinstance(x, P)),
        opt_abs_local, P())
    dsc_specs = dsc_spec_tree(cfg, mesh, settings)
    # adversary-view tap: each captured leaf is the (1, n_client, m)
    # per-client received-segment matrix of ONE aggregator; the leading
    # dim shards over the client axes (global (A, K, m)), the flattened
    # segment concatenates over 'model' (TP-local segments)
    if settings.capture_views and settings.fsa:
        view_spec = (P(caxis, None, "model")
                     if "model" in mesh.axis_names else P(caxis))
        view_specs = {str(i): view_spec
                      for i, d in enumerate(jax.tree.leaves(scatter_dims))
                      if d >= 0}
    else:
        view_specs = None

    def make_step():
        def step(params_stored, opt_state, dsc_ref, batch, key):
            # without an applicable TP plan the model axis falls back to
            # data-parallel over the group's batch when the global batch
            # divides all mesh positions, else replicated (see module
            # docstring)
            b0 = jax.tree.leaves(batch)[0].shape[0]
            model_split = (not use_tp and not use_pipe and model_size > 1
                           and b0 % (n_client * model_size) == 0)
            batch_spec = P((*ca, "model")) if model_split else P(caxis)
            pidx_spec = P("pipe") if "pipe" in mesh.axis_names else P()
            in_specs = (P(caxis),                                 # aidx
                        P("model"),                               # midx
                        pidx_spec,                                # pidx
                        param_in_specs,                           # broadcast
                        opt_specs, dsc_specs,
                        jax.tree.map(lambda _: batch_spec, batch),
                        P())
            out_specs = (param_specs, opt_specs, dsc_specs,
                         {"loss": P(), "grad_norm": P()})
            if view_specs is not None:
                out_specs = out_specs + (view_specs,)
            fn = _shard_map(
                functools.partial(fsa_body, model_split=model_split), mesh,
                in_specs=in_specs, out_specs=out_specs)
            return fn(jnp.arange(n_client, dtype=jnp.int32),
                      jnp.arange(model_size, dtype=jnp.int32),
                      jnp.arange(pipe_size, dtype=jnp.int32),
                      params_stored, opt_state, dsc_ref, batch, key)
        return step

    return make_step(), {"store": store,
                         "use": sh.param_shardings(cfg, mesh, "use")}


def abstract_train_state(cfg: ModelConfig, mesh: Mesh, opt: Optimizer,
                         settings: TrainSettings = TrainSettings()):
    """ShapeDtypeStructs of (params_stored, opt_state, dsc_ref).

    All three are GLOBAL (pre-shard_map) views with FULL logical shapes —
    the composite store sharding (model axis @ TP dim x client axes @
    scatter dim) and the shard_map specs do the slicing; optimizer/DSC
    state never materializes unsharded on a device (ZeRO-style).
    """
    n_client = _client_size(mesh)
    params = jax.eval_shape(
        functools.partial(tr.init_params, cfg=cfg), jax.random.PRNGKey(0))
    opt_state_global = jax.eval_shape(opt.init, params)
    if settings.use_dsc:
        sdt = sh.shift_state_dtype(settings.shift_dtype)
        dsc_global = {
            "s_clients": jax.tree.map(
                lambda p: jax.ShapeDtypeStruct((n_client, *p.shape), sdt),
                params),
            "s_agg": jax.tree.map(
                lambda p: jax.ShapeDtypeStruct(p.shape, sdt), params),
        }
    else:
        dsc_global = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct((), jnp.float32), params)
    if settings.async_buffer:
        dsc_global = {"dsc": dsc_global, "buffer": {
            "u": jax.tree.map(
                lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
                params),
            "w": jax.ShapeDtypeStruct((), jnp.float32),
            "t": jax.ShapeDtypeStruct((), jnp.int32),
        }}
    return params, opt_state_global, dsc_global


def init_dsc_state(cfg: ModelConfig, mesh: Mesh,
                   settings: TrainSettings):
    """Materialize the (sharded) DSC shift state: zero client refs
    stacked over the client axes + a zero aggregator-side shift in the
    params' store layout (or a replicated-scalar tree when DSC is off —
    the step function's placeholder).  Layout = :func:`dsc_spec_tree`."""
    params_abs = jax.eval_shape(
        functools.partial(tr.init_params, cfg=cfg), jax.random.PRNGKey(0))
    if not settings.use_dsc:
        refs = jax.tree.map(lambda p: jnp.zeros((), jnp.float32),
                            params_abs)
        if not settings.async_buffer:
            return refs
    else:
        n_client = _client_size(mesh)
        sdt = sh.shift_state_dtype(settings.shift_dtype)
        refs = {
            "s_clients": jax.tree.map(
                lambda p: jnp.zeros((n_client, *p.shape), sdt), params_abs),
            "s_agg": jax.tree.map(
                lambda p: jnp.zeros(p.shape, sdt), params_abs),
        }
    if settings.async_buffer:
        refs = {"dsc": refs, "buffer": {
            "u": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params_abs),
            "w": jnp.zeros(()),
            "t": jnp.zeros((), jnp.int32),
        }}
    shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        dsc_spec_tree(cfg, mesh, settings),
        is_leaf=lambda x: isinstance(x, P))
    return jax.device_put(refs, shardings)


def lower_train_step(cfg: ModelConfig, mesh: Mesh,
                     shape_name: str = "train_4k",
                     settings: TrainSettings = TrainSettings(),
                     opt: Optional[Optimizer] = None):
    """jit(...).lower() of the train step for (cfg, mesh, shape)."""
    opt = opt or adam(3e-4)
    step, shardings = make_train_step(cfg, mesh, opt, settings)
    params, opt_state, dsc_ref = abstract_train_state(cfg, mesh, opt,
                                                      settings)
    batch = shp.input_specs(cfg, shape_name)
    batch_sh = sh.batch_shardings(cfg, mesh, batch)
    store = shardings["store"]
    opt_sh = sh.opt_state_shardings(cfg, mesh, opt, params)
    rep = NamedSharding(mesh, P())
    dsc_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                          dsc_spec_tree(cfg, mesh, settings),
                          is_leaf=lambda x: isinstance(x, P))
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    jitted = jax.jit(
        step,
        in_shardings=(store, opt_sh, dsc_sh, batch_sh, rep),
        donate_argnums=(0, 1, 2))
    with mesh:
        return jitted.lower(params, opt_state, dsc_ref, batch, key)


def main():  # pragma: no cover - thin CLI over the factories
    """CLI: distributed FSA training on the host devices.

        XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
            --smoke --steps 20
    """
    import argparse
    import time
    from repro.configs import get_config
    from repro.data import lm_token_batches
    from repro.launch.mesh import make_host_mesh
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family variant (CPU-sized)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--dsc", action="store_true")
    ap.add_argument("--int8-wire", action="store_true")
    ap.add_argument("--data-axis", type=int, default=None)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--pp", type=int, default=1,
                    help="pipe axis size (contiguous layer stages)")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="1F1B microbatch count (must divide --batch)")
    ap.add_argument("--save", default=None, metavar="DIR",
                    help="write the final params as a sharded checkpoint "
                         "directory (the ServeEngine.from_checkpoint "
                         "handoff format)")
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    mesh = make_host_mesh(data=args.data_axis, model=args.model_axis,
                          pipe=args.pp)
    opt = adam(args.lr)
    settings = TrainSettings(use_dsc=args.dsc, grad_dtype="float32",
                             int8_wire=args.int8_wire,
                             microbatches=args.microbatches)
    step, shardings = make_train_step(cfg, mesh, opt, settings)
    key = jax.random.PRNGKey(0)
    with mesh:
        params = jax.device_put(tr.init_params(key, cfg),
                                shardings["store"])
        opt_state = opt.init(params)
        dsc_ref = init_dsc_state(cfg, mesh, settings)
        toks = lm_token_batches(key, 1, args.batch, args.seq, cfg.vocab)[0]
        batch = {"tokens": toks}
        jstep = jax.jit(step)
        t0 = time.time()
        for i in range(args.steps):
            params, opt_state, dsc_ref, m = jstep(
                params, opt_state, dsc_ref, batch, jax.random.PRNGKey(i))
            print(f"step {i:3d} loss={float(m['loss']):.4f} "
                  f"({time.time()-t0:.1f}s)", flush=True)
        if args.save:
            from repro.checkpoint import msgpack_ckpt as ck
            ck.save_sharded(args.save, params)
            print(f"saved sharded checkpoint -> {args.save}", flush=True)


if __name__ == "__main__":
    main()
