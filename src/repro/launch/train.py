"""Distributed train step: FSA expressed as explicit TPU collectives.

The step is a ``shard_map`` over the client axes (``pod``/``data``) with
the ``model`` axis left to GSPMD (tensor parallelism stays automatic):

  1. *FSA broadcast* — stored parameters are sharded over the client axes
     (each position = one aggregator's disjoint segment, Sec. 3.2.1); the
     shard_map in_spec requests them replicated, so XLA inserts the
     all-gather: x^t = sum_a m_(a) . x^t_(a)   (Algorithm 1 line 14).
  2. *Local update* — each client-axis position computes gradients on its
     own client group's batch shard (no cross-client reduction yet).
  3. *DSC (optional)* — each client group shift-compresses its update
     v_k = C(g_k - s_k), s_k += gamma v_k, before transmission.
  4. *FSA aggregation* — ``psum_scatter`` over the client axes: each
     aggregator receives and reduces ONLY its disjoint shard (this is the
     reduce-scatter that replaces FedAvg's all-reduce; Theorem B.1 is the
     algebraic identity all_reduce == all_gather . reduce_scatter).
     Gradients cross the wire in ``grad_dtype`` (bf16 halves the payload).
  5. *Shard-local optimizer* — aggregator a updates x_(a); optimizer state
     lives sharded (never materialized globally, ZeRO-style).

With ``fsa=False`` the baseline FedAvg schedule is emitted instead:
``pmean`` (all-reduce) of gradients + replicated optimizer.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.compressors import RandP
from repro.core.pipeline import DSCCompress
from repro.dist import sharding as sh
from repro.launch import shapes as shp
from repro.models import transformer as tr
from repro.models.config import ModelConfig
from repro.optim import Optimizer, adam


@dataclasses.dataclass(frozen=True)
class TrainSettings:
    grad_dtype: str = "bfloat16"     # wire dtype for the FSA reduce-scatter
    use_dsc: bool = False            # client-side shifted rand-p compression
    dsc_p: float = 0.1
    dsc_gamma: float = 0.5
    remat: bool = True
    fsa: bool = True                 # False => FedAvg all-reduce baseline


def dsc_stage(settings: TrainSettings) -> DSCCompress:
    """The simulator's DSC compression stage, shared verbatim by the
    distributed runtime (one DSC implementation, zero drift)."""
    return DSCCompress(compressor=RandP(p=settings.dsc_p),
                       gamma=settings.dsc_gamma)


def _client_size(mesh: Mesh) -> int:
    return sh.client_count(mesh)


def _shard_map(f, mesh: Mesh, in_specs, out_specs, manual_axes):
    """shard_map with the non-'model' axes manual, compatible with both
    the jax>=0.5 top-level API and the 0.4.x experimental one."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs,
                             axis_names=set(manual_axes), check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    auto = frozenset(mesh.axis_names) - set(manual_axes)
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False, auto=auto)


def make_train_step(cfg: ModelConfig, mesh: Mesh, opt: Optimizer,
                    settings: TrainSettings = TrainSettings()):
    """Returns (train_step, shardings dict)."""
    ca = sh.client_axes(mesh)
    caxis = ca if len(ca) > 1 else ca[0]
    n_client = _client_size(mesh)
    scatter_dims = sh.fsa_scatter_dims(cfg, mesh) if settings.fsa else None
    store = sh.param_shardings(cfg, mesh, "store" if settings.fsa else "use")

    def loss_fn(params, batch):
        return tr.loss_fn(params, cfg, batch)

    # ---------------- the manual (per-client-axis-position) body ----------
    def fsa_body(aidx_arr, params, opt_state, dsc_ref, batch, key):
        # params arrive replicated over client axes (the all-gather /
        # broadcast happened at the shard_map boundary); batch is this
        # client group's shard.  aidx_arr is this position's slice of
        # arange(n_client) — the aggregator id (axis_index lowers to an
        # unsupported PartitionId under partial-auto SPMD, so it rides in
        # as a sharded input instead).
        aidx = aidx_arr[0]
        loss_val, grads = jax.value_and_grad(loss_fn)(params, batch)
        loss_val = jax.lax.pmean(loss_val, caxis)

        if settings.use_dsc:
            # client-side shifted compression (Sec. 3.2.2) on the local
            # update, before transmission — the SAME DSCCompress stage the
            # simulator pipeline runs, applied leaf-wise.  dsc_ref leaves
            # are client-stacked (n_client, *param_shape), so each
            # client-axis position holds its OWN s_k (local (1, ...)).
            stage = dsc_stage(settings)
            leaves, treedef = jax.tree.flatten(grads)
            refs = jax.tree.leaves(dsc_ref)
            vs, refs_new = [], []
            for i, (g, s_stk) in enumerate(zip(leaves, refs)):
                k = jax.random.fold_in(jax.random.fold_in(key, i), aidx)
                v, s_new = stage.apply_leaf(k, g, s_stk[0])
                vs.append(v.astype(g.dtype))
                refs_new.append(s_new[None])
            grads = jax.tree.unflatten(treedef, vs)
            dsc_ref = jax.tree.unflatten(treedef, refs_new)

        # --- FSA aggregation: reduce-scatter the wire-dtype update -------
        def aggregate(g, dim):
            g = g.astype(settings.grad_dtype)
            if settings.fsa and dim >= 0:
                g = jax.lax.psum_scatter(g, caxis, scatter_dimension=dim,
                                         tiled=True)
            else:
                g = jax.lax.psum(g, caxis)
            return g / n_client

        if settings.fsa:
            grads = jax.tree.map(aggregate, grads, scatter_dims)
        else:
            grads = jax.tree.map(lambda g: aggregate(g, -1), grads)

        # --- shard-local optimizer on this aggregator's segment ----------
        def my_shard(p, dim):
            if not settings.fsa or dim < 0:
                return p
            size = p.shape[dim] // n_client
            return jax.lax.dynamic_slice_in_dim(p, aidx * size, size,
                                                axis=dim)

        params_shard = (jax.tree.map(my_shard, params, scatter_dims)
                        if settings.fsa else params)
        grads = jax.tree.map(lambda g, p: g.astype(p.dtype), grads,
                             params_shard)
        delta, opt_state = opt.update(grads, opt_state, params_shard)
        params_shard = jax.tree.map(jnp.add, params_shard, delta)

        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        gnorm = jax.lax.psum(gnorm * gnorm, caxis) ** 0.5 \
            if settings.fsa else gnorm
        metrics = {"loss": loss_val.astype(jnp.float32), "grad_norm": gnorm}
        return params_shard, opt_state, dsc_ref, metrics

    # ------------------------- shard_map specs ---------------------------
    def spec_of_store(leaf_dim):
        if leaf_dim is None or leaf_dim < 0 or not settings.fsa:
            return P()
        parts = [None] * (leaf_dim + 1)
        parts[leaf_dim] = caxis
        return P(*parts)

    params_abs = jax.eval_shape(
        functools.partial(tr.init_params, cfg=cfg), jax.random.PRNGKey(0))
    if settings.fsa:
        param_specs = jax.tree.map(spec_of_store, scatter_dims)
    else:
        param_specs = jax.tree.map(lambda _: P(), params_abs)
    opt_abs_local = jax.eval_shape(opt.init, params_abs)
    # opt state mirrors params leaf-wise (positional; scalars replicated)
    opt_specs = sh.mirror_state_specs(
        params_abs,
        jax.tree.leaves(param_specs, is_leaf=lambda x: isinstance(x, P)),
        opt_abs_local, P())
    # DSC refs are client-stacked on dim 0 -> shard dim 0 over client axes
    dsc_specs = jax.tree.map(lambda _: P(caxis) if settings.use_dsc else P(),
                             params_abs)

    batch_spec_leaf = P(caxis)

    def make_step():
        def step(params_stored, opt_state, dsc_ref, batch, key):
            in_specs = (P(caxis),                                 # aidx
                        jax.tree.map(lambda _: P(), params_abs),  # broadcast
                        opt_specs, dsc_specs,
                        jax.tree.map(lambda _: batch_spec_leaf, batch),
                        P())
            out_specs = (param_specs, opt_specs, dsc_specs,
                         {"loss": P(), "grad_norm": P()})
            fn = _shard_map(fsa_body, mesh,
                            in_specs=in_specs, out_specs=out_specs,
                            manual_axes=ca)
            return fn(jnp.arange(n_client, dtype=jnp.int32),
                      params_stored, opt_state, dsc_ref, batch, key)
        return step

    return make_step(), {"store": store,
                         "use": sh.param_shardings(cfg, mesh, "use")}


def abstract_train_state(cfg: ModelConfig, mesh: Mesh, opt: Optimizer,
                         settings: TrainSettings = TrainSettings()):
    """ShapeDtypeStructs of (params_stored, opt_state, dsc_ref).

    With FSA, optimizer/DSC state are *shard-local* (1/n_client of each
    FSA-sharded dim) — they are shard_map-internal layouts.
    """
    n_client = _client_size(mesh) if settings.fsa else 1
    scatter_dims = sh.fsa_scatter_dims(cfg, mesh)
    params = jax.eval_shape(
        functools.partial(tr.init_params, cfg=cfg), jax.random.PRNGKey(0))

    def shard_shape(p, dim):
        if not settings.fsa or dim < 0:
            return p
        shape = list(p.shape)
        shape[dim] //= n_client
        return jax.ShapeDtypeStruct(tuple(shape), p.dtype)

    params_shard = jax.tree.map(shard_shape, params, scatter_dims)
    opt_state = jax.eval_shape(opt.init, params_shard)

    # global (pre-shard_map) views: params stored globally have FULL shape
    # with store sharding; opt/dsc state globally also full shape (their
    # shard_map spec re-slices them)
    opt_state_global = jax.eval_shape(opt.init, params)
    if settings.use_dsc:
        dsc_global = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct((n_client, *p.shape),
                                           jnp.float32), params)
    else:
        dsc_global = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct((), jnp.float32), params)
    return params, opt_state_global, dsc_global


def lower_train_step(cfg: ModelConfig, mesh: Mesh,
                     shape_name: str = "train_4k",
                     settings: TrainSettings = TrainSettings(),
                     opt: Optional[Optimizer] = None):
    """jit(...).lower() of the train step for (cfg, mesh, shape)."""
    opt = opt or adam(3e-4)
    step, shardings = make_train_step(cfg, mesh, opt, settings)
    params, opt_state, dsc_ref = abstract_train_state(cfg, mesh, opt,
                                                      settings)
    batch = shp.input_specs(cfg, shape_name)
    batch_sh = sh.batch_shardings(cfg, mesh, batch)
    store = shardings["store"]
    opt_sh = sh.opt_state_shardings(cfg, mesh, opt, params)
    rep = NamedSharding(mesh, P())
    ca = sh.client_axes(mesh)
    caxis = ca if len(ca) > 1 else ca[0]
    dsc_sh = jax.tree.map(
        lambda _: NamedSharding(mesh, P(caxis)) if settings.use_dsc else rep,
        dsc_ref)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    jitted = jax.jit(
        step,
        in_shardings=(store, opt_sh, dsc_sh, batch_sh, rep),
        donate_argnums=(0, 1, 2))
    with mesh:
        return jitted.lower(params, opt_state, dsc_ref, batch, key)


def main():  # pragma: no cover - thin CLI over the factories
    """CLI: distributed FSA training on the host devices.

        XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
            --smoke --steps 20
    """
    import argparse
    import time
    from repro.configs import get_config
    from repro.data import lm_token_batches
    from repro.launch.mesh import make_host_mesh
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family variant (CPU-sized)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--dsc", action="store_true")
    ap.add_argument("--data-axis", type=int, default=None)
    ap.add_argument("--model-axis", type=int, default=1)
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    mesh = make_host_mesh(data=args.data_axis, model=args.model_axis)
    opt = adam(args.lr)
    settings = TrainSettings(use_dsc=args.dsc, grad_dtype="float32")
    step, shardings = make_train_step(cfg, mesh, opt, settings)
    key = jax.random.PRNGKey(0)
    n_client = _client_size(mesh)
    with mesh:
        params = jax.device_put(tr.init_params(key, cfg),
                                shardings["store"])
        opt_state = opt.init(params)
        if args.dsc:
            dsc_ref = jax.tree.map(
                lambda p: jnp.zeros((n_client, *p.shape), jnp.float32),
                params)
            dsc_ref = jax.device_put(dsc_ref, jax.tree.map(
                lambda _: NamedSharding(
                    mesh, P(sh.client_axes(mesh)[0])), dsc_ref))
        else:
            dsc_ref = jax.tree.map(lambda p: jnp.zeros((), jnp.float32),
                                   params)
        toks = lm_token_batches(key, 1, args.batch, args.seq, cfg.vocab)[0]
        batch = {"tokens": toks}
        jstep = jax.jit(step)
        t0 = time.time()
        for i in range(args.steps):
            params, opt_state, dsc_ref, m = jstep(
                params, opt_state, dsc_ref, batch, jax.random.PRNGKey(i))
            print(f"step {i:3d} loss={float(m['loss']):.4f} "
                  f"({time.time()-t0:.1f}s)", flush=True)


if __name__ == "__main__":
    main()
