"""Distributed serve steps: prefill (full forward) + decode (one token).

Decode shapes lower ``serve_step`` — ONE new token against a KV cache of
``seq_len`` — per the assignment.  Params are in the *use* layout
(tensor-parallel, replicated over client axes); caches shard the batch dim
over client axes and kv-heads/state over 'model'.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist import sharding as sh
from repro.launch import shapes as shp
from repro.models import transformer as tr
from repro.models.config import ModelConfig


def make_decode_step(cfg: ModelConfig, mesh: Mesh,
                     window: Optional[int] = None):
    def serve_step(params, cache, token, pos):
        logits, new_cache = tr.decode_step(params, cfg, cache, token, pos,
                                           window=window)
        return logits, new_cache
    return serve_step


def make_prefill_step(cfg: ModelConfig, mesh: Mesh):
    def prefill_step(params, batch):
        logits, caches, _ = tr.forward(params, cfg, batch["tokens"],
                                       batch.get("frontend_embeds"),
                                       mode="prefill", remat=False)
        # return only the last-position logits (next-token sampling) + cache
        return logits[:, -1:], caches
    return prefill_step


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(
        functools.partial(tr.init_params, cfg=cfg), jax.random.PRNGKey(0))


def lower_serve_step(cfg: ModelConfig, mesh: Mesh, shape_name: str):
    """jit(...).lower() of the prefill or decode step for (cfg, shape)."""
    shape = shp.SHAPES[shape_name]
    params = abstract_params(cfg)
    use = sh.param_shardings(cfg, mesh, "use")
    rep = NamedSharding(mesh, P())
    if shape.kind == "prefill":
        step = make_prefill_step(cfg, mesh)
        batch = shp.input_specs(cfg, shape_name)
        batch_sh = sh.batch_shardings(cfg, mesh, batch)
        jitted = jax.jit(step, in_shardings=(use, batch_sh))
        with mesh:
            return jitted.lower(params, batch)
    window = shp.decode_window(cfg, shape)
    step = make_decode_step(cfg, mesh, window)
    specs = shp.input_specs(cfg, shape_name)
    cache_sh = sh.cache_shardings(cfg, mesh, specs["cache"])
    tok_sh = sh.batch_shardings(cfg, mesh, specs["token"])
    jitted = jax.jit(step,
                     in_shardings=(use, cache_sh, tok_sh, rep),
                     out_shardings=(tok_sh, cache_sh),
                     donate_argnums=(1,))
    with mesh:
        return jitted.lower(params, specs["cache"], specs["token"],
                            specs["pos"])
