"""Serving entry points: ``ServeSettings`` + ``ServeEngine`` + lowering.

This is the serving twin of ``launch/train.py``: one settings object
(:class:`repro.serve.ServeSettings`) drives both the online engine
(:class:`repro.serve.ServeEngine` — continuous batching over the paged
KV cache) and the static lowering path used by dryruns and HLO audits
(:func:`lower_step`, which compiles the prefill / single-token decode
step for a named production shape).

Params are in the *use* layout (tensor-parallel, replicated over client
axes); caches shard the batch dim over client axes and kv-heads/state
over 'model'.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist import sharding as sh
from repro.launch import shapes as shp
from repro.models import transformer as tr
from repro.models.config import ModelConfig
from repro.serve import (BlockAllocator, BlockBudgetExceeded,  # noqa: F401
                         Request, RequestOutput, SamplingParams,
                         ServeEngine, ServeSettings, beam_search)


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(
        functools.partial(tr.init_params, cfg=cfg), jax.random.PRNGKey(0))


def _prefill_fn(cfg: ModelConfig):
    def prefill_step(params, batch):
        logits, caches, _ = tr.forward(params, cfg, batch["tokens"],
                                       batch.get("frontend_embeds"),
                                       mode="prefill", remat=False)
        # return only the last-position logits (next-token sampling) + cache
        return logits[:, -1:], caches
    return prefill_step


def _decode_fn(cfg: ModelConfig, window: Optional[int]):
    def serve_step(params, cache, token, pos):
        logits, new_cache = tr.decode_step(params, cfg, cache, token, pos,
                                           window=window)
        return logits, new_cache
    return serve_step


def lower_step(cfg: ModelConfig, mesh: Mesh, shape_name: str,
               settings: ServeSettings = ServeSettings()):
    """jit(...).lower() of the prefill or decode step for (cfg, shape).

    The unified lowering surface: the same :class:`ServeSettings` that
    configures a :class:`ServeEngine` selects the decode attention
    window here (``settings.window`` overrides the shape default), so a
    dryrun audits exactly what the engine would run.
    """
    shape = shp.SHAPES[shape_name]
    params = abstract_params(cfg)
    use = sh.param_shardings(cfg, mesh, "use")
    rep = NamedSharding(mesh, P())
    if shape.kind == "prefill":
        step = _prefill_fn(cfg)
        batch = shp.input_specs(cfg, shape_name)
        batch_sh = sh.batch_shardings(cfg, mesh, batch)
        jitted = jax.jit(step, in_shardings=(use, batch_sh))
        with mesh:
            return jitted.lower(params, batch)
    window = (settings.window if settings.window is not None
              else shp.decode_window(cfg, shape))
    step = _decode_fn(cfg, window)
    specs = shp.input_specs(cfg, shape_name)
    cache_sh = sh.cache_shardings(cfg, mesh, specs["cache"])
    tok_sh = sh.batch_shardings(cfg, mesh, specs["token"])
    jitted = jax.jit(step,
                     in_shardings=(use, cache_sh, tok_sh, rep),
                     out_shardings=(tok_sh, cache_sh),
                     donate_argnums=(1,))
    with mesh:
        return jitted.lower(params, specs["cache"], specs["token"],
                            specs["pos"])
