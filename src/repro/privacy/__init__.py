"""Empirical privacy-audit subsystem (Thm 3.3 / Cor. D.2, Figs. 2 & 12).

``repro.core.privacy`` holds the attack primitives (MIA audit with
bootstrap CIs, DLG inversion, the MI bound algebra).  This package turns
them into an *audit harness* against what an adversary REALLY observes:

* ``views``   — adversary-view geometry: the coordinate->aggregator
  assignment induced by the distributed runtime's per-leaf segment
  layout, reassembly of captured ``launch/train.py`` view payloads into
  the simulator's flat ``(A, K, n)`` form, and colluding-coalition
  unions.
* ``harness`` — scan-compiled audit runs: capture views from the
  simulator/scan engines (``FLConfig.keep_views``) or the distributed
  tap (``TrainSettings.capture_views``), sweep attacks over A and
  coalition size, and report leakage curves for the benchmark snapshot
  and the CI monotonicity gate.
"""
from repro.privacy import harness, views                       # noqa: F401
from repro.privacy.views import (colluding_view,               # noqa: F401
                                 flat_views_from_leaves,
                                 mesh_flat_assignment, view_layouts)
