"""Scan-compiled privacy-audit harness (Figs. 2, 5, 12 as a subsystem).

Runs the attack suites of ``repro.core.privacy`` against *captured*
adversary views — the ``(T, A, K, n)`` per-aggregator shard views the
scan engine materializes in one fused program (``FLConfig.keep_views`` +
``FLRun.run_scanned(collect_views=True)``) — for both the small-model
(MLP) problems of the paper's figures and transformer-family models from
the config zoo (token-sequence canaries for the MIA audit, continuous
input-embedding reconstruction for DLG via ``forward(inputs_embeds=...)``).

Everything is keyed on an :class:`AuditSpec`, so the benchmark snapshot
(``benchmarks/privacy_snapshot.py``), the tier-1 quick audit tests and
the nightly monotonicity gate all draw from the same runs.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from repro.core import masks as masks_lib
from repro.core import privacy
from repro.core.compressors import Identity, Int8RoundTrip, RandP
from repro.core.fl import FLConfig, FLRun


@dataclasses.dataclass(frozen=True)
class AuditSpec:
    """One privacy-audit configuration (a point on a leakage curve)."""

    A: int = 4                 # aggregators
    rounds: int = 30           # T
    K: int = 4                 # clients
    n_canaries: int = 8        # members == non-members == n_canaries
    use_dsc: bool = False      # DSC shifted compression on the wire
    int8_wire: bool = False    # int8 wire round trip in the payload
    p: float = 1.0             # DSC RandP retention (Fig. 2 right)
    a_c: int = 1               # colluding coalition size (Cor. D.2)
    q: float = 1.0             # per-round client participation prob.
    lr: float = 0.4
    seed: int = 0
    mask_scheme: str = "strided"
    n_bootstrap: int = 200     # bootstrap resamples for the AUC CI
    shard_attack: bool = False  # partition the canary-gradient attack
                                # compute over an ``attack`` device mesh
                                # (transformer-scale audits)


def fl_config(spec: AuditSpec) -> FLConfig:
    """The eris run whose views the audit attacks: literal FSA with
    materialized aggregator views, composing DSC and/or the int8 wire
    exactly as the production wire does.  ``q < 1`` switches to the
    buffered async engine (``eris_async``) with an i.i.d. Bernoulli(q)
    arrival model — the client participates in each round independently
    with probability q, and an aggregator's view of a skipped round is
    identically zero (privacy amplification by subsampling)."""
    comp = RandP(p=spec.p) if (spec.use_dsc and spec.p < 1.0) else Identity()
    method = "eris" if spec.q >= 1.0 else "eris_async"
    extra = {} if spec.q >= 1.0 else {"client_dropout": 1.0 - spec.q}
    return FLConfig(method=method, K=spec.K, A=spec.A, rounds=spec.rounds,
                    lr=spec.lr, seed=spec.seed, use_dsc=spec.use_dsc,
                    int8_wire=spec.int8_wire, compressor=comp,
                    mask_scheme=spec.mask_scheme, keep_views=True, **extra)


def capture_run(spec: AuditSpec, params0, loss_fn, client_batches):
    """Run T rounds in ONE scan-compiled program and capture the
    adversary views.  Returns (run, x_traj (T, n) PRE-round iterates,
    views (T, A, K, n))."""
    run = FLRun(fl_config(spec), params0, loss_fn)
    stacked = jax.tree.map(
        lambda b: jnp.stack([b] * spec.rounds), client_batches)
    x0 = run.x
    xs, views = run.run_scanned(stacked, collect_views=True)
    x_traj = jnp.concatenate([x0[None], xs[:-1]], axis=0)
    return run, x_traj, views


def coalition_views(views, assign, a_c: int, client: int = 0):
    """(obs_mask, observed view trajectory) for the union of the first
    ``a_c`` aggregators' views of one client (Cor. D.2 coalition)."""
    coalition = jnp.arange(a_c)
    obs = masks_lib.union_mask(assign, coalition)
    v = views[:, :a_c, client, :].sum(axis=1)       # (T, n) disjoint union
    return obs, v


def dsc_gamma_of(run: FLRun) -> float:
    """Effective DSC step of the run's compress stage (0.0 without DSC)."""
    from repro.core.pipeline import DSCCompress
    for st in run.pipeline.compress:
        if isinstance(st, DSCCompress):
            return st.gamma
    return 0.0


def deshift_views(v_tn: jax.Array, gamma: float) -> jax.Array:
    """Protocol-aware adversary against DSC: the client shift updates
    s_{t+1} = s_t + gamma v_t from TRANSMITTED values only (s_0 = 0), so
    an aggregator reconstructs, coordinate-wise on its own mask, the
    un-shifted payload  g~_t = v_t + gamma * sum_{tau<t} v_tau  exactly —
    shifted compression re-codes the wire, it does not hide the gradient
    from a curious aggregator.  Identity when gamma == 0."""
    if gamma == 0.0:
        return v_tn

    def body(s, v):
        return s + gamma * v, v + s

    _, g = jax.lax.scan(body, jnp.zeros_like(v_tn[0]), v_tn)
    return g


# ------------------------------------------------------- MLP (Fig. 2/5)
def mlp_model(dim: int = 8, classes: int = 3, hidden: int = 16):
    def init(key):
        k1, k2 = jax.random.split(key)
        return {"w1": 0.3 * jax.random.normal(k1, (dim, hidden)),
                "b1": jnp.zeros(hidden),
                "w2": 0.3 * jax.random.normal(k2, (hidden, classes)),
                "b2": jnp.zeros(classes)}

    def loss_fn(p, batch):
        xx, yy = batch
        h = jnp.tanh(xx @ p["w1"] + p["b1"])
        logits = h @ p["w2"] + p["b2"]
        return -jnp.take_along_axis(jax.nn.log_softmax(logits),
                                    yy[:, None], 1).mean()

    return init, loss_fn


def mlp_canary_problem(spec: AuditSpec, dim: int = 8, classes: int = 3,
                       hidden: int = 16):
    """Steinke-style one-run canary setup: OOD Gaussian inputs with
    random labels; the first half of client 0's canaries train (members,
    memorized), the second half is held out."""
    key = jax.random.PRNGKey(spec.seed)
    M = spec.n_canaries
    init, loss_fn = mlp_model(dim, classes, hidden)
    x = jax.random.normal(jax.random.fold_in(key, 2),
                          (spec.K, 2 * M, dim))                  # OOD
    y_can = jax.random.randint(jax.random.fold_in(key, 3),
                               (spec.K, 2 * M), 0, classes)
    batches = (x[:, :M], y_can[:, :M])
    members = jnp.concatenate([x[0, :M], y_can[0, :M, None]], axis=1)
    non = jnp.concatenate([x[0, M:], y_can[0, M:, None]], axis=1)
    params0 = init(key)
    return params0, loss_fn, batches, members, non


def _audit_captured(spec: AuditSpec, run, x_traj, views, grad_fn,
                    members, non, key_salt: int) -> dict:
    """The shared audit plumbing: coalition union -> protocol-aware
    de-shift -> ``mia_audit`` -> Thm 3.3 bound (one definition for every
    model family, so the MLP and transformer curves cannot diverge)."""
    assign = masks_lib.make_assignment(run.n, spec.A, spec.mask_scheme)
    obs, v = coalition_views(views, assign, spec.a_c)
    v = deshift_views(v, dsc_gamma_of(run))
    mesh = (privacy.attack_mesh(members.shape[0])
            if spec.shard_attack else None)
    res = privacy.mia_audit(
        jax.random.fold_in(jax.random.PRNGKey(spec.seed), key_salt),
        grad_fn, x_traj, v, obs, members, non,
        n_bootstrap=spec.n_bootstrap, mesh=mesh)
    # amplification by subsampling: each round leaks with prob. q, so
    # the linear-in-T Thm 3.3 budget scales by the participation rate
    res["mi_bound"] = spec.q * privacy.mi_bound(
        run.n, spec.rounds, spec.p if spec.use_dsc else 1.0, spec.A,
        a_c=spec.a_c)
    return res


def mia_mlp(spec: AuditSpec, dim: int = 8, classes: int = 3) -> dict:
    """MIA audit of the captured views under ``spec``.  Returns the
    ``core.privacy.mia_audit`` metrics + the matching Thm 3.3 bound."""
    params0, loss_fn, batches, members, non = mlp_canary_problem(
        spec, dim, classes)
    run, x_traj, views = capture_run(spec, params0, loss_fn, batches)
    grad_fn = jax.grad(lambda xf, c: loss_fn(
        run.unravel(xf), (c[:-1][None], c[-1][None].astype(jnp.int32))))
    return _audit_captured(spec, run, x_traj, views, grad_fn, members,
                           non, 0xA0D1)


def mia_mlp_sampling(spec: AuditSpec, q_grid, dim: int = 8,
                     classes: int = 3) -> dict:
    """Sampling-amplified leakage curve: the MIA audit at fixed A as a
    function of the per-round participation probability q (q = 1 is the
    synchronous engine; q < 1 the buffered async engine, whose arrival
    model zeroes a skipped client's wire rows — the adversary view of a
    skipped round carries nothing).  Returns {q: mia_mlp metrics}, each
    with the q-amplified Thm 3.3 bound."""
    return {float(q): mia_mlp(dataclasses.replace(spec, q=float(q)),
                              dim=dim, classes=classes)
            for q in q_grid}


def mia_mlp_collusion_sweep(spec: AuditSpec, dim: int = 8,
                            classes: int = 3) -> dict:
    """ONE captured run, the whole Cor. D.2 collusion curve: the audit
    vmapped (``mia_audit_sweep``) over the coalition unions
    a_c = 1..A.  Returns arrays indexed by a_c - 1."""
    params0, loss_fn, batches, members, non = mlp_canary_problem(
        spec, dim, classes)
    run, x_traj, views = capture_run(spec, params0, loss_fn, batches)
    assign = masks_lib.make_assignment(run.n, spec.A, spec.mask_scheme)
    gamma = dsc_gamma_of(run)
    masks, vs = [], []
    for a_c in range(1, spec.A + 1):
        obs, v = coalition_views(views, assign, a_c)
        masks.append(obs)
        vs.append(deshift_views(v, gamma))
    grad_fn = jax.grad(lambda xf, c: loss_fn(
        run.unravel(xf), (c[:-1][None], c[-1][None].astype(jnp.int32))))
    out = privacy.mia_audit_sweep(
        jax.random.fold_in(jax.random.PRNGKey(spec.seed), 0xC011),
        grad_fn, x_traj, jnp.stack(vs), jnp.stack(masks), members, non,
        n_bootstrap=spec.n_bootstrap)
    out["a_c"] = np.arange(1, spec.A + 1)
    return out


def dlg_mlp(A_values, wire: str = "f32", seed: int = 0, dim: int = 36,
            classes: int = 3, steps: int = 400, lr: float = 0.05) -> dict:
    """DLG inversion strength vs A for one wire format ('f32' or 'int8'
    — the int8 payload is the dequantized per-block round trip, exactly
    what an aggregator receives).  Returns {A: scale-invariant MSE}."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params0 = {"w": 0.5 * jax.random.normal(k1, (dim, classes)),
               "b": jnp.zeros(classes)}
    x_flat, unravel = ravel_pytree(params0)

    def loss_single(xf, inp, label):
        p = unravel(xf)
        return -jax.nn.log_softmax(inp @ p["w"] + p["b"])[label]

    grad_fn = jax.grad(loss_single)
    target = jax.random.normal(k2, (dim,))
    label = jnp.int32(1)
    g_true = grad_fn(x_flat, target, label)
    if wire == "int8":
        g_wire = Int8RoundTrip(inner=Identity())(k4, g_true)
    elif wire == "f32":
        g_wire = g_true
    else:
        raise ValueError(f"unknown wire format {wire!r}")
    out = {}
    for A in A_values:
        assign = masks_lib.make_assignment(x_flat.shape[0], A, "strided")
        obs = masks_lib.mask_for(assign, 0)
        rec = privacy.dlg_attack(k3, grad_fn, x_flat, g_wire * obs, obs,
                                 (dim,), label, steps=steps, lr=lr)
        out[A] = privacy.reconstruction_mse(rec["reconstruction"], target)
    return out


# ------------------------------------- transformer family (config zoo)
def tiny_lm_config(arch: str = "qwen2-0.5b"):
    """A CPU-sized member of the config zoo's family (one block below
    ``smoke()``) — small enough that (T, A, K, n) view capture fits in a
    quick-tier test."""
    import dataclasses as dc
    from repro.configs import get_config
    cfg = get_config(arch).smoke()
    # flash_attention pinned off: the committed BENCH_privacy.json MIA /
    # DLG curves were captured on the chunked-attention gradient path,
    # and the audit doesn't exercise the kernel anyway
    return dc.replace(cfg, name=cfg.name + "-audit", n_layers=1,
                      d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
                      d_ff=128, vocab=256, qkv_bias=False, qk_norm=False,
                      attn_chunk=16, flash_attention=False,
                      overlap_collectives=False)


def lm_canary_problem(cfg, spec: AuditSpec, seq: int = 16):
    """Token-sequence canaries for a transformer: random sequences, the
    member half trains as client 0's corpus (low-data memorization
    regime), the non-member half is held out."""
    from repro.models import transformer as tr
    key = jax.random.PRNGKey(spec.seed)
    M = spec.n_canaries
    canaries = jax.random.randint(jax.random.fold_in(key, 1),
                                  (2 * M, seq), 0, cfg.vocab)
    filler = jax.random.randint(jax.random.fold_in(key, 2),
                                (spec.K - 1, M, seq), 0, cfg.vocab)
    batches = {"tokens": jnp.concatenate([canaries[None, :M], filler], 0)}
    params0 = tr.init_params(key, cfg)

    def loss_fn(p, batch):
        return tr.loss_fn(p, cfg, batch)

    return params0, loss_fn, batches, canaries[:M], canaries[M:]


def mia_lm(cfg, spec: AuditSpec, seq: int = 16) -> dict:
    """MIA audit against a transformer-family model's captured views
    (canary = token sequence; gradient alignment on the ravel'd
    parameter vector, rounds folded under ``lax.scan``)."""
    from repro.models import transformer as tr
    params0, loss_fn, batches, members, non = lm_canary_problem(
        cfg, spec, seq)
    run, x_traj, views = capture_run(spec, params0, loss_fn, batches)
    grad_fn = jax.grad(lambda xf, c: tr.loss_fn(
        run.unravel(xf), cfg, {"tokens": c[None]}))
    return _audit_captured(spec, run, x_traj, views, grad_fn, members,
                           non, 0xA0D2)


def dlg_lm(cfg, A_values, wire: str = "f32", seed: int = 0, seq: int = 8,
           steps: int = 200, lr: float = 0.05) -> dict:
    """DLG against a transformer: reconstruct the continuous input
    embeddings of one training sequence from the observed (masked, wire-
    formatted) parameter gradient via ``forward(inputs_embeds=...)``.
    Returns {A: scale-invariant MSE vs the true embeddings}."""
    from repro.models import transformer as tr
    key = jax.random.PRNGKey(seed)
    params0 = tr.init_params(jax.random.fold_in(key, 1), cfg)
    x_flat, unravel = ravel_pytree(params0)
    tokens = jax.random.randint(jax.random.fold_in(key, 2), (1, seq),
                                0, cfg.vocab)
    emb_true = params0["embed"][tokens[0]]

    def grad_fn(xf, dummy, label_toks):
        return jax.grad(lambda f: tr.loss_fn(
            unravel(f), cfg,
            {"tokens": label_toks, "inputs_embeds": dummy}))(xf)

    g_true = grad_fn(x_flat, emb_true[None], tokens)
    if wire == "int8":
        g_wire = Int8RoundTrip(inner=Identity())(
            jax.random.fold_in(key, 3), g_true)
    else:
        g_wire = g_true
    out = {}
    for A in A_values:
        assign = masks_lib.make_assignment(x_flat.shape[0], A, "strided")
        obs = masks_lib.mask_for(assign, 0)
        rec = privacy.dlg_attack(jax.random.fold_in(key, 4), grad_fn,
                                 x_flat, g_wire * obs, obs,
                                 (1, seq, cfg.d_model), tokens,
                                 steps=steps, lr=lr)
        out[A] = privacy.reconstruction_mse(rec["reconstruction"][0],
                                            emb_true)
    return out
