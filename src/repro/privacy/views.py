"""Adversary-view geometry: what each aggregator observes, where.

The simulator's literal FSA (``core/fsa.fsa_round_sharded``) expresses an
aggregator's view as a masked flat vector — ``m_(a) ⊙ v_k`` over the
ravel'd parameter vector.  The distributed runtime expresses the same
view as *per-leaf segment rows*: aggregator ``a`` receives, for every
leaf with a client scatter dim, the flattened contiguous segment ``a`` of
every client's (TP-local) update (``launch/train.py``'s
``capture_views`` tap).  This module is the bridge:

* :func:`view_layouts` / :func:`mesh_flat_assignment` — the flat
  coordinate->aggregator assignment INDUCED by the mesh layout
  (identical chunking to ``dist/sharding.split_shards`` and the 'store'
  slices; coordinates on the replicated-psum fallback path map to -1:
  no aggregator sees them per-client, only their sum).
* :func:`flat_views_from_leaves` — reassemble one round of captured
  view payloads into the simulator's ``(A, K, n)`` array, zeros off-mask.
* :func:`colluding_view` — the Cor. D.2 coalition view (disjoint masks
  make the union a plain sum over the coalition's aggregators).

Pure numpy index bookkeeping — safe to call before jax device init.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import numpy as np

from repro.dist.sharding import scatter_dim_for, tp_local_shape


def _np_split_rows(arr: np.ndarray, dim: int, n_client: int) -> np.ndarray:
    """numpy twin of ``dist/sharding.split_shards``: (n_client, m) rows of
    flat indices, row a = aggregator a's contiguous segment of ``dim``."""
    pre = arr.shape[:dim]
    size = arr.shape[dim] // n_client
    x = arr.reshape(*pre, n_client, size, *arr.shape[dim + 1:])
    x = np.moveaxis(x, len(pre), 0)
    return x.reshape(n_client, -1)


@dataclasses.dataclass(frozen=True)
class LeafViewLayout:
    """Where one parameter leaf's captured view rows land in flat coords.

    ``chunks[j][a]`` holds the flat ravel indices (leaf offset included)
    of model-position j's segment for aggregator a; ``dim < 0`` leaves
    (no client-divisible dimension — replicated + psum'd) carry no
    chunks.  ``dup`` marks leaves replicated over the model axis whose
    captured width still concatenates ``tp`` identical chunks (the tap
    emits one per model position)."""

    index: int                 # leaf position in jax.tree flatten order
    offset: int                # flat offset in the ravel'd vector
    shape: tuple               # full (global) leaf shape
    dim: int                   # client scatter dim on the TP-local shape
    tp_dim: int                # model-axis shard dim (-1 = replicated)
    m_loc: int                 # flat elems per (model pos, aggregator) seg
    dup: bool                  # captured chunks are model-axis duplicates
    chunks: tuple              # tuple over model positions of (A, m_loc)


def view_layouts(params_abs: Any, n_client: int, tp: int = 1,
                 tp_specs: Optional[Any] = None) -> list[LeafViewLayout]:
    """Per-leaf view layouts for a parameter tree under (n_client, tp)."""
    leaves = jax.tree.leaves(params_abs)
    spec_leaves = (jax.tree.leaves(tp_specs) if tp_specs is not None
                   else [None] * len(leaves))
    out, offset = [], 0
    for i, (p, s) in enumerate(zip(leaves, spec_leaves)):
        shape = tuple(p.shape)
        size = int(np.prod(shape)) if shape else 1
        tp_dim = s.dim if (s is not None and tp > 1) else -1
        loc_shape = (tp_local_shape(shape, s, tp)
                     if s is not None else shape)
        dim = scatter_dim_for(loc_shape, n_client)
        if dim < 0:
            out.append(LeafViewLayout(i, offset, shape, -1, tp_dim, 0,
                                      False, ()))
            offset += size
            continue
        idx = np.arange(size, dtype=np.int64).reshape(shape)
        model_chunks = (np.split(idx, tp, axis=tp_dim) if tp_dim >= 0
                        else [idx])
        chunks = tuple(_np_split_rows(c, dim, n_client)
                       for c in model_chunks)
        out.append(LeafViewLayout(i, offset, shape, dim, tp_dim,
                                  chunks[0].shape[1], tp_dim < 0 and tp > 1,
                                  tuple(c + offset for c in chunks)))
        offset += size
    return out


def mesh_flat_assignment(params_abs: Any, n_client: int, tp: int = 1,
                         tp_specs: Optional[Any] = None) -> np.ndarray:
    """Flat (n,) coordinate->aggregator assignment induced by the mesh
    layout (-1 = replicated-psum coordinates: every aggregator observes
    only the client SUM there, never a per-client value).  Feeding this
    into ``FSASharded.assign_override`` makes the simulator's masks equal
    the distributed runtime's segment slices, so per-aggregator views are
    directly comparable across engines."""
    layouts = view_layouts(params_abs, n_client, tp, tp_specs)
    n = sum(int(np.prod(lay.shape)) if lay.shape else 1 for lay in layouts)
    assign = np.full(n, -1, dtype=np.int32)
    for lay in layouts:
        for rows in lay.chunks:
            for a in range(n_client):
                assign[rows[a]] = a
    return assign


def flat_views_from_leaves(view_leaves: dict, params_abs: Any,
                           n_client: int, tp: int = 1,
                           tp_specs: Optional[Any] = None) -> np.ndarray:
    """Reassemble one round of the distributed tap's captured payloads
    (``{str(leaf_index): (A, K, m_loc * tp)}``) into the simulator's
    ``(A, K, n)`` adversary-view array (zeros outside each aggregator's
    mask and on psum-fallback coordinates)."""
    layouts = view_layouts(params_abs, n_client, tp, tp_specs)
    n = sum(int(np.prod(lay.shape)) if lay.shape else 1 for lay in layouts)
    if not view_leaves:
        raise ValueError(
            "no captured view leaves: every parameter leaf took the "
            "replicated-psum fallback (no dimension divisible by "
            f"n_client={n_client}), so no per-client payload exists")
    some = next(iter(view_leaves.values()))
    A, K = np.asarray(some).shape[:2]
    out = np.zeros((A, K, n), dtype=np.float32)
    for lay in layouts:
        if lay.dim < 0:
            continue
        arr = np.asarray(view_leaves[str(lay.index)], dtype=np.float32)
        n_chunks = 1 if lay.dup else len(lay.chunks)
        for j in range(n_chunks):
            cols = arr[:, :, j * lay.m_loc:(j + 1) * lay.m_loc]
            rows = lay.chunks[j]
            for a in range(A):
                out[a][:, rows[a]] = cols[a]      # (K, m_loc) into the mask
    return out


def colluding_view(views: np.ndarray, coalition) -> np.ndarray:
    """Union view of a colluding coalition (Cor. D.2): masks are disjoint,
    so the union is the sum over the coalition's aggregator axis entries.
    ``views``: (..., A, K, n) with the aggregator axis third-from-last."""
    coalition = list(coalition)
    return np.asarray(views)[..., coalition, :, :].sum(axis=-3)
