from repro.data.synthetic import (federated_classification,  # noqa: F401
                                  lm_token_batches, dirichlet_partition,
                                  balanced_dirichlet_indices,
                                  federated_population)
