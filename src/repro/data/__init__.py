from repro.data.synthetic import (federated_classification,  # noqa: F401
                                  lm_token_batches, dirichlet_partition)
