"""Synthetic data pipeline: federated classification + LM token streams.

The paper evaluates on MNIST/CIFAR/IMDB/CNN-DailyMail; at laptop scale we
use controlled synthetic analogues (cluster-structured classification with
Dirichlet label skew, Zipf token streams) so the convergence/privacy
mechanics are exercised with reproducible statistics and no downloads.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def make_classification(key, n_samples: int, dim: int, n_classes: int,
                        noise: float = 0.5):
    """Gaussian cluster classification (linearly separable up to noise)."""
    k1, k2, k3 = jax.random.split(key, 3)
    centers = 2.0 * jax.random.normal(k1, (n_classes, dim))
    labels = jax.random.randint(k2, (n_samples,), 0, n_classes)
    x = centers[labels] + noise * jax.random.normal(k3, (n_samples, dim))
    return x, labels


def dirichlet_partition(key, labels: jax.Array, K: int, alpha: float,
                        n_classes: int):
    """Non-IID client partition: class proportions per client ~ Dir(alpha).
    Returns an (n_samples,) client-assignment vector."""
    props = jax.random.dirichlet(key, alpha * jnp.ones(K), (n_classes,))
    cum = jnp.cumsum(props, axis=1)                    # (n_classes, K)
    u = jax.random.uniform(jax.random.fold_in(key, 1), labels.shape)
    return jnp.argmax(u[:, None] < cum[labels], axis=1)


def balanced_dirichlet_indices(key, labels, K: int, alpha: float,
                               n_classes: int):
    """Exact-coverage Dirichlet(alpha) partition: a (K, n_samples // K)
    int array of sample indices whose concatenation is a permutation of
    ``arange(n_samples)`` — every sample lands on exactly one client,
    every client gets exactly its quota.  Label skew follows
    :func:`dirichlet_partition`; over/under-full clients are rebalanced
    deterministically (surplus clients donate their highest-index
    samples to deficit clients in id order), which dilutes but preserves
    the alpha-controlled concentration (tests/test_cohorts.py asserts
    both the exactly-once property and the concentration trend)."""
    import numpy as np
    n_samples = int(labels.shape[0])
    if n_samples % K:
        raise ValueError(f"population partition needs n_samples "
                         f"({n_samples}) divisible by K ({K})")
    quota = n_samples // K
    owner = np.asarray(jax.device_get(
        dirichlet_partition(key, labels, K, alpha, n_classes)))
    lists = [list(np.where(owner == k)[0]) for k in range(K)]
    surplus: list = []
    for k in range(K):
        while len(lists[k]) > quota:
            surplus.append(lists[k].pop())
    for k in range(K):
        while len(lists[k]) < quota:
            lists[k].append(surplus.pop())
    return jnp.asarray(np.stack([np.sort(np.asarray(l, dtype=np.int64))
                                 for l in lists]))


def federated_population(key, population: int, samples_per_client: int,
                         dim: int = 16, n_classes: int = 4,
                         alpha: float = 0.5, noise: float = 0.5):
    """Population-scale non-IID federation: (x, y) arrays of shape
    ``(population, S, dim)`` / ``(population, S)`` built from ONE global
    dataset split exactly once across the whole population via
    :func:`balanced_dirichlet_indices` — the data feed for the cohort-
    sampling async runtime (``FLConfig.population``), where each round
    gathers a drawn cohort's rows from the leading axis."""
    kd, kp = jax.random.split(key)
    x, y = make_classification(kd, population * samples_per_client, dim,
                               n_classes, noise)
    idx = balanced_dirichlet_indices(kp, y, population, alpha, n_classes)
    take = idx[:, :samples_per_client]
    return x[take], y[take]


def federated_classification(key, K: int, samples_per_client: int,
                             dim: int = 16, n_classes: int = 4,
                             alpha: float | None = None,
                             noise: float = 0.5):
    """Returns (x, y) arrays of shape (K, S, dim) / (K, S) — IID when
    alpha is None, Dirichlet(alpha) label-skewed otherwise."""
    n = K * samples_per_client
    kd, kp, ks = jax.random.split(key, 3)
    x, y = make_classification(kd, 4 * n, dim, n_classes, noise)
    if alpha is None:
        idx = jax.random.permutation(kp, 4 * n)[:n]
        xs, ys = x[idx], y[idx]
        return (xs.reshape(K, samples_per_client, dim),
                ys.reshape(K, samples_per_client))
    owner = dirichlet_partition(kp, y, K, alpha, n_classes)
    # rejection-style gather: for each client take its first S samples
    out_x, out_y = [], []
    owner_np, x_np, y_np = (jax.device_get(owner), jax.device_get(x),
                            jax.device_get(y))
    import numpy as np
    for k in range(K):
        idx = np.where(owner_np == k)[0]
        if len(idx) < samples_per_client:   # top up from the global pool
            extra = np.random.RandomState(k).choice(
                len(y_np), samples_per_client - len(idx), replace=False)
            idx = np.concatenate([idx, extra])
        idx = idx[:samples_per_client]
        out_x.append(x_np[idx]); out_y.append(y_np[idx])
    return jnp.asarray(np.stack(out_x)), jnp.asarray(np.stack(out_y))


def lm_token_batches(key, K: int, batch: int, seq_len: int, vocab: int,
                     zipf_a: float = 1.2):
    """Zipf-distributed next-token-predictable streams: token t+1 is a
    deterministic mix of token t and noise, so a real LM signal exists."""
    k1, k2 = jax.random.split(key)
    ranks = jnp.arange(1, vocab + 1, dtype=jnp.float32)
    probs = ranks ** (-zipf_a)
    probs = probs / probs.sum()
    base = jax.random.choice(k1, vocab, (K, batch, seq_len), p=probs)
    # inject structure: with prob .5, next token = (prev*7+3) % vocab
    det = (jnp.roll(base, 1, axis=-1) * 7 + 3) % vocab
    coin = jax.random.bernoulli(k2, 0.5, base.shape)
    return jnp.where(coin, det, base).astype(jnp.int32)
