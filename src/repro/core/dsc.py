"""Distributed Shifted Compression (Section 3.2.2).

Client side:    v_k = C_k(g_k - s_k);          s_k <- s_k + gamma * v_k
Aggregator a:   v_(a) = s_(a) + mean_k v_{k,(a)};
                s_(a) <- s_(a) + gamma * mean_k v_{k,(a)}         (Eq. 4)

The aggregator references {s_(a)} live on disjoint coordinate shards, so we
store them as one coordinate-partitioned vector ``s_agg`` of shape (n,) —
segment a of s_agg is exactly s_(a).

``gamma_star(omega)`` is the shift stepsize of Theorem 3.2:
gamma = sqrt((1 + 2w) / (2 (1 + w)^3)).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.compressors import Compressor


class DSCState(NamedTuple):
    s_clients: jax.Array   # (K, n) client reference vectors s_k
    s_agg: jax.Array       # (n,)   aggregator references (coordinate-partitioned)


def init_state(K: int, n: int, dtype=jnp.float32) -> DSCState:
    return DSCState(jnp.zeros((K, n), dtype), jnp.zeros((n,), dtype))


def gamma_star(omega: float) -> float:
    return float(((1.0 + 2.0 * omega) / (2.0 * (1.0 + omega) ** 3)) ** 0.5)


def client_compress(state: DSCState, grads: jax.Array,
                    compressor: Compressor, gamma: float,
                    key: jax.Array) -> tuple[jax.Array, jax.Array]:
    """All-clients shifted compression.

    grads: (K, n).  Returns (v, s_clients_new) with v: (K, n) the
    transmitted (dense-represented) compressed shifted updates.
    """
    K = grads.shape[0]
    keys = jax.random.split(key, K)
    v = jax.vmap(lambda k, d: compressor(k, d))(keys, grads - state.s_clients)
    s_new = state.s_clients + gamma * v
    return v, s_new


def aggregate(state: DSCState, v: jax.Array, gamma: float,
              weights: jax.Array | None = None
              ) -> tuple[jax.Array, jax.Array]:
    """Aggregator-side shift compensation (Eq. 4), coordinate-wise over the
    partitioned s_agg.  Returns (v_global, s_agg_new) where v_global is the
    reassembled sum over aggregators of v_(a) (disjoint shards -> the
    coordinate-wise expression below is exact)."""
    K = v.shape[0]
    if weights is None:
        weights = jnp.full((K,), 1.0 / K)
    else:
        weights = weights / weights.sum()
    mean_v = jnp.einsum("k,kn->n", weights, v)
    v_global = state.s_agg + mean_v
    s_agg_new = state.s_agg + gamma * mean_v
    return v_global, s_agg_new
