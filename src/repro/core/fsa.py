"""Federated Shard Aggregation (Section 3.2.1, Algorithm 1 without DSC).

Two equivalent implementations are provided:

* ``fsa_round_sharded`` — the literal protocol: per-aggregator masked
  shards are materialized, aggregated independently, and reassembled.
  This is the view an honest-but-curious aggregator has (used by the
  privacy attacks) and the form used to *prove* Theorem B.1 in tests.
* ``fsa_round`` — the algebraic shortcut: because masks are disjoint and
  complete, the reassembled model equals the centralized FedAvg update.
  This is what the production runtime lowers to (reduce-scatter +
  all-gather over the client axis; see repro.launch.train).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import masks as masks_lib


class FSAOutput(NamedTuple):
    x_new: jax.Array          # reassembled global model (n,)
    shard_views: jax.Array | None   # (A, K, n) what each aggregator saw


def shard_update(v: jax.Array, assign: jax.Array, A: int) -> jax.Array:
    """Partition one client update into A masked shards -> (A, n)."""
    m = masks_lib.masks_stacked(assign, A)          # (A, n)
    return m * v[None, :]


def reassemble(x_shards: jax.Array, assign: jax.Array, A: int) -> jax.Array:
    """x^{t+1} = sum_a m_(a) ⊙ x_(a)^{t+1}  (Algorithm 1 line 14)."""
    m = masks_lib.masks_stacked(assign, A)
    return (m * x_shards).sum(0)


def fsa_round_sharded(x: jax.Array, client_updates: jax.Array,
                      assign: jax.Array, A: int, lr: float,
                      weights: jax.Array | None = None,
                      keep_views: bool = True) -> FSAOutput:
    """Literal Algorithm 1 (no DSC): shard, aggregate per-aggregator,
    update each model segment, broadcast, reassemble.

    client_updates: (K, n); weights: optional per-client sample weights S_k.
    """
    K, n = client_updates.shape
    if weights is None:
        weights = jnp.full((K,), 1.0 / K)
    else:
        weights = weights / weights.sum()
    # each client shards its update: (K, A, n)
    shards = jax.vmap(lambda v: shard_update(v, assign, A))(client_updates)
    shard_views = jnp.swapaxes(shards, 0, 1)        # (A, K, n) adversary view
    # aggregator a: v_(a) = sum_k w_k v_{k,(a)}   (Eq. 2, weighted form)
    v_a = jnp.einsum("k,akn->an", weights, shard_views)
    # each aggregator updates its model segment: x_(a)^{t+1} = x_(a) - lr v_(a)
    m = masks_lib.masks_stacked(assign, A)
    x_a = m * x[None, :] - lr * v_a
    x_new = reassemble(x_a, assign, A)
    return FSAOutput(x_new, shard_views if keep_views else None)


def fsa_round(x: jax.Array, client_updates: jax.Array, lr: float,
              weights: jax.Array | None = None) -> jax.Array:
    """Algebraic form (Theorem B.1): identical iterates to FedAvg."""
    K = client_updates.shape[0]
    if weights is None:
        weights = jnp.full((K,), 1.0 / K)
    else:
        weights = weights / weights.sum()
    return x - lr * jnp.einsum("k,kn->n", weights, client_updates)


def fsa_round_with_failures(x: jax.Array, client_updates: jax.Array,
                            assign: jax.Array, A: int, lr: float,
                            agg_alive: jax.Array,
                            link_alive: jax.Array,
                            keep_views: bool = False):
    """Failure-injected round (Appendix F.5).

    agg_alive: (A,) bool — dropped aggregators contribute no segment update
    (their model shard stays at x_(a)^t for the round).
    link_alive: (K, A) bool — a failed client->aggregator link drops that
    client's shard; the aggregator renormalizes over received shards.

    Returns the bare x_new array (historical signature), or — with
    ``keep_views=True`` — an :class:`FSAOutput` whose ``shard_views`` are
    what the surviving aggregators actually RECEIVED: shard (a, k) is
    zero when link k->a failed or aggregator a was down, which is the
    adversary view the failure-injected scenario audits attack.
    """
    K, n = client_updates.shape
    m = masks_lib.masks_stacked(assign, A)                 # (A, n)
    shards = jnp.einsum("an,kn->akn", m, client_updates)   # (A, K, n)
    w = link_alive.T.astype(jnp.float32)                   # (A, K)
    cnt = jnp.maximum(w.sum(1, keepdims=True), 1.0)
    v_a = jnp.einsum("ak,akn->an", w / cnt, shards)
    v_a = v_a * agg_alive[:, None].astype(jnp.float32)
    x_a = m * x[None, :] - lr * v_a
    x_new = reassemble(x_a, assign, A)
    if not keep_views:
        return x_new
    views = (shards * w[:, :, None]
             * agg_alive[:, None, None].astype(jnp.float32))
    return FSAOutput(x_new, views)
