"""Pairwise-masking secure aggregation baseline (Bonawitz et al. 2017,
simplified: no dropout recovery) — the cryptographic alternative the
paper compares FSA against (Sec. 2 'Privacy-preserving FL').

Each ordered client pair (i < j) shares a PRG seed; client i adds
PRG(seed_ij), client j subtracts it.  Masks cancel exactly in the sum, so
the aggregate equals FedAvg while each individual masked update is
statistically independent of the client's data (perfect per-update
privacy) — at the cost of O(K^2) mask generation per round and total
failure on dropout without the recovery protocol (which is the overhead
FSA avoids)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pairwise_masks(key: jax.Array, K: int, n: int,
                   scale: float = 100.0) -> jax.Array:
    """(K, n) masks that sum to exactly zero across clients.  ``scale``
    emulates the large modular-field range of the real protocol (masks
    must dominate the signal for statistical hiding)."""
    def pair_seed(i, j):
        return jax.random.fold_in(jax.random.fold_in(key, i * 131071), j)

    masks = jnp.zeros((K, n))
    for i in range(K):
        for j in range(i + 1, K):
            m = scale * jax.random.normal(pair_seed(i, j), (n,))
            masks = masks.at[i].add(m).at[j].add(-m)
    return masks


def mask_updates(key: jax.Array, updates: jax.Array) -> jax.Array:
    """Masked per-client updates; their mean equals the unmasked mean."""
    K, n = updates.shape
    return updates + pairwise_masks(key, K, n)


def secure_agg_round(key, x, grads, lr):
    """FedAvg via masked updates — the server/aggregator sees only
    masked vectors (the adversary view), the model update is exact."""
    masked = mask_updates(key, grads)
    return x - lr * masked.mean(0), masked
