"""Pairwise-masking secure aggregation baseline (Bonawitz et al. 2017,
simplified: no dropout recovery) — the cryptographic alternative the
paper compares FSA against (Sec. 2 'Privacy-preserving FL').

Each unordered client pair {i, j} (i < j) shares a PRG seed; client i
adds PRG(seed_ij), client j subtracts it.  Masks cancel exactly in the
full-cohort sum, so the aggregate equals FedAvg while each individual
masked update is statistically independent of the client's data
(perfect per-update privacy) — at the cost of O(K^2) mask generation
per round and total failure on dropout without the recovery protocol
(which is the overhead FSA avoids).  Any weighted or partial sum does
NOT cancel: callers that aggregate with participation weights or
client dropout must refuse loudly (`pipeline.SecureAggAggregate` and
`rounds.scenarios` do) rather than produce a garbage aggregate.

Masks are *fixed-point*: integer multiples of a per-(K, scale) quantum
chosen so every f32 partial sum is exactly representable (mirroring the
real protocol's modular integer field).  Cancellation across clients is
therefore EXACTLY zero under jit for any summation order and any K, not
merely zero up to float round-off.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def _grid(scale: float, K: int) -> tuple[float, int]:
    """Fixed-point quantum ``q`` and level count ``L`` (draws lie on
    q * [-L, L)).  q is the power of two making the worst-case partial
    sum over all K(K-1) signed pair masks fit in f32's 2^24 exact-integer
    range, so additions never round and cancellation is exact."""
    budget = 2.0 ** 24
    q = 2.0 ** math.ceil(math.log2(max(K * K * scale / budget, 2.0 ** -16)))
    L = max(1, int(scale / q))
    return q, L


def pairwise_mask_row(key: jax.Array, i: jax.Array, K: int, n: int,
                      scale: float = 100.0) -> jax.Array:
    """Client ``i``'s mask: sum over partners j of sign(j - i) * m_ij,
    where m_ij is drawn from a seed keyed on the *unordered* pair
    (min, max) — so rows i and j derive the identical pair mask and the
    signs cancel.  This is the per-participant form the distributed
    engine evaluates locally (each mesh position draws only its own
    row); `pairwise_masks` is its vmap over rows."""
    q, L = _grid(scale, K)
    i = jnp.asarray(i)

    def pair(j):
        lo = jnp.minimum(i, j)
        hi = jnp.maximum(i, j)
        k = jax.random.fold_in(jax.random.fold_in(key, lo * 131071), hi)
        m = q * jax.random.randint(k, (n,), -L, L).astype(jnp.float32)
        return jnp.sign(j - i).astype(jnp.float32) * m

    return jax.vmap(pair)(jnp.arange(K)).sum(0)


def pairwise_masks(key: jax.Array, K: int, n: int,
                   scale: float = 100.0) -> jax.Array:
    """(K, n) masks that sum to exactly zero across clients.  ``scale``
    emulates the large modular-field range of the real protocol (masks
    must dominate the signal for statistical hiding).  Vectorized as a
    fold_in seed grid + vmap over rows — jits at scenario-matrix scale
    (the old version unrolled an O(K^2) Python loop of `.at` updates)."""
    return jax.vmap(
        lambda i: pairwise_mask_row(key, i, K, n, scale))(jnp.arange(K))


def mask_updates(key: jax.Array, updates: jax.Array) -> jax.Array:
    """Masked per-client updates; their *unweighted full-cohort* mean
    equals the unmasked mean.  Weighted/partial means do not cancel."""
    K, n = updates.shape
    return updates + pairwise_masks(key, K, n)


def secure_agg_round(key, x, grads, lr):
    """FedAvg via masked updates — the server/aggregator sees only
    masked vectors (the adversary view), the model update is exact."""
    masked = mask_updates(key, grads)
    return x - lr * masked.mean(0), masked
