"""Moments/RDP accountant for the composed LDP scenarios.

The LDP defense (`pipeline.LDPNoise` / `baselines.ldp_perturb`) clips
each client update to ``clip`` and adds Gaussian noise calibrated by
`baselines.gaussian_sigma` for a SINGLE-round (eps, delta) guarantee.
Across a T-round scenario the privacy loss composes; naive composition
(T*eps) is hopelessly loose, so the scenario pack tracks the cumulative
(eps, delta) with a Renyi-DP accountant (Mironov 2017; subsampled
amplification per Wang/Balle/Kasiviswanathan 2019 for integer orders;
the moments-accountant bound of Abadi et al. 2016 is the same object).

Everything here is plain Python/ math — the accountant runs at
snapshot/report time, never inside a jitted round.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

from repro.core import baselines as bl

# Integer Renyi orders: dense low range (tight for large noise) plus a
# spread tail (tight for small noise / many rounds).
DEFAULT_ORDERS: tuple[int, ...] = tuple(range(2, 33)) + (
    40, 48, 64, 96, 128, 192, 256, 384, 512)


def rdp_gaussian(alpha: float, noise_multiplier: float) -> float:
    """RDP of the Gaussian mechanism at order alpha: alpha / (2 z^2)."""
    if noise_multiplier <= 0:
        return math.inf
    return alpha / (2.0 * noise_multiplier ** 2)


def _log_comb(n: int, k: int) -> float:
    return (math.lgamma(n + 1) - math.lgamma(k + 1)
            - math.lgamma(n - k + 1))


def rdp_subsampled_gaussian(alpha: int, q: float,
                            noise_multiplier: float) -> float:
    """RDP at integer order alpha of the Poisson-subsampled Gaussian
    mechanism (sampling rate q, noise multiplier z = sigma/sensitivity):

        (1/(alpha-1)) log sum_{k=0}^{alpha} C(alpha,k) (1-q)^{alpha-k}
                           q^k exp(k(k-1)/(2 z^2))

    — the binomial-expansion bound of Wang et al. (2019), Thm 9 /
    Mironov et al.'s tight integer-order formula.  q=1 reduces to the
    plain Gaussian RDP."""
    if noise_multiplier <= 0:
        return math.inf
    if q <= 0:
        return 0.0
    if q >= 1.0:
        return rdp_gaussian(alpha, noise_multiplier)
    if alpha < 2 or alpha != int(alpha):
        raise ValueError(f"integer order >= 2 required, got {alpha}")
    alpha = int(alpha)
    z2 = noise_multiplier ** 2
    log_terms = [
        _log_comb(alpha, k)
        + (alpha - k) * math.log1p(-q)
        + (k * math.log(q) if k else 0.0)
        + k * (k - 1) / (2.0 * z2)
        for k in range(alpha + 1)
    ]
    m = max(log_terms)
    log_sum = m + math.log(sum(math.exp(t - m) for t in log_terms))
    return max(log_sum / (alpha - 1), 0.0)


def eps_from_rdp(orders: Sequence[float], rdp: Sequence[float],
                 delta: float) -> float:
    """(eps, delta)-DP from an RDP curve via the improved conversion
    (Balle et al. 2020 / Canonne-Kamath-Steinke form used by Opacus):

        eps = min_alpha rdp(alpha) + log((alpha-1)/alpha)
                         - (log delta + log alpha) / (alpha - 1)
    """
    if not 0 < delta < 1:
        raise ValueError(f"delta must be in (0, 1), got {delta}")
    best = math.inf
    for a, r in zip(orders, rdp):
        if math.isinf(r) or a <= 1:
            continue
        eps = (r + math.log((a - 1) / a)
               - (math.log(delta) + math.log(a)) / (a - 1))
        best = min(best, max(eps, 0.0))
    return best


@dataclasses.dataclass
class RDPAccountant:
    """Cumulative RDP over a round sequence.  ``step`` folds one round of
    the subsampled Gaussian mechanism; ``epsilon`` converts the running
    curve to the cumulative (eps, delta)."""

    orders: tuple[int, ...] = DEFAULT_ORDERS

    def __post_init__(self):
        self._rdp = [0.0] * len(self.orders)

    def step(self, noise_multiplier: float, q: float = 1.0,
             steps: int = 1) -> "RDPAccountant":
        for i, a in enumerate(self.orders):
            self._rdp[i] += steps * rdp_subsampled_gaussian(
                a, q, noise_multiplier)
        return self

    def epsilon(self, delta: float) -> float:
        return eps_from_rdp(self.orders, self._rdp, delta)


def ldp_noise_multiplier(ldp: bl.LDPConfig) -> float:
    """z = sigma / sensitivity for the repo's LDP mechanism: each clipped
    per-client update (L2 <= clip) is perturbed with
    sigma = gaussian_sigma(eps, delta, clip), so z = sigma / clip."""
    return bl.gaussian_sigma(ldp.eps, ldp.delta, ldp.clip) / ldp.clip


def ldp_cumulative_epsilon(ldp: Optional[bl.LDPConfig], rounds: int,
                           q: float = 1.0,
                           delta: Optional[float] = None
                           ) -> Optional[dict]:
    """Accountant state for a scenario cell: cumulative (eps, delta) of
    ``rounds`` compositions of the LDP mechanism at sampling rate ``q``
    (participation fraction or 1 - client_dropout).  None when the cell
    has no LDP stage — the scenario's accountant column is then empty."""
    if ldp is None:
        return None
    delta = ldp.delta if delta is None else delta
    z = ldp_noise_multiplier(ldp)
    acc = RDPAccountant().step(z, q=q, steps=rounds)
    return {
        "noise_multiplier": z,
        "per_round_eps": ldp.eps,
        "rounds": rounds,
        "q": q,
        "delta": delta,
        "eps": acc.epsilon(delta),
    }
