"""ERIS round engine — Algorithm 1 (FSA with optional DSC).

The round step is a pure function over an ``ErisState`` and is jit- and
scan-friendly.  Client gradients are produced by a user-supplied
``grad_fn(x, client_batch) -> (n,)`` which is vmapped over clients.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import baselines as bl
from repro.core import dsc as dsc_lib
from repro.core import masks as masks_lib
from repro.core import pipeline as pl
from repro.core.compressors import Compressor, Identity


class ErisState(NamedTuple):
    x: jax.Array           # global model (n,)
    dsc: dsc_lib.DSCState  # reference vectors (zeros when DSC disabled)
    t: jax.Array           # round counter
    key: jax.Array
    buf: Any = None        # pl.BufferState under async buffered aggregation


@dataclasses.dataclass(frozen=True)
class ErisConfig:
    A: int = 4                      # number of client-side aggregators
    lr: float = 0.1
    compressor: Compressor = Identity()
    gamma: Optional[float] = None   # None -> gamma*(omega) of Thm 3.2
    mask_scheme: str = "strided"
    fresh_masks: bool = False       # re-draw random masks each round (m^t)
    use_dsc: bool = False
    # ---- FedBuff-style buffered async aggregation (pl.BufferedAggregate)
    async_buffer: bool = False
    buffer_cadence: int = 1
    staleness_alpha: float = 1.0
    delay_max: int = 0
    client_dropout: float = 0.0
    # ---- composed-defense / failure scenario axes (rounds.scenarios)
    ldp: Optional[bl.LDPConfig] = None   # clip + Gaussian noise pre-wire
    secure_mask: bool = False            # Bonawitz pairwise wire masking
    agg_dropout: float = 0.0             # aggregator dropout probability
    link_failure: float = 0.0            # client->aggregator link failure
    participation: float = 1.0           # Bernoulli client sampling

    def gamma_value(self, n: int) -> float:
        if self.gamma is not None:
            return self.gamma
        if not self.use_dsc:
            return 0.0
        return dsc_lib.gamma_star(self.compressor.omega(n))


def init(key: jax.Array, x0: jax.Array, K: int,
         async_buffer: bool = False) -> ErisState:
    n = x0.shape[0]
    return ErisState(x0, dsc_lib.init_state(K, n), jnp.zeros((), jnp.int32),
                     key, pl.init_buffer(n) if async_buffer else None)


# Role salts for the composed-scenario paths: when a stage that consumes
# the noise/fail/part role is actually in the stage list, that role's key
# is fold_in(k_comp, salt) instead of aliasing k_comp — otherwise LDP
# noise, failure draws, and participation sampling would be CORRELATED
# with the compression randomness (and with each other).  Roles with no
# active consumer keep the historical alias, so the pure-eris trajectory
# is bit-compatible (guarded by the parity battery).
ROLE_SALTS = {"noise": 0x4E0E, "fail": 0xFA11, "part": 0x9A87}


def stage_roles(compress: tuple[pl.CompressStage, ...],
                aggregate: pl.AggregateStage) -> frozenset[str]:
    """Key roles consumed by an eris stage list.  BufferedAggregate with
    the trivial arrival model draws nothing and is excluded (keeps the
    degenerate async==sync parity bit-exact)."""
    roles = {st.key_role for st in compress}
    agg = aggregate
    while isinstance(agg, pl.BufferedAggregate):
        if not agg.arrival.trivial:
            roles.add(agg.key_role)
        agg = agg.inner
    roles.add(agg.key_role)
    return frozenset(roles)


def _round_keys(k_mask: jax.Array, k_comp: jax.Array,
                active: frozenset[str] = frozenset()) -> pl.RoundKeys:
    """RoundKeys preserving this engine's historical 2-key discipline
    (mask + comp); roles without an active consumer alias comp (bit-
    compatible with the pre-stage-list implementation), while roles in
    ``active`` get a distinct salted derivation (see ROLE_SALTS)."""
    c0, c1 = jax.random.split(k_comp)

    def role(r: str) -> jax.Array:
        if r in active:
            return jax.random.fold_in(k_comp, ROLE_SALTS[r])
        return k_comp

    return pl.RoundKeys(mask=k_mask, comp=k_comp, noise=role("noise"),
                        fail=role("fail"), part=role("part"),
                        comp0=c0, comp1=c1,
                        wire=jax.random.fold_in(k_comp, 0x3177))


def stages(cfg: ErisConfig, n: int, keep_views: bool = False
           ) -> tuple[tuple[pl.CompressStage, ...], pl.AggregateStage]:
    """The declarative stage list this engine executes — the SAME stage
    objects the simulator registry composes and the distributed runtime
    applies leaf-wise (one round implementation, three engines).

    The fresh-mask (m^t) path aggregates through :class:`pl.FSASharded`
    with a keyed per-round assignment; the static-mask path uses the
    algebraic mean (Theorem B.1 — iterate-identical, no (A, K, n)
    materialization inside a scan)."""
    gamma = cfg.gamma_value(n)
    failures = cfg.agg_dropout > 0.0 or cfg.link_failure > 0.0
    if cfg.secure_mask and (failures or cfg.participation < 1.0
                            or cfg.client_dropout > 0.0):
        raise ValueError(
            "secure_mask cannot compose with failures/dropout/partial "
            "participation: pairwise masks cancel only in the unweighted "
            "full-cohort mean and this simplified Bonawitz protocol has "
            "no dropout-recovery round (Sec. 2) — the aggregate would be "
            "garbage of magnitude `scale`, so refuse loudly")
    compress: tuple[pl.CompressStage, ...] = ()
    if cfg.ldp is not None:
        compress += (pl.LDPNoise(ldp=cfg.ldp),)
    if cfg.use_dsc:
        compress += (pl.DSCCompress(compressor=cfg.compressor, gamma=gamma),)
    if cfg.secure_mask:
        compress += (pl.PairwiseMask(),)
    if failures:
        aggregate: pl.AggregateStage = pl.FailureInjectedFSA(
            A=cfg.A, mask_scheme=cfg.mask_scheme,
            agg_dropout=cfg.agg_dropout, link_failure=cfg.link_failure,
            use_dsc=cfg.use_dsc, gamma=gamma, keep_views=keep_views)
    elif cfg.fresh_masks or keep_views:
        aggregate = pl.FSASharded(
            A=cfg.A, mask_scheme=cfg.mask_scheme,
            fresh_masks=cfg.fresh_masks, use_dsc=cfg.use_dsc, gamma=gamma,
            keep_views=keep_views)
    elif cfg.use_dsc:
        aggregate = pl.DSCAggregate(gamma=gamma)
    else:
        aggregate = pl.AggregateStage()
    if cfg.async_buffer:
        if cfg.use_dsc:
            raise ValueError(
                "async_buffer does not compose with use_dsc: the Eq. 4 "
                "shift state tracks per-round aggregator receipts, which "
                "a cadence-delayed buffered apply breaks")
        aggregate = pl.BufferedAggregate(
            inner=aggregate, cadence=cfg.buffer_cadence,
            arrival=pl.ArrivalModel(delay_max=cfg.delay_max,
                                    dropout=cfg.client_dropout,
                                    alpha=cfg.staleness_alpha))
    return compress, aggregate


def round_step(state: ErisState, cfg: ErisConfig,
               grad_fn: Callable[[jax.Array, jax.Array], jax.Array],
               client_batches, weights: jax.Array | None = None,
               keep_views: bool = False):
    """One ERIS round.  Returns (new_state, aux) where aux carries the
    adversary-observable shard views when ``keep_views`` (privacy evals).
    """
    n = state.x.shape[0]
    key, k_mask, k_comp = jax.random.split(state.key, 3)
    compress, aggregate = stages(cfg, n, keep_views)
    active = stage_roles(compress, aggregate)
    sample = cfg.participation < 1.0 and weights is None
    if sample:
        active = active | {"part"}
    keys = _round_keys(k_mask, k_comp, active & set(ROLE_SALTS))
    if sample:
        K = state.dsc.s_clients.shape[0]
        weights = pl.participation_weights(keys.part, K, cfg.participation)

    # --- client-side: local stochastic gradients (Algorithm 1 line 3)
    grads = pl.ClientStep()(grad_fn, state.x, client_batches)  # (K, n)

    # --- compression (line 4) + FSA aggregation (lines 5-13): the stage
    # list, executed exactly as RoundPipeline.run_round does
    rstate = pl.RoundState(x=state.x, dsc=state.dsc, ef=None, server=None,
                           buf=state.buf)
    v = grads
    for stage in compress:
        v, rstate = stage.apply(keys, rstate, v)
    agg = aggregate.apply(keys, rstate, v, weights)
    x_new = state.x - cfg.lr * agg.update

    mask_stage = (aggregate.inner
                  if isinstance(aggregate, pl.BufferedAggregate)
                  else aggregate)
    assign = (mask_stage.assignment(keys, n)
              if isinstance(mask_stage, pl.FSASharded)
              else masks_lib.make_assignment(n, cfg.A, cfg.mask_scheme))
    new_state = ErisState(x_new, agg.state.dsc, state.t + 1, key,
                          agg.state.buf)
    aux = {"assign": assign, "transmitted": v, "shard_views": agg.views}
    return new_state, aux


def run(key: jax.Array, x0: jax.Array, cfg: ErisConfig, grad_fn,
        client_batches_per_round, T: int, weights=None):
    """Run T rounds with static per-round client batches
    (client_batches_per_round has leading dims (T, K, ...))."""
    state = init(key, x0, client_batches_per_round.shape[1],
                 async_buffer=cfg.async_buffer)

    def body(st, batches):
        st, _ = round_step(st, cfg, grad_fn, batches, weights)
        return st, st.x

    state, xs = jax.lax.scan(body, state, client_batches_per_round)
    return state, xs
