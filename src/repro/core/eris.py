"""ERIS round engine — Algorithm 1 (FSA with optional DSC).

The round step is a pure function over an ``ErisState`` and is jit- and
scan-friendly.  Client gradients are produced by a user-supplied
``grad_fn(x, client_batch) -> (n,)`` which is vmapped over clients.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import dsc as dsc_lib
from repro.core import fsa as fsa_lib
from repro.core import masks as masks_lib
from repro.core import pipeline as pl
from repro.core.compressors import Compressor, Identity


class ErisState(NamedTuple):
    x: jax.Array           # global model (n,)
    dsc: dsc_lib.DSCState  # reference vectors (zeros when DSC disabled)
    t: jax.Array           # round counter
    key: jax.Array


@dataclasses.dataclass(frozen=True)
class ErisConfig:
    A: int = 4                      # number of client-side aggregators
    lr: float = 0.1
    compressor: Compressor = Identity()
    gamma: Optional[float] = None   # None -> gamma*(omega) of Thm 3.2
    mask_scheme: str = "strided"
    fresh_masks: bool = False       # re-draw random masks each round (m^t)
    use_dsc: bool = False

    def gamma_value(self, n: int) -> float:
        if self.gamma is not None:
            return self.gamma
        if not self.use_dsc:
            return 0.0
        return dsc_lib.gamma_star(self.compressor.omega(n))


def init(key: jax.Array, x0: jax.Array, K: int) -> ErisState:
    n = x0.shape[0]
    return ErisState(x0, dsc_lib.init_state(K, n), jnp.zeros((), jnp.int32),
                     key)


def round_step(state: ErisState, cfg: ErisConfig,
               grad_fn: Callable[[jax.Array, jax.Array], jax.Array],
               client_batches, weights: jax.Array | None = None,
               keep_views: bool = False):
    """One ERIS round.  Returns (new_state, aux) where aux carries the
    adversary-observable shard views when ``keep_views`` (privacy evals).
    """
    n = state.x.shape[0]
    key, k_mask, k_comp = jax.random.split(state.key, 3)
    assign = masks_lib.make_assignment(
        n, cfg.A, "random" if cfg.fresh_masks else cfg.mask_scheme,
        key=k_mask if cfg.fresh_masks else None)

    # --- client-side: local stochastic gradients (Algorithm 1 line 3)
    grads = pl.ClientStep()(grad_fn, state.x, client_batches)  # (K, n)

    # --- compression stage (line 4) — shared with fl.py / launch/train.py
    gamma = cfg.gamma_value(n)
    if cfg.use_dsc:
        stage = pl.DSCCompress(compressor=cfg.compressor, gamma=gamma)
        v, dsc = stage.compress(k_comp, state.dsc, grads)
    else:
        v, dsc = grads, state.dsc

    # --- FSA partition + aggregator-side (lines 5-13)
    out = fsa_lib.fsa_round_sharded(
        jnp.zeros_like(state.x), v, assign, cfg.A, 1.0,
        weights=weights, keep_views=keep_views) if keep_views else None
    agg = (pl.DSCAggregate(gamma=gamma) if cfg.use_dsc
           else pl.AggregateStage())
    if cfg.use_dsc:
        v_global, dsc = agg.aggregate(dsc, v, weights)
    else:
        v_global = agg.mean(v, weights)
    x_new = state.x - cfg.lr * v_global

    new_state = ErisState(x_new, dsc, state.t + 1, key)
    aux = {"assign": assign, "transmitted": v,
           "shard_views": out.shard_views if keep_views else None}
    return new_state, aux


def run(key: jax.Array, x0: jax.Array, cfg: ErisConfig, grad_fn,
        client_batches_per_round, T: int, weights=None):
    """Run T rounds with static per-round client batches
    (client_batches_per_round has leading dims (T, K, ...))."""
    state = init(key, x0, client_batches_per_round.shape[1])

    def body(st, batches):
        st, _ = round_step(st, cfg, grad_fn, batches, weights)
        return st, st.x

    state, xs = jax.lax.scan(body, state, client_batches_per_round)
    return state, xs
