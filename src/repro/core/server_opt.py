"""Server-side federated optimizers (paper Sec. 5 'Benefits': FSA supports
any centralized FL algorithm — FedAdam, FedYogi, FedNova — because the
sharded aggregation is exact and these optimizers are coordinate-wise).

Each takes the aggregated pseudo-gradient v^t = mean_k v_k^t and produces
the model delta; under FSA every aggregator runs the same update on its
disjoint segment, which equals the centralized update (tested in
tests/test_server_opt.py)."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class ServerOpt(NamedTuple):
    init: Callable[[jax.Array], Any]
    update: Callable[[jax.Array, Any], tuple[jax.Array, Any]]
    name: str


def fedavg_server(lr: float) -> ServerOpt:
    return ServerOpt(lambda x: (),
                     lambda v, s: (-lr * v, s), "fedavg")


def fedadam(lr: float, b1: float = 0.9, b2: float = 0.99,
            tau: float = 1e-3) -> ServerOpt:
    """Reddi et al. 2021, Alg. 2 (Adam variant)."""
    def init(x):
        return (jnp.zeros_like(x), jnp.zeros_like(x))

    def update(v, state):
        m, u = state
        m = b1 * m + (1 - b1) * v
        u = b2 * u + (1 - b2) * v * v
        delta = -lr * m / (jnp.sqrt(u) + tau)
        return delta, (m, u)

    return ServerOpt(init, update, "fedadam")


def fedyogi(lr: float, b1: float = 0.9, b2: float = 0.99,
            tau: float = 1e-3) -> ServerOpt:
    """Reddi et al. 2021, Alg. 2 (Yogi variant): sign-controlled second
    moment, less drift under heterogeneity."""
    def init(x):
        return (jnp.zeros_like(x), jnp.zeros_like(x))

    def update(v, state):
        m, u = state
        m = b1 * m + (1 - b1) * v
        u = u - (1 - b2) * v * v * jnp.sign(u - v * v)
        delta = -lr * m / (jnp.sqrt(jnp.abs(u)) + tau)
        return delta, (m, u)

    return ServerOpt(init, update, "fedyogi")


def fednova_scale(local_steps: jax.Array) -> jax.Array:
    """FedNova (Wang et al. 2020) normalization weights for heterogeneous
    local-step counts tau_k: w_k ∝ 1 (objective-consistent re-weighting of
    normalized updates v_k / tau_k); returns per-client scale 1/tau_k."""
    return 1.0 / jnp.maximum(local_steps.astype(jnp.float32), 1.0)


def get_server_opt(name: str, lr: float) -> ServerOpt:
    return {"fedavg": fedavg_server, "fedadam": fedadam,
            "fedyogi": fedyogi}[name](lr)
