"""Shared settings layer: the async-runtime knobs spoken by BOTH config
surfaces.

``FLConfig`` (the simulator/scan engines) and ``TrainSettings`` (the
distributed shard_map runtime) used to carry five duplicated fields —
``population``, ``buffer_cadence``, ``staleness_alpha``, ``delay_max``,
``client_dropout`` — each validating (or forgetting to validate) them
independently.  :class:`AsyncSettings` is the single frozen dataclass
both consume: construction validates every field with an error naming
it, and the owners' flat legacy knobs resolve against an explicitly
provided ``AsyncSettings`` with a conflict error that also names the
field (set each knob in ONE place).

The flat fields stay on ``FLConfig``/``TrainSettings`` for one more PR
so existing call sites don't churn; everything downstream (the rounds
registry, ``make_train_step``) consumes ``.async_settings()``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.pipeline import ArrivalModel, CohortSample

ASYNC_FIELDS = ("population", "buffer_cadence", "staleness_alpha",
                "delay_max", "client_dropout")


@dataclasses.dataclass(frozen=True)
class AsyncSettings:
    """The population-scale async runtime knobs (fedbuff / eris_async
    methods and ``TrainSettings.async_buffer``), validated on
    construction.

    population       >0: batches carry the whole population on their
                     leading axis; the per-round cohort is drawn from it
    buffer_cadence   server applies the buffer every C rounds
    staleness_alpha  arrival weight 1/(1+tau)^alpha
    delay_max        straggler staleness tau ~ U{0..delay_max}
    client_dropout   arrival dropout (never contributes)
    """
    population: int = 0
    buffer_cadence: int = 1
    staleness_alpha: float = 1.0
    delay_max: int = 0
    client_dropout: float = 0.0

    def __post_init__(self):
        if self.population < 0:
            raise ValueError(f"AsyncSettings.population must be >= 0, "
                             f"got {self.population}")
        if self.buffer_cadence < 1:
            raise ValueError(f"AsyncSettings.buffer_cadence must be >= 1, "
                             f"got {self.buffer_cadence}")
        if self.staleness_alpha < 0:
            raise ValueError(f"AsyncSettings.staleness_alpha must be >= 0, "
                             f"got {self.staleness_alpha}")
        if self.delay_max < 0:
            raise ValueError(f"AsyncSettings.delay_max must be >= 0, "
                             f"got {self.delay_max}")
        if not 0.0 <= self.client_dropout <= 1.0:
            # 1.0 (everyone drops) is legal — the fedbuff property tests
            # use it to prove dropped arrivals contribute zero weight
            raise ValueError(f"AsyncSettings.client_dropout must be in "
                             f"[0, 1], got {self.client_dropout}")

    # ------------------------------------------------ derived pipeline bits
    def arrival_model(self) -> ArrivalModel:
        return ArrivalModel(delay_max=self.delay_max,
                            dropout=self.client_dropout,
                            alpha=self.staleness_alpha)

    def cohort(self, K: int) -> Optional[CohortSample]:
        """Keyed per-round cohort draw, or None when population-scale
        selection is off."""
        if not self.population:
            return None
        if self.population < K:
            raise ValueError(
                f"AsyncSettings.population ({self.population}) must be >= "
                f"cohort size K ({K})")
        return CohortSample(population=self.population, cohort=K)

    # --------------------------------------------------------- construction
    @classmethod
    def from_knobs(cls, obj) -> "AsyncSettings":
        """Build from any object carrying (a subset of) the flat legacy
        knobs — FLConfig, TrainSettings, or a duck-typed stand-in."""
        defaults = {f.name: f.default for f in dataclasses.fields(cls)}
        return cls(**{name: getattr(obj, name, defaults[name])
                      for name in ASYNC_FIELDS})


def resolve_async(owner: str, explicit: Optional[AsyncSettings],
                  obj) -> AsyncSettings:
    """Resolve an owner's async knobs: its flat legacy fields, or an
    explicitly attached :class:`AsyncSettings` — never a disagreeing mix.

    A flat field that moved off its default while ``explicit`` says
    something else is a configuration bug; the error names the field so
    the caller knows exactly which knob is set in two places.
    """
    flat = AsyncSettings.from_knobs(obj)
    if explicit is None:
        return flat
    defaults = AsyncSettings()
    for name in ASYNC_FIELDS:
        flat_v, exp_v = getattr(flat, name), getattr(explicit, name)
        if flat_v != getattr(defaults, name) and flat_v != exp_v:
            raise ValueError(
                f"{owner}.{name}={flat_v!r} conflicts with "
                f"AsyncSettings.{name}={exp_v!r}: set the async knob in "
                f"one place (the flat field is deprecated; prefer "
                f"AsyncSettings)")
    return explicit
