"""Privacy analysis + attacks (Theorem 3.3, Corollary D.2, Section 4.1).

* ``mi_bound``      — the information-theoretic bound  I <= n T p A_c / A * C_max
* ``gaussian_cmax`` — the Gaussian instantiation  C_max <= 1/2 log(1+SNR)
* ``mia_audit``     — Steinke-style one-run canary auditing, gradient-
                      alignment attacker restricted to the coordinates the
                      adversary (an aggregator, or a colluding coalition
                      of a_c aggregators) actually observes.  Rounds are
                      consumed under ``lax.scan`` (memory stays O(C * n)
                      however long the trajectory) and the audit key
                      drives a bootstrap confidence interval on AUC /
                      balanced accuracy, so CI gates can compare
                      intervals instead of point estimates.
* ``mia_audit_sweep`` — the same audit vmapped over a STACK of
                      observation masks (per-aggregator, or the colluding
                      unions of Cor. D.2): one compiled program per
                      leakage curve.
* ``dlg_attack``    — DLG gradient-inversion (Zhu et al. 2019) against a
                      masked observed gradient; ``dlg_attack_batch``
                      vmaps it over a canary batch.
"""
from __future__ import annotations

import math
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.optim import adam


# ------------------------------------------------------- theoretical bounds
def mi_bound(n: int, T: int, p: float, A: int, c_max: float = 1.0,
             a_c: int = 1) -> float:
    """Mutual-information leakage bound (Thm 3.3 / Cor D.2):
    I(D_k; views) <= n * T * (p * A_c / A) * C_max."""
    return n * T * (p * a_c / A) * c_max


def gaussian_cmax(snr: float) -> float:
    """Per-coordinate MI under the Gaussian model of Remark D.1."""
    return 0.5 * math.log(1.0 + snr)


def observed_fraction(p: float, A: int, a_c: int = 1) -> float:
    """Expected fraction of update coordinates visible per round."""
    return p * a_c / A


# ----------------------------------------------------------------- MIA audit
def _mia_scores(grad_fn: Callable, x_traj: jax.Array, views: jax.Array,
                obs_mask: jax.Array, all_c: jax.Array) -> jax.Array:
    """Per-canary alignment scores, rounds folded under ``lax.scan``.

    For each canary c, score = sum_t <g~(x^t, c)|_obs, view^t|_obs> / ||view^t|_obs||
    where g~ is the canary gradient CALIBRATED by subtracting the mean
    gradient over all canaries (removes the shared non-member component,
    the same debiasing idea as Steinke et al.'s paired auditing).  Only
    the *view* is normalized (scale-stabilizes across rounds); the canary
    gradient's magnitude is deliberately kept — how strongly a canary
    still pulls on the model is itself membership signal, and dividing it
    out (a plain cosine) measurably weakens the audit.
    """
    def per_round(acc, xv):
        x_t, v_t = xv
        g = jax.vmap(lambda c: grad_fn(x_t, c))(all_c) * obs_mask
        g = g - g.mean(0, keepdims=True)           # calibration
        v = v_t * obs_mask
        return acc + (g @ v) / (jnp.linalg.norm(v) + 1e-12), None

    scores, _ = jax.lax.scan(per_round, jnp.zeros(all_c.shape[0]),
                             (x_traj, views))
    return scores


def _auc_balacc(s_in: jax.Array, s_out: jax.Array):
    auc = jnp.mean((s_in[:, None] > s_out[None, :]).astype(jnp.float32))
    thresh = jnp.median(jnp.concatenate([s_in, s_out]))
    bal = 0.5 * (jnp.mean(s_in > thresh) + jnp.mean(s_out <= thresh))
    return auc, bal


def _mia_stats(key: jax.Array, grad_fn: Callable, x_traj: jax.Array,
               views: jax.Array, obs_mask: jax.Array,
               canaries_in: jax.Array, canaries_out: jax.Array,
               n_bootstrap: int) -> dict:
    """Array-valued audit core (vmap-friendly; see :func:`mia_audit`)."""
    n_in = canaries_in.shape[0]
    n_out = canaries_out.shape[0]
    all_c = jnp.concatenate([canaries_in, canaries_out], axis=0)
    scores = _mia_scores(grad_fn, x_traj, views, obs_mask, all_c)
    s_in, s_out = scores[:n_in], scores[n_in:]
    auc, bal = _auc_balacc(s_in, s_out)
    out = {"auc": auc, "balanced_accuracy": bal,
           "score_gap": s_in.mean() - s_out.mean()}
    if n_bootstrap:
        # percentile bootstrap over canaries (members and non-members
        # resampled independently, preserving the class sizes)
        def boot(k):
            ki, ko = jax.random.split(k)
            si = s_in[jax.random.randint(ki, (n_in,), 0, n_in)]
            so = s_out[jax.random.randint(ko, (n_out,), 0, n_out)]
            return _auc_balacc(si, so)

        aucs, bals = jax.vmap(boot)(jax.random.split(key, n_bootstrap))
        q = jnp.array([2.5, 97.5])
        out["auc_ci"] = jnp.percentile(aucs, q)
        out["bal_acc_ci"] = jnp.percentile(bals, q)
    return out


def attack_mesh(n_canaries: int,
                devices: Optional[Sequence] = None) -> Mesh:
    """The 1-D ``attack`` mesh for sharded audit compute: the largest
    device prefix whose size divides the canary count (one device
    degenerates to the unsharded audit)."""
    devices = list(jax.devices() if devices is None else devices)
    d = len(devices)
    while n_canaries % d:
        d -= 1
    return Mesh(np.asarray(devices[:d]), ("attack",))


def mia_audit(key: jax.Array,
              grad_fn: Callable[[jax.Array, jax.Array], jax.Array],
              x_traj: jax.Array,           # (T, n) model iterates
              views: jax.Array,            # (T, n) adversary-observed update
              obs_mask: jax.Array,         # (n,) 0/1 observed coordinates
              canaries_in: jax.Array,      # (C, ...) member canary samples
              canaries_out: jax.Array,     # (C, ...) non-member canaries
              n_bootstrap: int = 200,
              mesh: Optional[Mesh] = None) -> dict:
    """Gradient-alignment membership inference (see :func:`_mia_scores`).

    Members (whose gradients actually entered the observed update) score
    higher.  Returns AUC-style pairwise accuracy and balanced accuracy at
    the median threshold — the metric family used for Fig. 2 trends —
    plus 95% bootstrap intervals ``auc_ci`` / ``bal_acc_ci`` keyed on
    ``key`` (``n_bootstrap=0`` disables them).

    ``mesh`` (an :func:`attack_mesh`) shards the attack compute: the
    canary batch is placed split over the ``attack`` axis, so the
    per-round canary-gradient vmap — the O(C * T * n) wall the
    transformer-scale audits hit — partitions across devices while the
    trajectory/views stay replicated.  The calibration mean is the only
    cross-canary reduction, so the scores match the single-device audit
    up to reduction order.  At transformer scale this is what makes
    LARGE canary batches affordable; with a handful of canaries the AUC
    estimate has so few distinguishable orderings that memorizing runs
    pin it to exactly 1.0."""
    if mesh is not None and mesh.devices.size > 1:
        cast = NamedSharding(mesh, P("attack"))
        rep = NamedSharding(mesh, P())
        canaries_in = jax.device_put(canaries_in, cast)
        canaries_out = jax.device_put(canaries_out, cast)
        x_traj, views, obs_mask, key = jax.device_put(
            (x_traj, views, obs_mask, key), rep)
        stats = jax.jit(
            lambda *a: _mia_stats(a[0], grad_fn, a[1], a[2], a[3], a[4],
                                  a[5], n_bootstrap))(
            key, x_traj, views, obs_mask, canaries_in, canaries_out)
    else:
        stats = _mia_stats(key, grad_fn, x_traj, views, obs_mask,
                           canaries_in, canaries_out, n_bootstrap)
    out = {k: float(v) for k, v in stats.items() if jnp.ndim(v) == 0}
    for k in ("auc_ci", "bal_acc_ci"):
        if k in stats:
            lo, hi = jax.device_get(stats[k])
            out[k] = (float(lo), float(hi))
    return out


def mia_audit_sweep(key: jax.Array, grad_fn: Callable,
                    x_traj: jax.Array,        # (T, n)
                    views: jax.Array,         # (M, T, n) per-mask views
                    obs_masks: jax.Array,     # (M, n) mask stack
                    canaries_in: jax.Array, canaries_out: jax.Array,
                    n_bootstrap: int = 200) -> dict:
    """One compiled attack suite for a whole leakage curve: the audit
    vmapped over a stack of observation masks (e.g. every aggregator, or
    the colluding unions a_c = 1..A of Cor. D.2) with the matching
    per-mask view trajectories.  Returns arrays of shape (M,) (CIs:
    (M, 2))."""
    keys = jax.random.split(key, obs_masks.shape[0])
    stats = jax.vmap(
        lambda k, v, m: _mia_stats(k, grad_fn, x_traj, v, m, canaries_in,
                                   canaries_out, n_bootstrap))(
        keys, views, obs_masks)
    return jax.device_get(stats)


# ------------------------------------------------------------------ DLG/iDLG
def dlg_attack(key: jax.Array,
               grad_fn: Callable[[jax.Array, jax.Array, jax.Array], jax.Array],
               x: jax.Array,                # model at attack round (n,)
               g_obs: jax.Array,            # observed (masked) gradient (n,)
               obs_mask: jax.Array,         # (n,) 0/1
               input_shape: tuple,
               label: jax.Array,            # iDLG: label assumed recovered
               steps: int = 300, lr: float = 0.1) -> dict:
    """Reconstruct the input from an observed (possibly FSA/DSC-masked,
    possibly int8-wire round-tripped) per-sample gradient by gradient
    matching on observed coordinates (``lax.scan`` over attack steps)."""
    dummy0 = 0.1 * jax.random.normal(key, input_shape)

    def match_loss(dummy):
        g = grad_fn(x, dummy, label) * obs_mask
        return jnp.sum((g - g_obs * obs_mask) ** 2)

    opt = adam(lr)
    state0 = opt.init(dummy0)

    def body(carry, _):
        dummy, st = carry
        loss, g = jax.value_and_grad(match_loss)(dummy)
        delta, st = opt.update(g, st, dummy)
        return (dummy + delta, st), loss

    (dummy, _), losses = jax.lax.scan(body, (dummy0, state0), None,
                                      length=steps)
    return {"reconstruction": dummy, "match_losses": losses}


def dlg_attack_batch(key: jax.Array, grad_fn: Callable, x: jax.Array,
                     g_obs: jax.Array,       # (C, n) observed gradients
                     obs_mask: jax.Array, input_shape: tuple,
                     labels: jax.Array,      # (C,) recovered labels
                     steps: int = 300, lr: float = 0.1) -> dict:
    """DLG vmapped over a canary batch: C independent inversions in ONE
    compiled program (shared model point and mask)."""
    keys = jax.random.split(key, g_obs.shape[0])
    return jax.vmap(
        lambda k, g, lab: dlg_attack(k, grad_fn, x, g, obs_mask,
                                     input_shape, lab, steps, lr))(
        keys, g_obs, labels)


def reconstruction_mse(recon: jax.Array, target: jax.Array) -> float:
    """Scale-invariant reconstruction error (lower = better attack)."""
    r = (recon - recon.mean()) / (recon.std() + 1e-8)
    t = (target - target.mean()) / (target.std() + 1e-8)
    return float(jnp.mean((r - t) ** 2))
