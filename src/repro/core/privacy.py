"""Privacy analysis + attacks (Theorem 3.3, Corollary D.2, Section 4.1).

* ``mi_bound``      — the information-theoretic bound  I <= n T p A_c / A * C_max
* ``gaussian_cmax`` — the Gaussian instantiation  C_max <= 1/2 log(1+SNR)
* ``mia_audit``     — Steinke-style one-run canary auditing, gradient-
                      alignment attacker restricted to the coordinates the
                      adversary (a single aggregator) actually observes
* ``dlg_attack``    — DLG gradient-inversion (Zhu et al. 2019) against a
                      masked observed gradient; reports reconstruction MSE
"""
from __future__ import annotations

import math
from typing import Callable

import jax
import jax.numpy as jnp

from repro.optim import adam


# ------------------------------------------------------- theoretical bounds
def mi_bound(n: int, T: int, p: float, A: int, c_max: float = 1.0,
             a_c: int = 1) -> float:
    """Mutual-information leakage bound (Thm 3.3 / Cor D.2):
    I(D_k; views) <= n * T * (p * A_c / A) * C_max."""
    return n * T * (p * a_c / A) * c_max


def gaussian_cmax(snr: float) -> float:
    """Per-coordinate MI under the Gaussian model of Remark D.1."""
    return 0.5 * math.log(1.0 + snr)


def observed_fraction(p: float, A: int, a_c: int = 1) -> float:
    """Expected fraction of update coordinates visible per round."""
    return p * a_c / A


# ----------------------------------------------------------------- MIA audit
def mia_audit(key: jax.Array,
              grad_fn: Callable[[jax.Array, jax.Array], jax.Array],
              x_traj: jax.Array,           # (T, n) model iterates
              views: jax.Array,            # (T, n) adversary-observed update
              obs_mask: jax.Array,         # (n,) 0/1 observed coordinates
              canaries_in: jax.Array,      # (C, ...) member canary samples
              canaries_out: jax.Array      # (C, ...) non-member canaries
              ) -> dict:
    """Gradient-alignment membership inference.

    For each canary c, score = sum_t <g~(x^t, c)|_obs, view^t|_obs> / ||view^t|_obs||
    where g~ is the canary gradient CALIBRATED by subtracting the mean
    gradient over all canaries (removes the shared non-member component,
    the same debiasing idea as Steinke et al.'s paired auditing).  Only
    the *view* is normalized (scale-stabilizes across rounds); the canary
    gradient's magnitude is deliberately kept — how strongly a canary
    still pulls on the model is itself membership signal, and dividing it
    out (a plain cosine) measurably weakens the audit.  Members (whose
    gradients actually entered the observed update) score higher.
    Returns AUC-style pairwise accuracy and balanced accuracy at the
    median threshold — the metric family used for Fig. 2 trends.
    """
    del key
    n_in = canaries_in.shape[0]
    all_c = jnp.concatenate([canaries_in, canaries_out], axis=0)

    def per_round(x_t, v_t):
        g = jax.vmap(lambda c: grad_fn(x_t, c))(all_c) * obs_mask
        g = g - g.mean(0, keepdims=True)           # calibration
        v = v_t * obs_mask
        return (g @ v) / (jnp.linalg.norm(v) + 1e-12)

    scores = jax.vmap(per_round)(x_traj, views).sum(0)
    s_in, s_out = scores[:n_in], scores[n_in:]
    auc = jnp.mean((s_in[:, None] > s_out[None, :]).astype(jnp.float32))
    thresh = jnp.median(jnp.concatenate([s_in, s_out]))
    bal_acc = 0.5 * (jnp.mean(s_in > thresh) + jnp.mean(s_out <= thresh))
    return {"auc": float(auc), "balanced_accuracy": float(bal_acc),
            "score_gap": float(s_in.mean() - s_out.mean())}


# ------------------------------------------------------------------ DLG/iDLG
def dlg_attack(key: jax.Array,
               grad_fn: Callable[[jax.Array, jax.Array, jax.Array], jax.Array],
               x: jax.Array,                # model at attack round (n,)
               g_obs: jax.Array,            # observed (masked) gradient (n,)
               obs_mask: jax.Array,         # (n,) 0/1
               input_shape: tuple,
               label: jax.Array,            # iDLG: label assumed recovered
               steps: int = 300, lr: float = 0.1) -> dict:
    """Reconstruct the input from an observed (possibly FSA/DSC-masked)
    per-sample gradient by gradient matching on observed coordinates."""
    dummy0 = 0.1 * jax.random.normal(key, input_shape)

    def match_loss(dummy):
        g = grad_fn(x, dummy, label) * obs_mask
        return jnp.sum((g - g_obs * obs_mask) ** 2)

    opt = adam(lr)
    state0 = opt.init(dummy0)

    def body(carry, _):
        dummy, st = carry
        loss, g = jax.value_and_grad(match_loss)(dummy)
        delta, st = opt.update(g, st, dummy)
        return (dummy + delta, st), loss

    (dummy, _), losses = jax.lax.scan(body, (dummy0, state0), None,
                                      length=steps)
    return {"reconstruction": dummy, "match_losses": losses}


def reconstruction_mse(recon: jax.Array, target: jax.Array) -> float:
    """Scale-invariant reconstruction error (lower = better attack)."""
    r = (recon - recon.mean()) / (recon.std() + 1e-8)
    t = (target - target.mean()) / (target.std() + 1e-8)
    return float(jnp.mean((r - t) ** 2))
