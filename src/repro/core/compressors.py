"""Unbiased omega-compression operators (Definition 3.1 of the paper).

A randomized map C: R^n -> R^n is an omega-compressor if
    E[C(x)] = x   and   E[||C(x) - x||^2] <= omega * ||x||^2.

All compressors operate on flat f32 vectors.  Each returns the compressed
vector *densely represented* (zeros at dropped coordinates); the number of
coordinates/bits actually transmitted on a wire is reported by
``wire_bits`` so scalability benchmarks can account payloads exactly, as
the paper does in Table 2 / Appendix F.2.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Compressor:
    """Base class.  Subclasses implement __call__(key, x) -> x_hat."""

    name: str = "identity"

    def __call__(self, key: jax.Array, x: jax.Array) -> jax.Array:
        del key
        return x

    def omega(self, n: int) -> float:
        """Variance parameter of Definition 3.1."""
        del n
        return 0.0

    def retention(self, n: int) -> float:
        """Expected fraction of coordinates present in the output."""
        del n
        return 1.0

    def wire_bits(self, n: int) -> float:
        """Expected number of bits on the wire for an n-vector."""
        return 32.0 * n

    @property
    def unbiased(self) -> bool:
        return True


@dataclasses.dataclass(frozen=True)
class Identity(Compressor):
    name: str = "identity"


@dataclasses.dataclass(frozen=True)
class RandP(Compressor):
    """Random (Bernoulli) sparsification: keep each coordinate w.p. p,
    scale kept coordinates by 1/p.  omega = (1-p)/p (paper, Sec. 3.2.2)."""

    p: float = 0.1
    name: str = "rand_p"

    def __call__(self, key, x):
        mask = jax.random.bernoulli(key, self.p, x.shape)
        return jnp.where(mask, x / self.p, 0.0)

    def omega(self, n):
        return (1.0 - self.p) / self.p

    def retention(self, n):
        return self.p

    def wire_bits(self, n):
        # value + index per surviving coordinate
        return self.p * n * (32.0 + jnp.ceil(jnp.log2(max(n, 2))))


@dataclasses.dataclass(frozen=True)
class RandK(Compressor):
    """Random-k sparsification: keep exactly k uniformly chosen coordinates,
    scale by n/k.  omega = n/k - 1."""

    k: int = 128
    name: str = "rand_k"

    def __call__(self, key, x):
        n = x.shape[-1]
        # Gumbel top-k gives a uniform k-subset without a full permutation.
        scores = jax.random.gumbel(key, (n,))
        thresh = jax.lax.top_k(scores, self.k)[0][-1]
        mask = scores >= thresh
        return jnp.where(mask, x * (n / self.k), 0.0)

    def omega(self, n):
        return n / self.k - 1.0

    def retention(self, n):
        return self.k / n

    def wire_bits(self, n):
        return self.k * (32.0 + jnp.ceil(jnp.log2(max(n, 2))))


@dataclasses.dataclass(frozen=True)
class QSGD(Compressor):
    """QSGD stochastic quantization (Alistarh et al. 2017) with s levels.

    C(x) = ||x||_2 * sign(x_i) * xi_i where xi_i in {0, 1/s, ..., 1} is a
    stochastic rounding of |x_i|/||x||_2.  Unbiased; omega <= min(n/s^2,
    sqrt(n)/s).
    """

    s: int = 16
    name: str = "qsgd"

    def __call__(self, key, x):
        norm = jnp.linalg.norm(x)
        safe = jnp.where(norm > 0, norm, 1.0)
        y = jnp.abs(x) / safe * self.s          # in [0, s]
        low = jnp.floor(y)
        prob = y - low
        up = jax.random.bernoulli(key, prob, x.shape)
        q = (low + up) / self.s
        out = norm * jnp.sign(x) * q
        return jnp.where(norm > 0, out, 0.0)

    def omega(self, n):
        return float(min(n / self.s**2, (n**0.5) / self.s))

    def retention(self, n):
        return 1.0  # all coordinates exposed (quantized)

    def wire_bits(self, n):
        import math
        return 32.0 + n * (1 + math.ceil(math.log2(self.s + 1)))


@dataclasses.dataclass(frozen=True)
class TopK(Compressor):
    """Top-k by magnitude.  BIASED (not an omega-compressor); included as a
    baseline ingredient (PriPrune-style defenses, Table 7)."""

    k: int = 128
    name: str = "top_k"

    def __call__(self, key, x):
        del key
        thresh = jax.lax.top_k(jnp.abs(x), self.k)[0][-1]
        return jnp.where(jnp.abs(x) >= thresh, x, 0.0)

    def omega(self, n):
        return float("nan")

    def retention(self, n):
        return self.k / n

    def wire_bits(self, n):
        return self.k * (32.0 + jnp.ceil(jnp.log2(max(n, 2))))

    @property
    def unbiased(self) -> bool:
        return False


@dataclasses.dataclass(frozen=True)
class Int8RoundTrip(Compressor):
    """Wire-format composition: inner omega-compressor followed by
    per-block stochastic int8 quantize->dequantize (the same math as the
    Pallas ``kernels/quantize`` pair, in vmap-safe jnp form).

    Both stages are unbiased, so the composition is an omega-compressor;
    the int8 stage's variance contribution (bounded by the per-block
    absmax / 254 rounding grid) is negligible next to any sparsifying
    inner compressor, so ``omega`` reports the inner bound.  Used when a
    wire format must compose with DSC/EF: the shifted references then
    update with exactly the values the aggregators receive.
    """

    inner: Compressor = Identity()
    block: int = 256
    name: str = "int8_round_trip"

    def __call__(self, key, x):
        from repro.kernels import ref as kref
        k_in, k_q = jax.random.split(key)
        y = self.inner(k_in, x)
        n = y.shape[-1]
        seed = jax.random.bits(k_q, dtype=jnp.uint32)
        q, scale = kref.quantize_ref(y, seed, block=self.block)
        return kref.dequantize_ref(q, scale, block=self.block)[:n]

    def omega(self, n):
        return self.inner.omega(n)

    def retention(self, n):
        return self.inner.retention(n)

    def wire_bits(self, n):
        # the quantizer runs on the DENSE inner output, so the wire
        # carries a dense int8 vector + one f32 scale per block
        import math
        return 8.0 * n + 32.0 * math.ceil(n / self.block)

    @property
    def unbiased(self) -> bool:
        return self.inner.unbiased


def get_compressor(name: str, n: Optional[int] = None, **kw) -> Compressor:
    name = name.lower()
    if name in ("identity", "none"):
        return Identity()
    if name == "rand_p":
        return RandP(p=kw.get("p", 0.1))
    if name == "rand_k":
        return RandK(k=kw.get("k", max(1, (n or 1024) // 10)))
    if name == "qsgd":
        return QSGD(s=kw.get("s", 16))
    if name == "top_k":
        return TopK(k=kw.get("k", max(1, (n or 1024) // 10)))
    raise ValueError(f"unknown compressor {name!r}")
