"""FSA shard masks (Section 3.2.1).

A mask set {m_(a)}_{a=1..A} over R^n must be *disjoint*
(m_a ⊙ m_a' = 0 for a != a') and *complete* (sum_a m_a = 1_n).  We store
the set as a single integer *assignment vector* ``assign`` of shape (n,)
with values in [0, A): coordinate i belongs to aggregator assign[i].  This
is memory-proportional to n rather than A*n and makes disjointness and
completeness true by construction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def make_assignment(n: int, A: int, scheme: str = "strided",
                    key: jax.Array | None = None) -> jax.Array:
    """Build the shard assignment for n coordinates over A aggregators.

    Schemes:
      * ``strided``    — round robin (i mod A); balanced to within 1.
      * ``contiguous`` — A contiguous coordinate blocks.
      * ``random``     — random permutation of the strided assignment
                         (fresh masks per round when a per-round key is
                         supplied, matching the paper's m^t notation).
    """
    if A < 1:
        raise ValueError("need A >= 1 aggregators")
    base = jnp.arange(n, dtype=jnp.int32) % A
    if scheme == "strided":
        return base
    if scheme == "contiguous":
        return jnp.minimum(jnp.arange(n, dtype=jnp.int32) * A // max(n, 1),
                           A - 1).astype(jnp.int32)
    if scheme == "random":
        if key is None:
            raise ValueError("random scheme needs a PRNG key")
        return jax.random.permutation(key, base)
    raise ValueError(f"unknown scheme {scheme!r}")


def mask_for(assign: jax.Array, a) -> jax.Array:
    """Binary mask m_(a) for aggregator a (float32, shape (n,))."""
    return (assign == a).astype(jnp.float32)


def union_mask(assign: jax.Array, coalition) -> jax.Array:
    """Colluding-coalition view mask (Cor. D.2): the union of the
    coalition members' masks.  Disjointness makes the union a plain sum,
    so its density is exactly ``observed_fraction(1.0, A, a_c)`` up to
    per-mask rounding."""
    coalition = jnp.asarray(coalition, dtype=jnp.int32)
    return (assign[None, :] == coalition[:, None]).any(0).astype(jnp.float32)


def masks_stacked(assign: jax.Array, A: int) -> jax.Array:
    """All masks as an (A, n) stack (small-n simulator/testing only)."""
    return jax.nn.one_hot(assign, A, dtype=jnp.float32).T


def check_disjoint_complete(assign: jax.Array, A: int) -> bool:
    m = masks_stacked(assign, A)
    disjoint = bool(jnp.all((m[:, None] * m[None]).sum(-1)
                            * (1 - jnp.eye(A)) == 0))
    complete = bool(jnp.all(m.sum(0) == 1))
    return disjoint and complete


def make_weighted_assignment(n: int, weights, key: jax.Array | None = None
                             ) -> jax.Array:
    """Heterogeneous shards (paper Sec. 5 'Limitations'): aggregator a
    receives a fraction weights[a] of the coordinates — larger shards for
    stronger aggregators, smaller for bandwidth-constrained ones.  Only
    disjointness+completeness are required, so any weight vector works;
    worst-case leakage becomes max_a weights[a] * n * C_max per round
    instead of n/A."""
    import numpy as np
    w = np.asarray(weights, dtype=np.float64)
    w = w / w.sum()
    bounds = np.floor(np.cumsum(w) * n + 0.5).astype(np.int32)
    assign = np.zeros(n, dtype=np.int32)
    start = 0
    for a, b in enumerate(bounds):
        assign[start:b] = a
        start = b
    out = jnp.asarray(assign)
    if key is not None:
        out = jax.random.permutation(key, out)
    return out


def shard_sizes(assign: jax.Array, A: int) -> jax.Array:
    """Number of coordinates per aggregator (worst-case leakage is driven
    by the largest shard — Sec. 5 'Limitations')."""
    return jnp.bincount(assign, length=A)
