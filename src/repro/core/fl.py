"""Federated engine: runs ERIS or any baseline over a model + client data.

Models are pytrees; the engine flattens them once (ravel_pytree) so every
method operates on the paper's R^n update vectors, then unravels for
evaluation.  This is the laptop-scale simulator used by the convergence,
privacy, and utility benchmarks; the production multi-pod path lives in
repro.launch.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.core import baselines as bl
from repro.core import dsc as dsc_lib
from repro.core import error_feedback as ef_lib
from repro.core import fsa as fsa_lib
from repro.core import masks as masks_lib
from repro.core import secure_agg as sa_lib
from repro.core import server_opt as so_lib
from repro.core.compressors import Compressor, Identity


@dataclasses.dataclass(frozen=True)
class FLConfig:
    method: str = "eris"          # eris|fedavg|fedavg_ldp|soteriafl|priprune|
                                  # shatter|secure_agg|min_leakage
    K: int = 8                    # clients
    A: int = 4                    # aggregators (eris)
    rounds: int = 50
    lr: float = 0.1
    use_dsc: bool = False
    use_ef: bool = False          # error feedback (biased compressors)
    compressor: Compressor = Identity()
    server_opt: str = "fedavg"    # fedavg|fedadam|fedyogi (Sec. 5 Benefits)
    participation: float = 1.0    # client sampling fraction per round
    gamma: Optional[float] = None
    mask_scheme: str = "strided"
    ldp: Optional[bl.LDPConfig] = None
    prune_rate: float = 0.1       # priprune
    shatter_chunks: int = 8
    shatter_r: int = 4
    agg_dropout: float = 0.0      # appendix F.5 failure injection
    link_failure: float = 0.0
    seed: int = 0


class FLRun:
    """Holds the jitted round function and mutable training state."""

    def __init__(self, cfg: FLConfig, params0: Any,
                 loss_fn: Callable[[Any, Any], jax.Array]):
        self.cfg = cfg
        flat0, self.unravel = ravel_pytree(params0)
        self.n = flat0.shape[0]
        self.x = flat0
        self.key = jax.random.PRNGKey(cfg.seed)
        self.loss_fn = loss_fn
        self._grad = jax.grad(lambda x, b: loss_fn(self.unravel(x), b))
        self.dsc = dsc_lib.init_state(cfg.K, self.n)
        self.ef = ef_lib.init_state(cfg.K, self.n)
        self.server = so_lib.get_server_opt(cfg.server_opt, cfg.lr)
        self.server_state = self.server.init(flat0)
        self.history: list[dict] = []
        self._round = jax.jit(self._round_impl)

    # ---------------------------------------------------------------- core
    def _client_grads(self, x, batches):
        return jax.vmap(lambda b: self._grad(x, b))(batches)

    def _round_impl(self, key, x, dsc, ef, server_state, batches):
        cfg = self.cfg
        grads = self._client_grads(x, batches)
        k_m, k_c, k_n, k_f, k_p = jax.random.split(key, 5)
        views = None
        ef_new = ef
        # partial participation: sample clients; weights renormalize the
        # aggregation over the sampled subset (at least one participates)
        if cfg.participation < 1.0:
            part = jax.random.bernoulli(k_p, cfg.participation, (cfg.K,))
            part = part.at[jax.random.randint(k_p, (), 0, cfg.K)].set(True)
            weights = part.astype(jnp.float32)
        else:
            weights = None
        if cfg.method in ("fedavg", "min_leakage"):
            x_new, dsc_new = bl.fedavg_round(x, grads, cfg.lr,
                                             weights=weights), dsc
            views = grads if cfg.method == "fedavg" else None
        elif cfg.method == "secure_agg":
            x_new, views = sa_lib.secure_agg_round(k_c, x, grads, cfg.lr)
            dsc_new = dsc
        elif cfg.method == "fedavg_ldp":
            noised = bl.ldp_perturb(k_n, grads, cfg.ldp or bl.LDPConfig())
            x_new, dsc_new, views = bl.fedavg_round(x, noised, cfg.lr), dsc, noised
        elif cfg.method == "soteriafl":
            gamma = cfg.gamma if cfg.gamma is not None else \
                dsc_lib.gamma_star(cfg.compressor.omega(self.n))
            x_new, st = bl.soteriafl_round(
                k_c, x, grads, cfg.lr, bl.SoteriaState(dsc),
                cfg.compressor, gamma, cfg.ldp)
            dsc_new, views = st.dsc, None
        elif cfg.method == "priprune":
            x_new, dsc_new = bl.priprune_round(x, grads, cfg.lr,
                                               cfg.prune_rate), dsc
        elif cfg.method == "shatter":
            x_new, dsc_new = bl.shatter_round(
                k_c, x, grads, cfg.lr, cfg.shatter_chunks, cfg.shatter_r), dsc
        elif cfg.method == "eris":
            gamma = cfg.gamma if cfg.gamma is not None else (
                dsc_lib.gamma_star(cfg.compressor.omega(self.n))
                if cfg.use_dsc else 0.0)
            if cfg.use_dsc:
                v, s_clients = dsc_lib.client_compress(
                    dsc, grads, cfg.compressor, gamma, k_c)
            elif cfg.use_ef:
                v, ef_new = ef_lib.client_compress(ef, grads,
                                                   cfg.compressor, k_c)
                s_clients = dsc.s_clients
            else:
                v, s_clients = grads, dsc.s_clients
            assign = masks_lib.make_assignment(self.n, cfg.A, cfg.mask_scheme)
            if cfg.agg_dropout > 0 or cfg.link_failure > 0:
                ka, kl = jax.random.split(k_f)
                agg_alive = jax.random.bernoulli(
                    ka, 1.0 - cfg.agg_dropout, (cfg.A,))
                link_alive = jax.random.bernoulli(
                    kl, 1.0 - cfg.link_failure, (cfg.K, cfg.A))
                # failures apply to the *transmitted* v; DSC shift compensation
                # still uses what aggregators actually received
                x_acc = fsa_lib.fsa_round_with_failures(
                    jnp.zeros(self.n), v, assign, cfg.A, 1.0,
                    agg_alive, link_alive)
                mean_v = -x_acc  # accumulated -1.0 * aggregated update
                v_global = (dsc.s_agg + mean_v) if cfg.use_dsc else mean_v
                s_agg = dsc.s_agg + gamma * mean_v if cfg.use_dsc else dsc.s_agg
            else:
                v_global, s_agg = dsc_lib.aggregate(
                    dsc if cfg.use_dsc else dsc._replace(
                        s_agg=jnp.zeros_like(dsc.s_agg)), v, gamma,
                    weights=weights)
                if not cfg.use_dsc:
                    s_agg = dsc.s_agg
            if cfg.server_opt != "fedavg":
                # Sec. 5 Benefits: any centralized server optimizer rides
                # on FSA (aggregators run it segment-wise; == centralized)
                delta, server_state = self.server.update(v_global,
                                                         server_state)
                x_new = x + delta
            else:
                x_new = x - cfg.lr * v_global
            dsc_new = dsc_lib.DSCState(s_clients, s_agg)
            views = v
        else:
            raise ValueError(f"unknown method {self.cfg.method!r}")
        return x_new, dsc_new, ef_new, server_state, views

    # ----------------------------------------------------------------- API
    def step(self, batches, collect_views: bool = False):
        self.key, sub = jax.random.split(self.key)
        x_new, dsc_new, ef_new, sstate, views = self._round(
            sub, self.x, self.dsc, self.ef, self.server_state, batches)
        self.x, self.dsc, self.ef = x_new, dsc_new, ef_new
        self.server_state = sstate
        return views if collect_views else None

    def params(self):
        return self.unravel(self.x)

    def evaluate(self, batch) -> float:
        return float(self.loss_fn(self.params(), batch))


def run_fl(cfg: FLConfig, params0, loss_fn, batches_per_round,
           eval_batch=None, eval_every: int = 10):
    """Convenience driver.  batches_per_round: callable(round, key)->(K,...)
    pytree of per-client batches."""
    run = FLRun(cfg, params0, loss_fn)
    key = jax.random.PRNGKey(cfg.seed + 1)
    losses = []
    for t in range(cfg.rounds):
        key, sub = jax.random.split(key)
        run.step(batches_per_round(t, sub))
        if eval_batch is not None and (t % eval_every == 0
                                       or t == cfg.rounds - 1):
            losses.append((t, run.evaluate(eval_batch)))
    return run, losses
