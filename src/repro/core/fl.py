"""Federated engine: runs ERIS or any baseline over a model + client data.

Models are pytrees; the engine flattens them once (ravel_pytree) so every
method operates on the paper's R^n update vectors, then unravels for
evaluation.  Methods are declarative stage compositions resolved by
``repro.core.rounds`` — the engine itself has no per-method branches.

Two drivers share one round implementation:

* ``FLRun.step`` / ``run_fl``      — per-round jitted calls (interactive:
  inspect ``run.x`` / adversary views between rounds).
* ``FLRun.run_scanned`` / ``run_fl_scan`` — ALL rounds as one
  ``jax.lax.scan``-compiled XLA program (T fused rounds, one dispatch);
  identical trajectory to stepping, measured faster in
  benchmarks/convergence.py.

This is the laptop-scale simulator used by the convergence, privacy, and
utility benchmarks; the production multi-pod path lives in repro.launch
and consumes the same compression stages.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.core import baselines as bl
from repro.core import rounds as rounds_lib
from repro.core.compressors import Compressor, Identity
from repro.core.pipeline import (RoundState, participation_weights,
                                 split_round_keys)
from repro.core.settings import AsyncSettings, resolve_async


@dataclasses.dataclass(frozen=True)
class FLConfig:
    method: str = "eris"          # any key of repro.core.rounds.METHODS
    K: int = 8                    # clients
    A: int = 4                    # aggregators (eris)
    rounds: int = 50
    lr: float = 0.1
    use_dsc: bool = False
    use_ef: bool = False          # error feedback (biased compressors)
    compressor: Compressor = Identity()
    server_opt: str = "fedavg"    # fedavg|fedadam|fedyogi (Sec. 5 Benefits)
    participation: float = 1.0    # client sampling fraction per round
    gamma: Optional[float] = None
    mask_scheme: str = "strided"
    fresh_masks: bool = False     # re-draw random masks per round (m^t)
    ldp: Optional[bl.LDPConfig] = None
    secure_mask: bool = False     # Bonawitz pairwise wire masking composed
                                  # onto the eris wire (rounds.scenarios);
                                  # refuses dropout/partial participation
    prune_rate: float = 0.1       # priprune
    shatter_chunks: int = 8
    shatter_r: int = 4
    agg_dropout: float = 0.0      # appendix F.5 failure injection
    link_failure: float = 0.0
    compress_impl: str = "jnp"    # jnp | pallas (kernels/dsc_update) | fused
                                  # (one-pass kernels/dsc_quantize, int8+DSC)
    int8_wire: bool = False       # Pallas int8 wire quantization stage
    keep_views: bool = False      # materialize (A, K, n) aggregator views
                                  # (eris: routes through literal FSASharded
                                  # — the privacy-audit path)
    # ---- population-scale async runtime (fedbuff / eris_async methods).
    # The flat fields below are the deprecated spelling of
    # core.settings.AsyncSettings; prefer attaching one via ``async_``.
    # Setting a knob in BOTH places to different values raises with the
    # conflicting field named (core.settings.resolve_async).
    population: int = 0           # >0: batches carry the whole population
                                  # on their leading axis; K becomes the
                                  # per-round cohort size drawn from it
    buffer_cadence: int = 1       # server applies the buffer every C rounds
    staleness_alpha: float = 1.0  # arrival weight 1/(1+tau)^alpha
    delay_max: int = 0            # straggler staleness tau ~ U{0..delay_max}
    client_dropout: float = 0.0   # arrival dropout (never contributes)
    async_: Optional[AsyncSettings] = None
    seed: int = 0

    def async_settings(self) -> AsyncSettings:
        """The resolved async-runtime knobs (shared with TrainSettings)."""
        return resolve_async("FLConfig", self.async_, self)


class FLRun:
    """Holds the jitted round pipeline and mutable training state."""

    def __init__(self, cfg: FLConfig, params0: Any,
                 loss_fn: Callable[[Any, Any], jax.Array]):
        self.cfg = cfg
        flat0, self.unravel = ravel_pytree(params0)
        self.n = flat0.shape[0]
        self.key = jax.random.PRNGKey(cfg.seed)
        self.loss_fn = loss_fn
        self._grad = jax.grad(lambda x, b: loss_fn(self.unravel(x), b))
        self.pipeline = rounds_lib.build_round(cfg, self.n)
        self.state: RoundState = self.pipeline.init_state(flat0, cfg.K)
        self._round = jax.jit(self._round_impl)
        self._scan: dict = {}

    # -------------------------------------------------- state conveniences
    @property
    def x(self) -> jax.Array:
        return self.state.x

    @property
    def dsc(self):
        return self.state.dsc

    @property
    def ef(self):
        return self.state.ef

    @property
    def server_state(self):
        return self.state.server

    # ---------------------------------------------------------------- core
    def _round_impl(self, key, state: RoundState, batches):
        keys = split_round_keys(key)
        weights = participation_weights(keys.part, self.cfg.K,
                                        self.cfg.participation)
        return self.pipeline.run_round(self._grad, keys, state, batches,
                                       weights)

    # ----------------------------------------------------------------- API
    def step(self, batches, collect_views: bool = False):
        self.key, sub = jax.random.split(self.key)
        self.state, views = self._round(sub, self.state, batches)
        return views if collect_views else None

    def run_scanned(self, batches_stacked, collect_views: bool = False):
        """Run T rounds (T = leading dim of batches_stacked) as a single
        scan-compiled program.  Trajectory-identical to T ``step`` calls.
        Returns the per-round model iterates (T, n); with
        ``collect_views`` also the stacked per-round adversary views
        (``(T, A, K, n)`` under ``FLConfig.keep_views``) — the
        scan-compiled privacy-audit capture."""
        fn = self._scan.get(collect_views)
        if fn is None:
            fn = jax.jit(
                lambda key, state, bs: self.pipeline.scan_rounds(
                    self._grad, key, state, bs,
                    participation=self.cfg.participation,
                    collect_views=collect_views))
            self._scan[collect_views] = fn
        if collect_views:
            self.key, self.state, xs, views = fn(self.key, self.state,
                                                 batches_stacked)
            return xs, views
        self.key, self.state, xs = fn(self.key, self.state, batches_stacked)
        return xs

    def params(self):
        return self.unravel(self.x)

    def evaluate(self, batch) -> float:
        return float(self.loss_fn(self.params(), batch))


def run_fl(cfg: FLConfig, params0, loss_fn, batches_per_round,
           eval_batch=None, eval_every: int = 10):
    """Convenience driver.  batches_per_round: callable(round, key)->(K,...)
    pytree of per-client batches."""
    run = FLRun(cfg, params0, loss_fn)
    key = jax.random.PRNGKey(cfg.seed + 1)
    losses = []
    for t in range(cfg.rounds):
        key, sub = jax.random.split(key)
        run.step(batches_per_round(t, sub))
        if eval_batch is not None and (t % eval_every == 0
                                       or t == cfg.rounds - 1):
            losses.append((t, run.evaluate(eval_batch)))
    return run, losses


def run_fl_scan(cfg: FLConfig, params0, loss_fn, batches_per_round,
                eval_batch=None, eval_every: int = 10):
    """Scan-compiled twin of :func:`run_fl`: materializes the per-round
    batches up front (same keys as the loop driver), runs ONE fused
    T-round XLA program, then evaluates the recorded iterates.  Returns
    (run, losses) with the same trajectory as ``run_fl``."""
    run = FLRun(cfg, params0, loss_fn)
    key = jax.random.PRNGKey(cfg.seed + 1)
    per_round = []
    for t in range(cfg.rounds):
        key, sub = jax.random.split(key)
        per_round.append(batches_per_round(t, sub))
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *per_round)
    xs = run.run_scanned(stacked)
    losses = []
    if eval_batch is not None:
        for t in range(cfg.rounds):
            if t % eval_every == 0 or t == cfg.rounds - 1:
                losses.append((t, float(loss_fn(run.unravel(xs[t]),
                                                eval_batch))))
    return run, losses
