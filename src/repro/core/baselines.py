"""Baseline FL methods the paper compares against (Section 4.1).

All baselines share the ERIS engine's conventions: flat model vector x,
client gradients (K, n) from a vmapped grad_fn, one update per round.

* FedAvg           — McMahan et al. 2017 (no defense, no compression)
* FedAvgLDP        — per-client clipping + Gaussian noise (LDP-FL style)
* SoteriaFL        — centralized shifted compression + LDP noise (Li et al.
                     2022); == ERIS DSC with A=1 plus DP perturbation
* PriPrune         — withhold the top-|g| fraction of coordinates
* ShatterLite      — chunked partial exchange over random r-subsets
                     (neighborhood-only; deviates from FedAvg on purpose)
* MinLeakage       — FedAvg iterates, but the adversary sees only the final
                     model (idealized lower bound; relevant to privacy only)
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import dsc as dsc_lib
from repro.core.compressors import Compressor


def gaussian_sigma(eps: float, delta: float, clip: float) -> float:
    """Classic Gaussian-mechanism calibration sigma = C sqrt(2 ln(1.25/d))/eps."""
    return clip * math.sqrt(2.0 * math.log(1.25 / delta)) / eps


def clip_by_norm(g: jax.Array, clip: float) -> jax.Array:
    nrm = jnp.linalg.norm(g)
    return g * jnp.minimum(1.0, clip / jnp.maximum(nrm, 1e-12))


# ---------------------------------------------------------------- FedAvg
def fedavg_round(x, grads, lr, weights=None):
    K = grads.shape[0]
    w = jnp.full((K,), 1.0 / K) if weights is None else weights / weights.sum()
    return x - lr * jnp.einsum("k,kn->n", w, grads)


# ----------------------------------------------------------- FedAvg-LDP
@dataclasses.dataclass(frozen=True)
class LDPConfig:
    eps: float = 10.0
    delta: float = 1e-5
    clip: float = 1.0


def ldp_perturb(key, grads: jax.Array, cfg: LDPConfig) -> jax.Array:
    sigma = gaussian_sigma(cfg.eps, cfg.delta, cfg.clip)
    clipped = jax.vmap(lambda g: clip_by_norm(g, cfg.clip))(grads)
    noise = sigma * jax.random.normal(key, grads.shape)
    return clipped + noise


def fedavg_ldp_round(key, x, grads, lr, cfg: LDPConfig):
    return fedavg_round(x, ldp_perturb(key, grads, cfg), lr)


# ------------------------------------------------------------ SoteriaFL
class SoteriaState(NamedTuple):
    dsc: dsc_lib.DSCState


def soteriafl_round(key, x, grads, lr, state: SoteriaState,
                    compressor: Compressor, gamma: float,
                    ldp: LDPConfig | None = None):
    """Centralized shifted compression (+ optional LDP noise pre-compression)."""
    k_noise, k_comp = jax.random.split(key)
    if ldp is not None:
        grads = ldp_perturb(k_noise, grads, ldp)
    v, s_clients = dsc_lib.client_compress(state.dsc, grads, compressor,
                                           gamma, k_comp)
    v_global, s_agg = dsc_lib.aggregate(state.dsc, v, gamma)
    return x - lr * v_global, SoteriaState(dsc_lib.DSCState(s_clients, s_agg))


# ------------------------------------------------------------- PriPrune
def prune_withhold(grads: jax.Array, prune_rate: float) -> jax.Array:
    """Withhold (zero) the most informative (largest-magnitude)
    prune_rate fraction of each client update before transmission.
    Shared by priprune_round and the pipeline's PruneWithhold stage."""
    n = grads.shape[-1]
    k = max(1, int(round(prune_rate * n)))

    def prune(g):
        thresh = jax.lax.top_k(jnp.abs(g), k)[0][-1]
        return jnp.where(jnp.abs(g) >= thresh, 0.0, g)

    return jax.vmap(prune)(grads)


def priprune_round(x, grads, lr, prune_rate: float):
    return fedavg_round(x, prune_withhold(grads, prune_rate), lr)


# ---------------------------------------------------------- ShatterLite
def shatter_update(key, grads: jax.Array, n_chunks: int, r: int) -> jax.Array:
    """Chunked partial gradient exchange: coordinates are split into
    n_chunks contiguous chunks; each chunk is averaged over a random
    r-subset of the K clients (gossip-neighborhood approximation).  This
    intentionally deviates from full averaging, matching the utility drop
    the paper reports for Shatter when training from scratch.  Shared by
    shatter_round and the pipeline's ShatterAggregate stage."""
    K, n = grads.shape
    chunk_id = jnp.minimum(jnp.arange(n) * n_chunks // n, n_chunks - 1)
    # random r-subset per chunk
    scores = jax.random.uniform(key, (n_chunks, K))
    thresh = jax.lax.top_k(scores, r)[0][:, -1:]
    member = (scores >= thresh).astype(jnp.float32)       # (n_chunks, K)
    member = member / jnp.maximum(member.sum(1, keepdims=True), 1.0)
    w_per_coord = member[chunk_id]                        # (n, K)
    return jnp.einsum("nk,kn->n", w_per_coord, grads)


def shatter_round(key, x, grads, lr, n_chunks: int, r: int):
    return x - lr * shatter_update(key, grads, n_chunks, r)


# ---------------------------------------------------------- MinLeakage
min_leakage_round = fedavg_round  # identical iterates; differs in adversary view
