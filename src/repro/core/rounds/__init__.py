"""Declarative FL method registry: method name -> RoundPipeline."""
from repro.core.rounds.registry import METHODS, build_round  # noqa: F401
