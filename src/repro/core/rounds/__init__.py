"""Declarative FL method registry: method name -> RoundPipeline, plus
the cross-silo scenario matrix (defense x failure compositions)."""
from repro.core.rounds.registry import METHODS, build_round  # noqa: F401
from repro.core.rounds.scenarios import (  # noqa: F401
    DEFENSES, FAILURES, Scenario, scenario_matrix)
