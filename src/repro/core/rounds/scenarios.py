"""Cross-silo scenario matrix: composed defenses x failure modes on the
real eris wire.

The pack the ROADMAP's comparison story rests on: FSA composed with the
defenses the paper argues against (SoteriaFL-style LDP noise with an RDP
accountant, Bonawitz pairwise secure-agg masking, the int8 wire format)
crossed with the failure axes of Appendix F.5 (aggregator dropout + link
failure, client dropout through the async buffered runtime).  Each cell
is a declarative :class:`~repro.core.pipeline.RoundPipeline` stage
composition resolved through the method registry — the SAME composition
runs in the simulator, the scan engine, and (via
``launch.train.TrainSettings``) the distributed shard_map runtime, and
exposes its aggregator views to the `repro.privacy` audit.

Infeasible compositions refuse LOUDLY with the protocol reason instead
of producing silent garbage:

* ``secure_agg`` x any dropout/failure — pairwise masks cancel only in
  the unweighted full-cohort mean (no dropout-recovery round).
* ``dsc_int8`` x ``client_drop`` — DSC's Eq. 4 shift state tracks
  per-round aggregator receipts, which buffered async apply breaks.

`benchmarks/scenario_snapshot.py` sweeps the feasible cells into the
committed utility-privacy-bytes Pareto surface (``BENCH_pareto.json``).
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Optional

from repro.core import accountant as acct
from repro.core import baselines as bl
from repro.core.compressors import RandP

if TYPE_CHECKING:   # runtime import is lazy: core.fl imports core.rounds
    from repro.core.fl import FLConfig

# Scenario-standard LDP mechanism: per-round (eps=8, delta=1e-5) after
# clipping to unit L2 — loose enough per round that the composed
# accountant curve (not a single round) is the interesting number.
SCENARIO_LDP = bl.LDPConfig(eps=8.0, delta=1e-5, clip=1.0)

DEFENSES: dict[str, dict] = {
    "none": {},
    "int8": dict(int8_wire=True),
    "dsc_int8": dict(use_dsc=True, compressor=RandP(p=0.5),
                     int8_wire=True),
    "ldp": dict(ldp=SCENARIO_LDP),
    "ldp_int8": dict(ldp=SCENARIO_LDP, int8_wire=True),
    "secure_agg": dict(secure_mask=True),
}

FAILURES: dict[str, dict] = {
    "none": {},
    "agg_fail": dict(agg_dropout=0.25, link_failure=0.1),
    "client_drop": dict(client_dropout=0.25),
}


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One cell of the defense x failure matrix."""

    defense: str
    failure: str

    def __post_init__(self):
        if self.defense not in DEFENSES:
            raise ValueError(f"unknown defense {self.defense!r} "
                             f"(have {sorted(DEFENSES)})")
        if self.failure not in FAILURES:
            raise ValueError(f"unknown failure {self.failure!r} "
                             f"(have {sorted(FAILURES)})")

    @property
    def name(self) -> str:
        return f"{self.defense}+{self.failure}"

    @property
    def refusal(self) -> Optional[str]:
        """Why this composition is infeasible (None when it runs)."""
        if self.defense == "secure_agg" and self.failure != "none":
            return ("pairwise masks cancel only in the unweighted "
                    "full-cohort mean; the simplified Bonawitz protocol "
                    "has no dropout-recovery round")
        if self.defense == "dsc_int8" and self.failure == "client_drop":
            return ("DSC's Eq. 4 shift state tracks per-round aggregator "
                    "receipts, which buffered async apply breaks")
        return None

    @property
    def feasible(self) -> bool:
        return self.refusal is None

    @property
    def knobs(self) -> dict:
        return {**DEFENSES[self.defense], **FAILURES[self.failure]}

    @property
    def int8(self) -> bool:
        return bool(self.knobs.get("int8_wire", False))

    @property
    def ldp(self) -> Optional[bl.LDPConfig]:
        return self.knobs.get("ldp")

    @property
    def q(self) -> float:
        """Per-round client sampling/arrival rate (the amplification
        factor the accountant and mi_bound see)."""
        return 1.0 - self.knobs.get("client_dropout", 0.0)

    def fl_config(self, K: int = 6, A: int = 4, rounds: int = 20,
                  lr: float = 0.3, seed: int = 0,
                  keep_views: bool = False) -> "FLConfig":
        """The cell as an FLConfig — resolved by the method registry into
        its stage composition; any engine (step / scan / distributed
        settings twin) runs it from here."""
        from repro.core.fl import FLConfig
        if not self.feasible:
            raise ValueError(
                f"scenario {self.name!r} is infeasible: {self.refusal}")
        knobs = self.knobs
        method = "eris_async" if "client_dropout" in knobs else "eris"
        return FLConfig(method=method, K=K, A=A, rounds=rounds, lr=lr,
                        seed=seed, keep_views=keep_views, **knobs)

    def wire_bytes_per_client(self, n: int) -> int:
        """Simulator/scan wire accounting: bytes one client transmits per
        round (the distributed engine's per-position number comes from
        `dist.sharding.mesh_wire_bytes` instead).  LDP noise and pairwise
        masks are format-preserving; int8 ships 1 B/coord + per-block f32
        scales (padded to QBLOCK)."""
        if self.int8:
            from repro.kernels.quantize import wire_payload_bytes
            return int(wire_payload_bytes(n))
        return 4 * n

    def accountant(self, rounds: int) -> Optional[dict]:
        """Cumulative (eps, delta) across the scenario's rounds for LDP
        cells (RDP composition, subsampling-amplified by q); None when
        no noise stage is active."""
        return acct.ldp_cumulative_epsilon(self.ldp, rounds, q=self.q)


def scenario_matrix(feasible_only: bool = True) -> list[Scenario]:
    cells = [Scenario(d, f) for d in DEFENSES for f in FAILURES]
    return [c for c in cells if c.feasible] if feasible_only else cells


def get(name: str) -> Scenario:
    defense, _, failure = name.partition("+")
    return Scenario(defense, failure or "none")
