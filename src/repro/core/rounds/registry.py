"""Method registry: every ``FLConfig.method`` as a declarative stage
composition (no engine branches).

Reading this file IS the paper's Table-1 comparison:

  method       compress                 aggregate               server
  ----------   ----------------------   ---------------------   -------
  fedavg       (identity)               weighted mean            -lr*u
  min_leakage  (identity)               weighted mean            -lr*u
  fedavg_ldp   LDP noise                mean                     -lr*u
  soteriafl    [LDP noise +] DSC        DSC shift-compensated    -lr*u
  priprune     top-|g| withholding      mean                     -lr*u
  shatter      (identity)               chunked r-subset         -lr*u
  secure_agg   (identity)               pairwise-masked mean     -lr*u
  eris         [DSC | EF | -] [+int8]   FSA (DSC-compensated /   fedavg |
                                        failure-injected)        fedadam |
                                                                 fedyogi
  fedbuff      [int8]                   buffered async mean      -lr*u
  eris_async   [int8]                   buffered async FSA       (as eris)

``fedbuff`` / ``eris_async`` wrap the synchronous aggregate in the
FedBuff-style :class:`BufferedAggregate` (staleness-weighted arrivals
fold into a cross-round buffer, server applies on ``buffer_cadence``)
and, when ``FLConfig.population`` is set, draw a keyed K-client cohort
from the population each round.

Builders take (cfg: FLConfig, n: int) duck-typed — anything with the
FLConfig fields works — and return a frozen RoundPipeline.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core import baselines as bl
from repro.core import dsc as dsc_lib
from repro.core.compressors import Int8RoundTrip
from repro.core.pipeline import (AggregateStage, BufferedAggregate,
                                 ClientStep, DSCAggregate, DSCCompress,
                                 EFCompress, FailureInjectedFSA, FSASharded,
                                 Int8Wire, LDPNoise, PairwiseMask,
                                 PruneWithhold, RoundPipeline,
                                 SecureAggAggregate, ServerStage,
                                 ShatterAggregate)


def _gamma(cfg, n: int) -> float:
    if cfg.gamma is not None:
        return cfg.gamma
    if getattr(cfg, "use_dsc", False):
        return dsc_lib.gamma_star(cfg.compressor.omega(n))
    return 0.0


def _fedavg_server(cfg) -> ServerStage:
    return ServerStage(opt="fedavg", lr=cfg.lr)


def _build_fedavg(cfg, n):
    return RoundPipeline(aggregate=AggregateStage(use_weights=True),
                         server=_fedavg_server(cfg), view="transmitted")


def _build_min_leakage(cfg, n):
    # FedAvg iterates; the adversary sees only the final model.
    return RoundPipeline(aggregate=AggregateStage(use_weights=True),
                         server=_fedavg_server(cfg), view="none")


def _build_fedavg_ldp(cfg, n):
    return RoundPipeline(
        compress=(LDPNoise(ldp=cfg.ldp or bl.LDPConfig(), key_role="noise"),),
        aggregate=AggregateStage(use_weights=False),
        server=_fedavg_server(cfg), view="transmitted")


def _build_soteriafl(cfg, n):
    gamma = cfg.gamma if cfg.gamma is not None else \
        dsc_lib.gamma_star(cfg.compressor.omega(n))
    stages: tuple = ()
    if cfg.ldp is not None:
        stages += (LDPNoise(ldp=cfg.ldp, key_role="comp0"),)
    stages += (DSCCompress(compressor=cfg.compressor, gamma=gamma,
                           key_role="comp1"),)
    return RoundPipeline(
        compress=stages,
        aggregate=DSCAggregate(gamma=gamma, use_weights=False),
        server=_fedavg_server(cfg), view="none")


def _build_priprune(cfg, n):
    return RoundPipeline(compress=(PruneWithhold(rate=cfg.prune_rate),),
                         aggregate=AggregateStage(use_weights=False),
                         server=_fedavg_server(cfg), view="none")


def _build_shatter(cfg, n):
    return RoundPipeline(
        aggregate=ShatterAggregate(chunks=cfg.shatter_chunks,
                                   r=cfg.shatter_r, key_role="comp"),
        server=_fedavg_server(cfg), view="none")


def _build_secure_agg(cfg, n):
    return RoundPipeline(aggregate=SecureAggAggregate(key_role="comp"),
                         server=_fedavg_server(cfg), view="none")


def _build_eris(cfg, n):
    gamma = _gamma(cfg, n)
    int8 = getattr(cfg, "int8_wire", False)
    compressor = cfg.compressor
    impl = getattr(cfg, "compress_impl", "jnp")
    if int8 and (cfg.use_dsc or cfg.use_ef):
        # wire format INSIDE the shifted/error-feedback compressor, so the
        # client references update with exactly what aggregators receive
        # (otherwise s_agg random-walks away from mean_k s_k).
        # ``compress_impl='fused'`` keeps the whole composition in the
        # one-pass ``kernels/dsc_quantize`` kernel; the plain 'pallas'
        # DSC kernel computes a bare RandP, so anything else routes
        # through the composed jnp compressor.
        compressor = Int8RoundTrip(inner=compressor)
        impl = "fused" if impl == "fused" else "jnp"
    compress: tuple = ()
    if getattr(cfg, "ldp", None) is not None:
        # composed-defense scenarios: clip + Gaussian noise BEFORE any
        # compression/masking (SoteriaFL's noise-then-compress order)
        compress += (LDPNoise(ldp=cfg.ldp, key_role="noise"),)
    if cfg.use_dsc:
        compress += (DSCCompress(compressor=compressor, gamma=gamma,
                                 key_role="comp", impl=impl),)
    elif cfg.use_ef:
        compress += (EFCompress(compressor=compressor, key_role="comp"),)
    elif int8:
        compress += (Int8Wire(key_role="wire"),)
    secure_mask = getattr(cfg, "secure_mask", False)
    failures = cfg.agg_dropout > 0 or cfg.link_failure > 0
    if secure_mask:
        if (failures or cfg.participation < 1.0
                or getattr(cfg, "client_dropout", 0.0) > 0.0):
            raise ValueError(
                "secure_mask cannot compose with failures/dropout/partial "
                "participation: pairwise masks cancel only in the "
                "unweighted full-cohort mean, and this simplified "
                "Bonawitz protocol has no dropout-recovery round — the "
                "aggregate would be garbage of magnitude `scale`")
        compress += (PairwiseMask(key_role="noise"),)
    keep_views = getattr(cfg, "keep_views", False)
    if failures:
        aggregate = FailureInjectedFSA(
            A=cfg.A, mask_scheme=cfg.mask_scheme,
            agg_dropout=cfg.agg_dropout, link_failure=cfg.link_failure,
            use_dsc=cfg.use_dsc, gamma=gamma, key_role="fail",
            keep_views=keep_views)
    elif getattr(cfg, "fresh_masks", False) or keep_views:
        # the paper's m^t path and/or the privacy-audit path: literal FSA
        # (keyed per-round assignment when fresh; ``keep_views``
        # materializes the (A, K, n) aggregator views) — the same
        # FSASharded stage eris.round_step runs
        aggregate = FSASharded(
            A=cfg.A, mask_scheme=cfg.mask_scheme,
            fresh_masks=getattr(cfg, "fresh_masks", False),
            use_dsc=cfg.use_dsc, gamma=gamma, keep_views=keep_views,
            key_role="mask")
    elif cfg.use_dsc:
        aggregate = DSCAggregate(gamma=gamma, use_weights=True)
    else:
        aggregate = AggregateStage(use_weights=True)
    return RoundPipeline(client=ClientStep(), compress=compress,
                         aggregate=aggregate,
                         server=ServerStage(opt=cfg.server_opt, lr=cfg.lr),
                         view="transmitted")


# ------------------------------------------------ async (population-scale)
def _as_async(pipeline: RoundPipeline, cfg) -> RoundPipeline:
    """Wrap a synchronous pipeline's aggregate in the FedBuff-style
    buffered stage and (when ``population`` is set) a keyed per-round
    cohort draw.  With the trivial arrival model and ``cadence=1`` the
    wrapped pipeline is bit-identical to the synchronous one.

    The async knobs resolve through :class:`repro.core.settings
    .AsyncSettings` — the ONE dataclass FLConfig and TrainSettings both
    consume — so validation (and its field-naming errors) lives in one
    place.  Duck-typed cfgs without ``async_settings()`` fall back to
    reading the flat fields directly."""
    from repro.core.settings import AsyncSettings
    if getattr(cfg, "use_dsc", False) or getattr(cfg, "use_ef", False):
        raise ValueError(
            "buffered async aggregation does not compose with per-client "
            "shift/error-feedback state: DSC's s_agg (Eq. 4) tracks what "
            "aggregators receive EVERY round, which a cadence-delayed "
            "buffered apply breaks (run use_dsc/use_ef synchronously, or "
            "int8_wire for a stateless wire format)")
    if hasattr(cfg, "async_settings"):
        a = cfg.async_settings()
    else:
        a = AsyncSettings.from_knobs(cfg)
    aggregate = BufferedAggregate(inner=pipeline.aggregate,
                                  arrival=a.arrival_model(),
                                  cadence=a.buffer_cadence,
                                  key_role="fail")
    return dataclasses.replace(pipeline, aggregate=aggregate,
                               cohort=a.cohort(cfg.K))


def _build_fedbuff(cfg, n):
    """FedAvg client/server around the buffered async aggregate (+ the
    int8 wire stage when configured) — the FedBuff baseline."""
    compress: tuple = ()
    if getattr(cfg, "int8_wire", False):
        compress += (Int8Wire(key_role="wire"),)
    base = RoundPipeline(compress=compress,
                         aggregate=AggregateStage(use_weights=True),
                         server=_fedavg_server(cfg), view="transmitted")
    return _as_async(base, cfg)


def _build_eris_async(cfg, n):
    """ERIS's FSA aggregation (keyed masks, adversary views, failure
    injection — whatever the config selects) buffered FedBuff-style with
    cohort sampling: the population-scale serverless composition."""
    return _as_async(_build_eris(cfg, n), cfg)


METHODS: dict[str, Callable] = {
    "fedavg": _build_fedavg,
    "min_leakage": _build_min_leakage,
    "fedavg_ldp": _build_fedavg_ldp,
    "soteriafl": _build_soteriafl,
    "priprune": _build_priprune,
    "shatter": _build_shatter,
    "secure_agg": _build_secure_agg,
    "eris": _build_eris,
    "fedbuff": _build_fedbuff,
    "eris_async": _build_eris_async,
}


def build_round(cfg, n: int) -> RoundPipeline:
    """FLConfig -> declarative round pipeline for its method."""
    try:
        builder = METHODS[cfg.method]
    except KeyError:
        raise ValueError(f"unknown method {cfg.method!r} "
                         f"(have {sorted(METHODS)})") from None
    return builder(cfg, n)
