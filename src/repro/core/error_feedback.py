"""Error feedback (EF21-style) for BIASED compressors.

The paper's DSC requires unbiased omega-compressors (Def. 3.1); top-k is
biased and provably non-convergent alone.  Error feedback accumulates the
compression residual e_k and transmits C(g_k + e_k), restoring
convergence (Karimireddy et al. 2019).  This composes with FSA exactly
like DSC does — it only changes the vector FSA shards — giving a
beyond-paper third compression mode: {none, DSC(unbiased), EF(biased)}.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.compressors import Compressor


class EFState(NamedTuple):
    e: jax.Array     # (K, n) per-client residual memory


def init_state(K: int, n: int) -> EFState:
    return EFState(jnp.zeros((K, n)))


def client_compress(state: EFState, grads: jax.Array,
                    compressor: Compressor, key: jax.Array
                    ) -> tuple[jax.Array, EFState]:
    """v_k = C(g_k + e_k);  e_k <- g_k + e_k - v_k."""
    K = grads.shape[0]
    keys = jax.random.split(key, K)
    target = grads + state.e
    v = jax.vmap(lambda k, t: compressor(k, t))(keys, target)
    return v, EFState(target - v)
