"""Composable FL round pipeline (the paper's Algorithm 1 as a stage graph).

ERIS composes orthogonally from four stages, and so does every baseline
the paper compares against (SoteriaFL frames private compressed FL the
same way):

    ClientStep      local stochastic gradients            (Alg. 1 line 3)
    CompressStage*  what leaves the client                (line 4: DSC /
                    error feedback / LDP noise / pruning / wire int8)
    AggregateStage  how shards meet                       (lines 5-13: FSA
                    sharded or algebraic / all-reduce / secure-agg /
                    shatter / failure-injected FSA)
    ServerStage     how the global model moves            (line 14 +
                    Sec. 5 'Benefits': fedavg / fedadam / fedyogi)

A method is a :class:`RoundPipeline` — a frozen declarative composition —
instead of a branch in an if/elif chain.  The same stage objects drive
the laptop simulator (``repro.core.fl``), the pure-functional scan engine
(``repro.core.eris``), and the distributed shard_map runtime
(``repro.launch.train`` calls ``CompressStage.apply_leaf`` per parameter
leaf), so simulator and production semantics cannot drift.

RNG discipline: every round splits its key into five role keys
(mask/comp/noise/fail/part) exactly like the original engine; each stage
declares which role it consumes, which keeps trajectories bit-compatible
with the pre-pipeline implementation (asserted in tests/test_pipeline.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import baselines as bl
from repro.core import dsc as dsc_lib
from repro.core import error_feedback as ef_lib
from repro.core import fsa as fsa_lib
from repro.core import masks as masks_lib
from repro.core import secure_agg as sa_lib
from repro.core import server_opt as so_lib
from repro.core.compressors import Compressor, Identity, RandP


# ================================================================== state
class BufferState(NamedTuple):
    """FedBuff-style aggregator buffer carried across rounds: the
    staleness-weighted update accumulator, its cumulative weight, and the
    absolute round counter driving the server-apply cadence."""
    u: jax.Array             # weighted update accumulator (n,)
    w: jax.Array             # cumulative arrival weight ()
    t: jax.Array             # rounds folded since start (int32, ())


def init_buffer(n: int) -> BufferState:
    return BufferState(jnp.zeros(n), jnp.zeros(()),
                       jnp.zeros((), jnp.int32))


class RoundState(NamedTuple):
    """Everything a round carries forward (a scan carry)."""
    x: jax.Array             # global model (n,)
    dsc: dsc_lib.DSCState    # DSC reference vectors (zeros when unused)
    ef: ef_lib.EFState       # error-feedback residuals (zeros when unused)
    server: Any              # server optimizer state
    buf: Any = None          # BufferState under buffered async aggregation


class RoundKeys(NamedTuple):
    """Per-round role keys (the engine's historical 5-way split, plus the
    two sub-keys SoteriaFL derives from ``comp`` and a dedicated wire
    key — ``comp1`` can collide with a client's compressor key since
    ``split(k, 2)[1] == split(k, K)[1]`` for K=2, so independent stages
    must not share it)."""
    mask: jax.Array
    comp: jax.Array
    noise: jax.Array
    fail: jax.Array
    part: jax.Array
    comp0: jax.Array         # split(comp)[0] — SoteriaFL pre-noise
    comp1: jax.Array         # split(comp)[1] — SoteriaFL compression
    wire: jax.Array          # wire-format stages (int8 quantization)


def split_round_keys(key: jax.Array) -> RoundKeys:
    k_mask, k_comp, k_noise, k_fail, k_part = jax.random.split(key, 5)
    c0, c1 = jax.random.split(k_comp)
    return RoundKeys(k_mask, k_comp, k_noise, k_fail, k_part, c0, c1,
                     jax.random.fold_in(k_comp, 0x3177))


def participation_weights(key: jax.Array, K: int,
                          fraction: float) -> Optional[jax.Array]:
    """Client-sampling weights: Bernoulli(fraction) per client with at
    least one participant forced (None when everyone participates)."""
    if fraction >= 1.0:
        return None
    # Distinct sub-keys: reusing ``key`` for both draws deterministically
    # coupled the forced index to the Bernoulli mask (same entropy).
    k_draw, k_force = jax.random.split(key)
    part = jax.random.bernoulli(k_draw, fraction, (K,))
    part = part.at[jax.random.randint(k_force, (), 0, K)].set(True)
    return part.astype(jnp.float32)


# ======================================================= async primitives
# Key salts: BufferedAggregate folds its role key with ARRIVAL_SALT and
# CohortSample with COHORT_SALT, so the arrival/cohort draws are
# decorrelated from every existing consumer of the same role key (the
# eris engine aliases fail/part to comp; FailureInjectedFSA splits fail
# directly) without changing any synchronous trajectory.
ARRIVAL_SALT = 0xA51C
COHORT_SALT = 0xC0C0
PAIRWISE_SALT = 0x6D5C   # PairwiseMask folds its role key with this, so
                         # composing it with LDPNoise (same "noise" role)
                         # draws decorrelated mask and noise streams


@dataclasses.dataclass(frozen=True)
class ArrivalModel:
    """Deterministic-keyed straggler/dropout arrivals (the FedBuff-style
    async client model): each cohort member arrives with staleness
    ``tau ~ U{0..delay_max}`` and survives dropout w.p. ``1 - dropout``;
    its update is weighted ``1/(1+tau)^alpha`` (Nguyen et al.'s FedBuff
    staleness discount) and a dropped client contributes NOTHING."""

    delay_max: int = 0
    dropout: float = 0.0
    alpha: float = 1.0

    @property
    def trivial(self) -> bool:
        """Statically no-op: zero staleness, zero dropout.  The trivial
        model draws no randomness and weights every arrival exactly 1.0,
        so buffered aggregation degenerates to the synchronous path
        bit-exactly (asserted in tests/test_fedbuff.py)."""
        return self.delay_max == 0 and self.dropout == 0.0

    def staleness_weight(self, tau: jax.Array) -> jax.Array:
        return (1.0 + tau.astype(jnp.float32)) ** (-self.alpha)

    def draw(self, key: jax.Array, K: int
             ) -> tuple[jax.Array, jax.Array, jax.Array]:
        """(tau, alive, weight) for a K-client cohort."""
        kd, ka = jax.random.split(key)
        tau = jax.random.randint(kd, (K,), 0, self.delay_max + 1)
        alive = jax.random.bernoulli(ka, 1.0 - self.dropout, (K,))
        omega = self.staleness_weight(tau) * alive.astype(jnp.float32)
        return tau, alive, omega


@dataclasses.dataclass(frozen=True)
class CohortSample:
    """Per-round cohort draw over a population: a keyed
    without-replacement sample of ``cohort`` client ids out of
    ``population``.  The draw is a pure function of the round's role key,
    so it is reproducible and identical across engines, and it traces —
    the scan engine compiles the whole population's cohort selection into
    the single fused T-round program."""

    population: int
    cohort: int
    key_role: str = "part"

    def __post_init__(self):
        if not 0 < self.cohort <= self.population:
            raise ValueError(
                f"cohort size {self.cohort} must be in 1..population "
                f"({self.population})")

    def draw(self, keys: RoundKeys) -> jax.Array:
        key = jax.random.fold_in(getattr(keys, self.key_role), COHORT_SALT)
        return jax.random.permutation(key, self.population)[:self.cohort]

    def gather(self, keys: RoundKeys, batches):
        """Select the cohort's rows from population-leading batch arrays
        (leading dim = population -> leading dim = cohort)."""
        idx = self.draw(keys)
        return idx, jax.tree.map(lambda b: jnp.take(b, idx, axis=0),
                                 batches)


# ======================================================== kernel plumbing
def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _largest_divisor(n: int, cap: int) -> int:
    from repro.kernels.common import largest_divisor
    return largest_divisor(n, cap)


def _seed_of(key: jax.Array) -> jax.Array:
    return jax.random.bits(key, dtype=jnp.uint32)


# ================================================================= client
@dataclasses.dataclass(frozen=True)
class ClientStep:
    """Local update: one full-batch stochastic gradient per client,
    vmapped (Algorithm 1 line 3)."""

    def __call__(self, grad_fn: Callable, x: jax.Array, batches) -> jax.Array:
        return jax.vmap(lambda b: grad_fn(x, b))(batches)


# ============================================================== compress
@dataclasses.dataclass(frozen=True)
class CompressStage:
    """Base stage: identity (what FedAvg transmits)."""

    key_role: str = "comp"

    def _key(self, keys: RoundKeys) -> jax.Array:
        return getattr(keys, self.key_role)

    def apply(self, keys: RoundKeys, state: RoundState,
              v: jax.Array) -> tuple[jax.Array, RoundState]:
        return v, state


@dataclasses.dataclass(frozen=True)
class LDPNoise(CompressStage):
    """Per-client clip + Gaussian perturbation (LDP-FL / SoteriaFL's
    privacy mechanism)."""

    ldp: bl.LDPConfig = bl.LDPConfig()
    key_role: str = "noise"

    def apply(self, keys, state, v):
        return bl.ldp_perturb(self._key(keys), v, self.ldp), state


@dataclasses.dataclass(frozen=True)
class DSCCompress(CompressStage):
    """Distributed shifted compression, client side (Sec. 3.2.2):
    v_k = C(g_k - s_k);  s_k <- s_k + gamma v_k.

    ``impl='pallas'`` routes a RandP compressor through the fused
    ``kernels/dsc_update`` TPU kernel (interpret-mode on CPU): one kernel
    sweep instead of four HBM passes on the full model vector.

    ``impl='fused'`` goes one further for the int8-wire composition
    (``Int8RoundTrip(inner=RandP)``): the new ``kernels/dsc_quantize``
    kernel does mask-draw, shift-subtract, per-256-block stochastic int8
    AND the round-trip shift update in ONE VMEM pass — 2 reads + the
    int8 payload + 1 write, replacing the ~7-sweep two-kernel chain.
    The transmitted value is the dequantized wire payload, so the shift
    state tracks exactly what the aggregators receive.
    """

    compressor: Compressor = Identity()
    gamma: float = 0.0
    impl: str = "jnp"            # jnp | pallas | fused

    def compress(self, key: jax.Array, dsc: dsc_lib.DSCState,
                 grads: jax.Array) -> tuple[jax.Array, dsc_lib.DSCState]:
        if self.impl == "pallas":
            v, s_new = self._compress_pallas(key, dsc.s_clients, grads)
        elif self.impl == "fused":
            v, s_new = self._compress_fused(key, dsc.s_clients, grads)
        else:
            v, s_new = dsc_lib.client_compress(dsc, grads, self.compressor,
                                               self.gamma, key)
        return v, dsc._replace(s_clients=s_new)

    def _compress_fused(self, key, s_clients, grads):
        from repro.core.compressors import Int8RoundTrip
        from repro.kernels import dsc_quantize as dq_kernel
        from repro.kernels import quantize as q_kernel
        comp = self.compressor
        inner = comp.inner if isinstance(comp, Int8RoundTrip) else comp
        if not isinstance(inner, RandP):
            raise ValueError("fused DSC->int8 path needs a RandP (or "
                             "Int8RoundTrip(RandP)) compressor, got "
                             f"{comp.name!r}")
        K, n = grads.shape
        pad = (-n) % q_kernel.QBLOCK
        g = jnp.pad(grads.astype(jnp.float32),
                    ((0, 0), (0, pad))).reshape(-1)
        s = jnp.pad(s_clients, ((0, 0), (0, pad))).reshape(-1)
        # mirror Int8RoundTrip's key discipline: one subkey for the inner
        # RandP draw, one for the rounding draw
        k_in, k_q = jax.random.split(key)
        nb = g.shape[0] // q_kernel.QBLOCK
        q, scale, s_new = dq_kernel.dsc_quantize(
            g, s, _seed_of(k_in), _seed_of(k_q), p=inner.p,
            gamma=self.gamma,
            block_b=_largest_divisor(nb, dq_kernel.BLOCK_B),
            interpret=_interpret())
        # the simulator aggregates in f32, so reconstruct the wire value
        # (the distributed runtime ships q/scale and dequantizes receiver
        # side instead)
        v_hat = q_kernel.dequantize(q, scale,
                                    block_b=_largest_divisor(
                                        nb, q_kernel.BLOCK_B),
                                    interpret=_interpret())
        shape = (K, n + pad)
        return (v_hat.reshape(shape)[:, :n],
                s_new.reshape(shape)[:, :n])

    def _compress_pallas(self, key, s_clients, grads):
        from repro.kernels import dsc_update as dsc_kernel
        if not isinstance(self.compressor, RandP):
            raise ValueError("pallas DSC path needs a RandP compressor, "
                             f"got {self.compressor.name!r}")
        K, n = grads.shape
        pad = (-n) % dsc_kernel.LANES
        g = jnp.pad(grads, ((0, 0), (0, pad))).reshape(-1)
        s = jnp.pad(s_clients, ((0, 0), (0, pad))).reshape(-1)
        rows = g.shape[0] // dsc_kernel.LANES
        v, s_new = dsc_kernel.dsc_update(
            g, s, _seed_of(key), p=self.compressor.p, gamma=self.gamma,
            block_rows=_largest_divisor(rows, dsc_kernel.BLOCK_ROWS),
            interpret=_interpret())
        shape = (K, n + pad)
        return v.reshape(shape)[:, :n], s_new.reshape(shape)[:, :n]

    def apply(self, keys, state, v):
        v, dsc = self.compress(self._key(keys), state.dsc, v)
        return v, state._replace(dsc=dsc)

    def apply_leaf(self, key: jax.Array, g: jax.Array,
                   s: jax.Array) -> tuple[jax.Array, jax.Array]:
        """Single-client, single-leaf form for the distributed runtime
        (each shard_map position holds its own s_k leaf)."""
        v = self.compressor(key, g.astype(s.dtype) - s)
        return v, s + self.gamma * v


@dataclasses.dataclass(frozen=True)
class EFCompress(CompressStage):
    """EF21-style error feedback for BIASED compressors:
    v_k = C(g_k + e_k);  e_k <- g_k + e_k - v_k."""

    compressor: Compressor = Identity()

    def apply(self, keys, state, v):
        v, ef = ef_lib.client_compress(state.ef, v, self.compressor,
                                       self._key(keys))
        return v, state._replace(ef=ef)


@dataclasses.dataclass(frozen=True)
class PruneWithhold(CompressStage):
    """PriPrune-style defense: withhold (zero) the top-|g| fraction of
    coordinates of each client update before transmission."""

    rate: float = 0.1

    def apply(self, keys, state, v):
        return bl.prune_withhold(v, self.rate), state


@dataclasses.dataclass(frozen=True)
class Int8Wire(CompressStage):
    """Beyond-paper wire format: per-256-block stochastic int8
    quantize->dequantize round trip via the Pallas ``kernels/quantize``
    kernels (interpret-mode on CPU).  Unbiased, so it composes as an
    omega-compressor (Def. 3.1); payload ~1.03 B/coord vs 4 B f32."""

    key_role: str = "wire"

    def apply(self, keys, state, v):
        from repro.kernels import quantize as q_kernel
        K, n = v.shape
        pad = (-n) % q_kernel.QBLOCK
        flat = jnp.pad(v, ((0, 0), (0, pad))).reshape(-1)
        nb = flat.shape[0] // q_kernel.QBLOCK
        block_b = _largest_divisor(nb, q_kernel.BLOCK_B)
        q, scale = q_kernel.quantize(flat, _seed_of(self._key(keys)),
                                     block_b=block_b, interpret=_interpret())
        deq = q_kernel.dequantize(q, scale, block_b=block_b,
                                  interpret=_interpret())
        return deq.reshape(K, n + pad)[:, :n], state


@dataclasses.dataclass(frozen=True)
class PairwiseMask(CompressStage):
    """Bonawitz pairwise masking as a WIRE stage — the composed-defense
    form: each client adds its row of the fixed-point pairwise mask grid
    before transmission, so every downstream aggregator view (FSA shards
    included) is masked, while the masks cancel exactly in the unweighted
    full-cohort sum.

    Composition caveat (enforced loudly, not silently): cancellation
    needs the plain full-cohort mean.  Partial participation, client
    dropout, or link failure leave unpaired masks of magnitude ``scale``
    in the aggregate — `rounds.scenarios` refuses those compositions and
    `SecureAggAggregate` raises on weighted aggregation."""

    scale: float = 100.0
    key_role: str = "noise"

    def apply(self, keys, state, v):
        key = jax.random.fold_in(self._key(keys), PAIRWISE_SALT)
        K, n = v.shape
        return v + sa_lib.pairwise_masks(key, K, n, self.scale), state


# ============================================================== aggregate
class AggregateResult(NamedTuple):
    update: jax.Array                    # aggregated pseudo-gradient (n,)
    state: RoundState
    views: Optional[jax.Array] = None    # adversary-view override


@dataclasses.dataclass(frozen=True)
class AggregateStage:
    """Base: exact weighted mean — FedAvg's all-reduce, equivalently FSA's
    algebraic form (Theorem B.1: all_reduce == all_gather . reduce_scatter
    over disjoint complete masks)."""

    use_weights: bool = True
    key_role: str = "comp"

    def _key(self, keys: RoundKeys) -> jax.Array:
        return getattr(keys, self.key_role)

    def _w(self, v: jax.Array, weights) -> jax.Array:
        K = v.shape[0]
        if weights is None or not self.use_weights:
            return jnp.full((K,), 1.0 / K)
        return weights / weights.sum()

    def mean(self, v: jax.Array, weights) -> jax.Array:
        return jnp.einsum("k,kn->n", self._w(v, weights), v)

    def apply(self, keys: RoundKeys, state: RoundState, v: jax.Array,
              weights) -> AggregateResult:
        return AggregateResult(self.mean(v, weights), state)


@dataclasses.dataclass(frozen=True)
class DSCAggregate(AggregateStage):
    """Aggregator-side shift compensation (Eq. 4):
    u = s_agg + mean_k v_k;  s_agg <- s_agg + gamma mean_k v_k."""

    gamma: float = 0.0

    def aggregate(self, dsc: dsc_lib.DSCState, v: jax.Array, weights
                  ) -> tuple[jax.Array, dsc_lib.DSCState]:
        u, s_agg = dsc_lib.aggregate(
            dsc, v, self.gamma, weights=weights if self.use_weights else None)
        return u, dsc._replace(s_agg=s_agg)

    def apply(self, keys, state, v, weights):
        u, dsc = self.aggregate(state.dsc, v, weights)
        return AggregateResult(u, state._replace(dsc=dsc))


@dataclasses.dataclass(frozen=True)
class FSASharded(AggregateStage):
    """Literal Algorithm 1 lines 5-13: materialize per-aggregator masked
    shards, aggregate each independently, reassemble.  Iterate-identical
    to the algebraic mean (Theorem B.1) but also exposes the
    honest-but-curious aggregator views — the privacy-eval path.

    ``fresh_masks`` draws a NEW random assignment every round (the
    paper's m^t notation) keyed on the round's ``mask`` role key, so the
    draw is reproducible and identical across engines.  ``use_dsc`` adds
    the aggregator-side shift compensation of Eq. 4 on the sharded mean
    (u = s_agg + mean; s_agg += gamma mean) — the composition the eris
    fresh-mask path runs.

    ``assign_override`` pins the coordinate->aggregator assignment to an
    explicit vector instead of a scheme — used by the privacy-audit
    harness to attack the simulator under the DISTRIBUTED runtime's
    per-leaf segment layout (``repro.privacy.views.mesh_flat_assignment``),
    so per-aggregator views are comparable across engines."""

    A: int = 4
    mask_scheme: str = "strided"
    keep_views: bool = True
    fresh_masks: bool = False        # re-draw random masks per round (m^t)
    use_dsc: bool = False
    gamma: float = 0.0
    key_role: str = "mask"
    assign_override: Optional[jax.Array] = None

    def assignment(self, keys: RoundKeys, n: int) -> jax.Array:
        if self.assign_override is not None:
            return self.assign_override
        if self.fresh_masks:
            return masks_lib.make_assignment(n, self.A, "random",
                                             key=self._key(keys))
        return masks_lib.make_assignment(n, self.A, self.mask_scheme)

    def apply(self, keys, state, v, weights):
        n = v.shape[1]
        assign = self.assignment(keys, n)
        out = fsa_lib.fsa_round_sharded(
            jnp.zeros(n), v, assign, self.A, 1.0,
            weights=weights if self.use_weights else None,
            keep_views=self.keep_views)
        mean_v = -out.x_new
        if self.use_dsc:
            dsc = state.dsc
            u = dsc.s_agg + mean_v
            state = state._replace(
                dsc=dsc._replace(s_agg=dsc.s_agg + self.gamma * mean_v))
        else:
            u = mean_v
        return AggregateResult(u, state, out.shard_views)


@dataclasses.dataclass(frozen=True)
class SecureAggAggregate(AggregateStage):
    """Bonawitz-style pairwise masking: the aggregate is the exact mean,
    the adversary view is the masked per-client updates.

    Pairwise masks cancel ONLY in the unweighted full-cohort mean — a
    weighted or partial sum (participation sampling, client dropout)
    leaves unpaired masks of magnitude ``scale`` in the aggregate, i.e.
    garbage.  The simplified protocol has no dropout recovery, so this
    stage fails loudly instead."""

    use_weights: bool = False

    def apply(self, keys, state, v, weights):
        if weights is not None:
            raise ValueError(
                "secure_agg cannot aggregate a weighted/partial cohort: "
                "pairwise masks cancel only in the unweighted full-cohort "
                "mean, and this simplified Bonawitz protocol has no "
                "dropout-recovery round (run with participation=1.0 / "
                "no client dropout, or pick a different defense)")
        masked = sa_lib.mask_updates(self._key(keys), v)
        return AggregateResult(masked.mean(0), state, masked)


@dataclasses.dataclass(frozen=True)
class ShatterAggregate(AggregateStage):
    """ShatterLite: coordinates in contiguous chunks, each chunk averaged
    over a random r-subset of clients (gossip-neighborhood approximation;
    intentionally deviates from the full mean)."""

    chunks: int = 8
    r: int = 4

    def apply(self, keys, state, v, weights):
        u = bl.shatter_update(self._key(keys), v, self.chunks, self.r)
        return AggregateResult(u, state)


@dataclasses.dataclass(frozen=True)
class FailureInjectedFSA(AggregateStage):
    """Appendix F.5: aggregator dropout + client->aggregator link failures
    on the transmitted shards; DSC shift compensation (when enabled) uses
    what the aggregators actually received.  ``keep_views`` materializes
    the (A, K, n) received shards (link-failed/dead entries zeroed) so the
    adversary-view audit can attack the failure-injected wire."""

    A: int = 4
    mask_scheme: str = "strided"
    agg_dropout: float = 0.0
    link_failure: float = 0.0
    use_dsc: bool = False
    gamma: float = 0.0
    key_role: str = "fail"
    keep_views: bool = False

    def apply(self, keys, state, v, weights):
        K, n = v.shape
        assign = masks_lib.make_assignment(n, self.A, self.mask_scheme)
        ka, kl = jax.random.split(self._key(keys))
        agg_alive = jax.random.bernoulli(ka, 1.0 - self.agg_dropout,
                                         (self.A,))
        link_alive = jax.random.bernoulli(kl, 1.0 - self.link_failure,
                                          (K, self.A))
        out = fsa_lib.fsa_round_with_failures(
            jnp.zeros(n), v, assign, self.A, 1.0, agg_alive, link_alive,
            keep_views=self.keep_views)
        if self.keep_views:
            x_acc, views = out.x_new, out.shard_views
        else:
            x_acc, views = out, None
        mean_v = -x_acc
        dsc = state.dsc
        if self.use_dsc:
            u = dsc.s_agg + mean_v
            dsc = dsc._replace(s_agg=dsc.s_agg + self.gamma * mean_v)
        else:
            u = mean_v
        return AggregateResult(u, state._replace(dsc=dsc), views)


@dataclasses.dataclass(frozen=True)
class BufferedAggregate(AggregateStage):
    """FedBuff-style buffered asynchronous aggregation around ANY inner
    aggregate stage: arrivals (drawn from ``arrival``) fold their
    staleness-weighted updates into a cross-round :class:`BufferState`;
    the server consumes the buffer only every ``cadence`` rounds and the
    update is zero in between.

    Per round the inner stage aggregates the arrived cohort with weights
    ``base_k * omega_k`` (``omega_k = alive_k / (1+tau_k)^alpha``), the
    buffer accumulates ``W_r * contrib`` with the round's arrival mass
    ``W_r = sum(base*omega)/sum(base)``, and an apply round emits
    ``buf.u / buf.w`` then resets.  With the TRIVIAL arrival model and
    ``cadence=1`` every step is algebraically `0 + 1.0*u`, `u / 1.0` —
    IEEE-exact identities — so the async path reproduces the synchronous
    inner stage bit-for-bit (the degenerate-case parity gate).

    The inner stage must consume weights (``use_weights=True``) so
    staleness discounts reach the mean; dropped clients are additionally
    hard-zeroed out of ``v`` (and the adversary views) so they can never
    contribute — a dropped client transmitted nothing."""

    inner: AggregateStage = AggregateStage()
    arrival: ArrivalModel = ArrivalModel()
    cadence: int = 1
    key_role: str = "fail"

    def __post_init__(self):
        if self.cadence < 1:
            raise ValueError(f"cadence must be >= 1, got {self.cadence}")
        if not self.inner.use_weights:
            raise ValueError(
                "BufferedAggregate needs an inner aggregate with "
                "use_weights=True; otherwise staleness/dropout weights "
                "would be silently ignored")

    def init_buffer(self, n: int) -> BufferState:
        return init_buffer(n)

    def apply(self, keys, state, v, weights):
        if state.buf is None:
            raise ValueError("BufferedAggregate needs RoundState.buf — "
                             "initialize via RoundPipeline.init_state "
                             "(or pipeline.init_buffer)")
        K = v.shape[0]
        if self.arrival.trivial:
            # statically synchronous: no draws, unit round weight — the
            # fold below is then bit-exact identity around the inner stage
            res = self.inner.apply(keys, state, v, weights)
            contrib, inner_state, views = res.update, res.state, res.views
            w_round = jnp.ones(())
        else:
            k_arr = jax.random.fold_in(self._key(keys), ARRIVAL_SALT)
            _, alive, omega = self.arrival.draw(k_arr, K)
            base = weights if (weights is not None and self.use_weights) \
                else jnp.ones((K,))
            w_eff = base * omega
            w_sum = w_eff.sum()
            # dropped clients transmitted nothing: hard-zero their rows
            # (and views) so no inner stage can leak or aggregate them
            v = v * alive[:, None].astype(v.dtype)
            safe_w = jnp.where(w_sum > 0, w_eff, jnp.ones((K,)))
            res = self.inner.apply(keys, state, v, safe_w)
            w_round = jnp.where(w_sum > 0, w_sum / base.sum(), 0.0)
            contrib = jnp.where(w_sum > 0, res.update, 0.0)
            inner_state, views = res.state, res.views
            if views is not None:
                # (A, K, n) aggregator views or (K, n) per-client views:
                # mask the cohort axis either way
                a = alive.astype(views.dtype)
                views = views * (a[None, :, None] if views.ndim == 3
                                 else a[:, None])
        buf = state.buf
        u_acc = buf.u + w_round * contrib
        w_acc = buf.w + w_round
        t_new = buf.t + 1
        do_apply = (t_new % self.cadence) == 0
        update = jnp.where(do_apply,
                           u_acc / jnp.maximum(w_acc, 1e-12), 0.0)
        buf_new = BufferState(u=jnp.where(do_apply, 0.0, u_acc),
                              w=jnp.where(do_apply, 0.0, w_acc),
                              t=t_new)
        return AggregateResult(update, inner_state._replace(buf=buf_new),
                               views)


# ================================================================= server
@dataclasses.dataclass(frozen=True)
class ServerStage:
    """Global model update from the aggregated pseudo-gradient.  Under FSA
    every aggregator runs the same coordinate-wise optimizer on its
    disjoint segment == the centralized update (Sec. 5 'Benefits')."""

    opt: str = "fedavg"          # fedavg | fedadam | fedyogi
    lr: float = 0.1

    def make(self) -> so_lib.ServerOpt:
        return so_lib.get_server_opt(self.opt, self.lr)

    def init(self, x0: jax.Array):
        return self.make().init(x0)

    def apply(self, state: RoundState, u: jax.Array) -> RoundState:
        delta, sstate = self.make().update(u, state.server)
        return state._replace(x=state.x + delta, server=sstate)


# =============================================================== pipeline
@dataclasses.dataclass(frozen=True)
class RoundPipeline:
    """One FL method, declaratively: client -> compress* -> aggregate ->
    server.  ``view`` names what an adversary observes: the transmitted
    per-client vectors, an aggregate-stage override, or nothing."""

    client: ClientStep = ClientStep()
    compress: tuple[CompressStage, ...] = ()
    aggregate: AggregateStage = AggregateStage()
    server: ServerStage = ServerStage()
    view: str = "none"           # none | transmitted
    cohort: Optional[CohortSample] = None   # population-scale cohort draw

    def init_state(self, x0: jax.Array, K: int) -> RoundState:
        n = x0.shape[0]
        buf = (self.aggregate.init_buffer(n)
               if isinstance(self.aggregate, BufferedAggregate) else None)
        return RoundState(x0, dsc_lib.init_state(K, n),
                          ef_lib.init_state(K, n), self.server.init(x0),
                          buf)

    def run_round(self, grad_fn: Callable, keys: RoundKeys,
                  state: RoundState, batches, weights=None
                  ) -> tuple[RoundState, Optional[jax.Array]]:
        """One round.  Returns (new_state, adversary_views).  With a
        ``cohort``, ``batches`` carries the WHOLE population on its
        leading axis and only the drawn cohort's rows are stepped."""
        if self.cohort is not None:
            _, batches = self.cohort.gather(keys, batches)
        grads = self.client(grad_fn, state.x, batches)
        v = grads
        for stage in self.compress:
            v, state = stage.apply(keys, state, v)
        agg = self.aggregate.apply(keys, state, v, weights)
        state = self.server.apply(agg.state, agg.update)
        views = agg.views if agg.views is not None else (
            v if self.view == "transmitted" else None)
        return state, views

    def scan_rounds(self, grad_fn: Callable, key: jax.Array,
                    state: RoundState, batches_stacked, weights=None,
                    participation: float = 1.0,
                    collect_views: bool = False):
        """All T rounds as ONE compiled program: ``jax.lax.scan`` over the
        leading (round) axis of ``batches_stacked``.  Key handling matches
        the per-round driver (split the carry key once per round), so the
        trajectory is identical to stepping — just without T dispatches
        and T retrace-sized XLA programs.  Returns (final_key, final_state,
        x_traj) with final_key advanced exactly as T step calls would.

        ``collect_views`` additionally stacks the per-round adversary
        views (the privacy-audit path: e.g. ``FSASharded.keep_views``
        shard views become one ``(T, A, K, n)`` array out of the single
        fused program)."""
        K = state.dsc.s_clients.shape[0]

        def body(carry, batches_t):
            k, st = carry
            k, sub = jax.random.split(k)
            keys = split_round_keys(sub)
            w = weights if weights is not None else \
                participation_weights(keys.part, K, participation)
            st, views = self.run_round(grad_fn, keys, st, batches_t, w)
            if collect_views:
                if views is None:
                    raise ValueError(
                        "collect_views: this pipeline exposes no adversary "
                        "view (view='none' and no aggregate override)")
                return (k, st), (st.x, views)
            return (k, st), st.x

        (key, state), out = jax.lax.scan(body, (key, state), batches_stacked)
        if collect_views:
            xs, views = out
            return key, state, xs, views
        return key, state, out
