"""ERIS core: Federated Shard Aggregation + Distributed Shifted Compression."""
from repro.core import (baselines, compressors, dsc, eris,  # noqa: F401
                        error_feedback, fl, fsa, masks, privacy,
                        secure_agg, server_opt)
