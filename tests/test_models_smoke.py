"""Per-architecture smoke tests (reduced same-family variants, CPU):
one forward + one train step, asserting shapes and no NaNs — required for
every assigned architecture."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import transformer as tr

KEY = jax.random.PRNGKey(0)
B, S = 2, 64


def make_batch(cfg, key, batch=B, seq=S):
    kt, kf = jax.random.split(key)
    n_pre = cfg.n_frontend_tokens if cfg.frontend == "vlm" else 0
    batch_d = {"tokens": jax.random.randint(kt, (batch, seq - n_pre), 0,
                                            cfg.vocab)}
    if cfg.frontend == "vlm":
        batch_d["frontend_embeds"] = jax.random.normal(
            kf, (batch, cfg.n_frontend_tokens, cfg.d_frontend))
    return batch_d


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).smoke()
    params = tr.init_params(KEY, cfg)
    batch = make_batch(cfg, jax.random.fold_in(KEY, 1))
    # forward
    logits, _, aux = tr.forward(params, cfg, batch["tokens"],
                                batch.get("frontend_embeds"))
    assert logits.shape == (B, S, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits))), arch
    # one SGD train step
    loss, grads = jax.value_and_grad(tr.loss_fn)(params, cfg, batch)
    assert np.isfinite(float(loss)), arch
    gnorm = jax.tree.reduce(
        lambda a, g: a + float(jnp.sum(jnp.square(g))), grads, 0.0)
    assert np.isfinite(gnorm) and gnorm > 0, arch
    new = jax.tree.map(lambda p, g: p - 1e-2 * g, params, grads)
    loss2 = tr.loss_fn(new, cfg, batch)
    assert np.isfinite(float(loss2)), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    """serve_step: one token against a KV/recurrent cache."""
    cfg = get_config(arch).smoke()
    if cfg.frontend == "vlm":
        pytest.skip("decode for VLM exercised via dense path (same decoder)")
    params = tr.init_params(KEY, cfg)
    cache = tr.init_cache(cfg, B, 32, dtype=jnp.float32)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, cache = tr.decode_step(params, cfg, cache, tok, jnp.int32(0))
    assert logits.shape == (B, 1, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits))), arch
    logits2, _ = tr.decode_step(params, cfg, cache,
                                jnp.argmax(logits[:, -1:], -1), jnp.int32(1))
    assert not bool(jnp.any(jnp.isnan(logits2)))


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "xlstm-350m", "hymba-1.5b",
                                  "olmoe-1b-7b"])
def test_decode_consistent_with_forward(arch):
    """Greedy decode logits == teacher-forced forward logits (one family
    per block type)."""
    import dataclasses
    cfg = get_config(arch).smoke()
    if cfg.family == "moe":
        # ample capacity => no token dropping => decode matches exactly;
        # capacity-dropped tokens diverging is expected MoE semantics and
        # is covered by test_moe.py::test_capacity_drops.
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = tr.init_params(KEY, cfg)
    T = 12
    toks = jax.random.randint(jax.random.fold_in(KEY, 2), (1, T), 0,
                              cfg.vocab)
    full, _, _ = tr.forward(params, cfg, toks)
    cache = tr.init_cache(cfg, 1, T, dtype=jnp.float32)
    for t in range(T):
        step, cache = tr.decode_step(params, cfg, cache, toks[:, t:t + 1],
                                     jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(step[0, 0]), np.asarray(full[0, t]),
            atol=2e-3, rtol=2e-3, err_msg=f"{arch} t={t}")


def test_sliding_window_decode_runs():
    cfg = get_config("starcoder2-3b").smoke()
    params = tr.init_params(KEY, cfg)
    W = 8
    cache = tr.init_cache(cfg, 1, 64, window=W, dtype=jnp.float32)
    assert cache["kv"]["k"].shape[2] == W
    tok = jnp.zeros((1, 1), jnp.int32)
    for t in range(12):
        logits, cache = tr.decode_step(params, cfg, cache, tok, jnp.int32(t),
                                       window=W)
        assert not bool(jnp.any(jnp.isnan(logits)))


def test_param_counts_match_spec():
    """Analytic param_count == sum of actual leaf sizes, and sanity-check
    the full-size configs land near their nameplate sizes."""
    for arch in ARCHS:
        cfg = get_config(arch).smoke()
        params = tr.init_params(KEY, cfg)
        actual = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
        assert tr.param_count(cfg) == actual, arch
    assert 25e9 < tr.param_count(get_config("qwen3-32b")) < 45e9
    assert 30e9 < tr.param_count(get_config("phi3.5-moe-42b-a6.6b")) < 50e9
    assert 4e9 < tr.active_param_count(get_config("phi3.5-moe-42b-a6.6b")) < 9e9
    assert 0.25e9 < tr.param_count(get_config("xlstm-350m")) < 0.6e9
    assert 0.4e9 < tr.param_count(get_config("qwen2-0.5b")) < 0.8e9
