"""Paged KV cache properties: the block allocator's invariants under
random alloc/free interleavings (hypothesis) and the block-table
scatter/gather roundtrip (``write_prefill`` -> table-indexed gather
reproduces the dense prefill cache exactly)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serve.cache import (SCRATCH_BLOCK, BlockAllocator,
                               BlockBudgetExceeded, pages_for,
                               write_prefill)


# ------------------------------------------------------------- allocator
@given(n_tokens=st.integers(0, 500), bs=st.integers(1, 64))
@settings(max_examples=40, deadline=None)
def test_pages_for_covers_exactly(n_tokens, bs):
    p = pages_for(n_tokens, bs)
    assert p * bs >= n_tokens            # covers every token
    assert (p - 1) * bs < n_tokens or p == 0   # with no spare block


@given(num_blocks=st.integers(2, 64), seed=st.integers(0, 2**16))
@settings(max_examples=25, deadline=None)
def test_allocator_invariants_random_walk(num_blocks, seed):
    """Random alloc/free interleaving: uniqueness, conservation, budget,
    peak tracking, all-or-nothing exhaustion."""
    rng = np.random.default_rng(seed)
    a = BlockAllocator(num_blocks, block_size=8)
    held = []
    peak_seen = 0
    for _ in range(60):
        if held and rng.random() < 0.4:
            i = int(rng.integers(len(held)))
            a.free(held.pop(i))
            continue
        want = int(rng.integers(1, max(2, num_blocks // 2)))
        got = a.alloc(want)
        if got is None:
            assert want > a.available      # only exhaustion returns None
            continue
        assert len(got) == want
        held.append(got)
        flat = [b for grp in held for b in grp]
        assert len(flat) == len(set(flat))             # unique
        assert all(0 < b < num_blocks for b in flat)   # never scratch/oob
        peak_seen = max(peak_seen, len(flat))
        # conservation: every block is exactly one of {used, free, scratch}
        assert a.used + a.available == a.capacity == num_blocks - 1
        assert a.used <= a.capacity
    assert a.peak_used == peak_seen
    for grp in held:
        a.free(grp)
    assert a.available == a.capacity and a.used == 0


def test_allocator_all_or_nothing_and_strict():
    a = BlockAllocator(num_blocks=4, block_size=8)   # capacity 3
    assert a.alloc(5) is None
    assert a.available == 3                           # nothing leaked
    with pytest.raises(BlockBudgetExceeded):
        a.alloc(5, strict=True)
    got = a.alloc(3)
    assert sorted(got) == [1, 2, 3]
    assert a.alloc(1) is None


def test_allocator_double_free_rejected():
    a = BlockAllocator(num_blocks=4, block_size=8)
    blocks = a.alloc(2)
    a.free(blocks)
    with pytest.raises(ValueError):
        a.free(blocks)
    with pytest.raises(ValueError):
        a.free([SCRATCH_BLOCK])           # scratch is never allocatable


def test_allocator_validation():
    with pytest.raises(ValueError):
        BlockAllocator(num_blocks=1, block_size=8)
    with pytest.raises(ValueError):
        BlockAllocator(num_blocks=8, block_size=0)


# ----------------------------------------------------- table roundtrip
@given(S=st.integers(1, 40), bs=st.sampled_from([1, 4, 8, 16]),
       L=st.integers(1, 3), KV=st.sampled_from([1, 2, 4]),
       seed=st.integers(0, 2**16))
@settings(max_examples=15, deadline=None)
def test_write_prefill_block_table_roundtrip(S, bs, L, KV, seed):
    """Scatter a dense (L, S, KV, hd) prefill cache into allocator-owned
    blocks, then gather through the block table — bytes must round-trip
    and untouched pool blocks must stay zero."""
    hd = 8
    key = jax.random.PRNGKey(seed)
    k = jax.random.normal(key, (L, S, KV, hd), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 1), (L, S, KV, hd))
    num_blocks = pages_for(S, bs) + 3
    a = BlockAllocator(num_blocks, bs)
    pages = np.asarray(a.alloc(pages_for(S, bs)), np.int32)
    pools = {"k": jnp.zeros((L, num_blocks, KV, bs, hd), jnp.float32),
             "v": jnp.zeros((L, num_blocks, KV, bs, hd), jnp.float32)}
    pools = write_prefill(pools, k, v, jnp.asarray(pages), bs)
    # gather back through the table
    idx = np.arange(S)
    got_k = np.asarray(pools["k"])[:, pages[idx // bs], :, idx % bs]
    got_v = np.asarray(pools["v"])[:, pages[idx // bs], :, idx % bs]
    # advanced indexing fronts the (S,) dims: (S, L, KV, hd)
    np.testing.assert_array_equal(got_k, np.asarray(k).transpose(1, 0, 2, 3))
    np.testing.assert_array_equal(got_v, np.asarray(v).transpose(1, 0, 2, 3))
    # blocks the table never referenced are untouched
    unused = sorted(set(range(num_blocks)) - set(pages.tolist()))
    assert not np.asarray(pools["k"])[:, unused].any()
    assert not np.asarray(pools["v"])[:, unused].any()
