"""ERIS round engine + DSC semantics + convergence behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines, dsc, eris
from repro.core.compressors import Identity, QSGD, RandP

KEY = jax.random.PRNGKey(0)


def quad_grad_fn(x, batch):
    """Least-squares client: batch = (a, b); f = 0.5||a*x - b||^2 / len."""
    a, b = batch
    return a * (a * x - b)


def make_quad_problem(key, K, n):
    ka, kb = jax.random.split(key)
    a = 1.0 + jax.random.uniform(ka, (K, n))
    b = jax.random.normal(kb, (K, n))
    return (a, b)


def test_eris_no_dsc_equals_fedavg_trajectory():
    K, n, T = 4, 32, 15
    batches = make_quad_problem(KEY, K, n)
    cfg = eris.ErisConfig(A=4, lr=0.05, use_dsc=False)
    state = eris.init(KEY, jnp.zeros(n), K)
    x_ref = jnp.zeros(n)
    for _ in range(T):
        state, _ = eris.round_step(state, cfg, quad_grad_fn, batches)
        grads = jax.vmap(lambda ba, bb: quad_grad_fn(x_ref, (ba, bb)))(*batches)
        x_ref = baselines.fedavg_round(x_ref, grads, 0.05)
        np.testing.assert_allclose(np.asarray(state.x), np.asarray(x_ref),
                                   atol=1e-5)


def test_dsc_identity_compressor_equals_fedavg():
    """With C = Id the shifted scheme telescopes: v_global = mean grads."""
    K, n = 3, 16
    batches = make_quad_problem(KEY, K, n)
    cfg = eris.ErisConfig(A=2, lr=0.1, use_dsc=True, compressor=Identity(),
                          gamma=1.0)
    state = eris.init(KEY, jnp.zeros(n), K)
    x_ref = jnp.zeros(n)
    for _ in range(10):
        state, _ = eris.round_step(state, cfg, quad_grad_fn, batches)
        grads = jax.vmap(lambda a, b: quad_grad_fn(x_ref, (a, b)))(*batches)
        x_ref = baselines.fedavg_round(x_ref, grads, 0.1)
        np.testing.assert_allclose(np.asarray(state.x), np.asarray(x_ref),
                                   atol=1e-4)


def test_gamma_star():
    assert dsc.gamma_star(0.0) == pytest.approx(np.sqrt(0.5))
    w = 3.0
    assert dsc.gamma_star(w) == pytest.approx(
        np.sqrt((1 + 2 * w) / (2 * (1 + w) ** 3)))


def test_dsc_shift_tracks_gradients():
    """s_k drifts toward the client gradient direction (the reference
    tracks the local update direction over time — Sec. 3.2.2)."""
    K, n, T = 2, 24, 200
    batches = make_quad_problem(KEY, K, n)
    cfg = eris.ErisConfig(A=2, lr=0.02, use_dsc=True,
                          compressor=RandP(p=0.5))
    state = eris.init(KEY, jnp.zeros(n), K)
    for _ in range(T):
        state, _ = eris.round_step(state, cfg, quad_grad_fn, batches)
    grads = jax.vmap(lambda a, b: quad_grad_fn(state.x, (a, b)))(*batches)
    err0 = float(jnp.linalg.norm(grads))          # ||g - 0||
    err = float(jnp.linalg.norm(grads - state.dsc.s_clients))
    assert err < err0


@pytest.mark.parametrize("comp", [RandP(p=0.3), QSGD(s=8)])
def test_eris_dsc_converges_on_quadratic(comp):
    """ERIS+DSC drives the quadratic objective near its optimum
    (Theorem 3.2: with full local gradients Gamma_2 = 0 => exact)."""
    K, n, T = 4, 32, 800
    batches = make_quad_problem(KEY, K, n)
    a, b = batches
    # optimum of (1/K) sum_k .5||a_k x - b_k||^2: x* = sum a b / sum a^2
    x_star = (a * b).sum(0) / (a * a).sum(0)
    cfg = eris.ErisConfig(A=4, lr=0.05, use_dsc=True, compressor=comp)
    state = eris.init(KEY, jnp.zeros(n), K)
    step = jax.jit(lambda s: eris.round_step(s, cfg, quad_grad_fn, batches)[0])
    for _ in range(T):
        state = step(state)
    final_err = float(jnp.linalg.norm(state.x - x_star) /
                      jnp.linalg.norm(x_star))
    assert final_err < 0.05, final_err


def test_fresh_masks_reproducible_and_valid():
    from repro.core import masks as masks_lib
    K, n = 2, 40
    batches = make_quad_problem(KEY, K, n)
    cfg = eris.ErisConfig(A=5, lr=0.1, fresh_masks=True)
    state = eris.init(KEY, jnp.zeros(n), K)
    _, aux = eris.round_step(state, cfg, quad_grad_fn, batches)
    assert masks_lib.check_disjoint_complete(aux["assign"], 5)


def test_scan_runner():
    K, n, T = 3, 16, 5
    a, b = make_quad_problem(KEY, K, n)
    batches = jnp.stack([jnp.stack([a, b], 1)] * T)   # (T, K, 2, n)
    cfg = eris.ErisConfig(A=2, lr=0.05)
    gf = lambda x, bb: quad_grad_fn(x, (bb[0], bb[1]))
    state, xs = eris.run(KEY, jnp.zeros(n), cfg, gf, batches, T)
    assert xs.shape == (T, n)
    assert not bool(jnp.any(jnp.isnan(xs)))
