"""Property tests for the FSA mask algebra (Section 3.2.1) — every
assignment scheme must partition all n coordinates exactly once, coalition
unions must match the Thm 3.3 observed fraction, and the mesh-induced
assignment must mirror the distributed runtime's segment layout."""
import jax
import jax.numpy as jnp
import numpy as np

from hypothesis import given, settings, strategies as st

from repro.core import masks as masks_lib
from repro.core import privacy

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------- partition properties
@given(n=st.integers(4, 257), A=st.integers(1, 9),
       scheme=st.sampled_from(["strided", "contiguous", "random"]))
@settings(max_examples=25, deadline=None)
def test_assignment_partitions_every_coordinate_once(n, A, scheme):
    key = jax.random.fold_in(KEY, n * 31 + A) if scheme == "random" else None
    assign = masks_lib.make_assignment(n, A, scheme, key=key)
    m = masks_lib.masks_stacked(assign, A)
    # completeness: every coordinate covered exactly once
    np.testing.assert_array_equal(np.asarray(m.sum(0)), np.ones(n))
    # disjointness: pairwise products vanish
    assert masks_lib.check_disjoint_complete(assign, A)
    # values live in [0, A)
    a = np.asarray(assign)
    assert a.min() >= 0 and a.max() < A
    # shard sizes balanced to within 1 for strided
    if scheme == "strided":
        sizes = np.asarray(masks_lib.shard_sizes(assign, A))
        assert sizes.max() - sizes.min() <= 1


@given(n=st.integers(16, 200), A=st.integers(2, 8),
       scheme=st.sampled_from(["strided", "contiguous"]))
@settings(max_examples=20, deadline=None)
def test_mask_for_disjoint_across_aggregators(n, A, scheme):
    """``mask_for`` never double-books a coordinate, for both strided and
    block (contiguous) assignments."""
    assign = masks_lib.make_assignment(n, A, scheme)
    total = sum(np.asarray(masks_lib.mask_for(assign, a)) for a in range(A))
    np.testing.assert_array_equal(total, np.ones(n))
    for a in range(A):
        for b in range(a + 1, A):
            overlap = (np.asarray(masks_lib.mask_for(assign, a))
                       * np.asarray(masks_lib.mask_for(assign, b)))
            assert overlap.sum() == 0


# ------------------------------------------- coalition union densities
@given(n=st.integers(32, 400), A=st.integers(2, 8), a_c=st.integers(1, 8))
@settings(max_examples=20, deadline=None)
def test_union_density_matches_observed_fraction(n, A, a_c):
    """|union of a_c colluders' masks| / n == observed_fraction(1, A, a_c)
    up to the per-mask rounding of at most 1 coordinate each."""
    a_c = min(a_c, A)
    assign = masks_lib.make_assignment(n, A, "strided")
    union = np.asarray(masks_lib.union_mask(assign, jnp.arange(a_c)))
    assert set(np.unique(union)) <= {0.0, 1.0}
    expected = privacy.observed_fraction(1.0, A, a_c) * n
    assert abs(union.sum() - expected) <= a_c


@given(A=st.integers(2, 8), a_c=st.integers(1, 4),
       p=st.sampled_from([0.2, 0.5, 1.0]))
@settings(max_examples=10, deadline=None)
def test_randp_composed_density_matches_observed_fraction(A, a_c, p):
    """Composing a RandP(p) payload with the coalition union: the expected
    fraction of OBSERVED nonzero coordinates is p * a_c / A (the Thm 3.3
    retention term), within binomial tolerance."""
    from repro.core.compressors import RandP
    a_c = min(a_c, A)
    n = 4096
    assign = masks_lib.make_assignment(n, A, "strided")
    union = masks_lib.union_mask(assign, jnp.arange(a_c))
    v = jnp.ones(n)
    observed = np.asarray(RandP(p=p)(jax.random.fold_in(KEY, A * 10 + a_c),
                                     v) * union)
    frac = privacy.observed_fraction(p, A, a_c)
    got = (observed != 0).sum()
    sigma = np.sqrt(max(n * frac * (1 - frac), 1.0))
    assert abs(got - frac * n) <= 5 * sigma + a_c


# -------------------------------------------------- mesh-induced masks
def test_mesh_assignment_mirrors_segment_layout():
    """``privacy.views.mesh_flat_assignment`` partitions every coordinate
    of segment-sharded leaves exactly once, maps psum-fallback leaves to
    -1, and ``flat_views_from_leaves`` reassembles ``split_shards`` rows
    into exactly the masked flat vector — the geometry contract between
    the distributed tap and the simulator's (A, K, n) views."""
    from repro.dist.sharding import split_shards
    from repro.privacy import views as pv
    params = {"w": jnp.arange(24.0).reshape(2, 12),
              "b": jnp.arange(100.0, 108.0),
              "odd": jnp.arange(3.0)}       # 3 not divisible by n_client=4
    n_client = 4
    assign = pv.mesh_flat_assignment(params, n_client)
    flat = np.concatenate([np.asarray(v).ravel()
                           for v in jax.tree.leaves(params)])
    assert assign.shape == flat.shape
    covered = assign >= 0
    # the indivisible leaf is psum-fallback (-1); the rest partition
    assert (~covered).sum() == 3
    sizes = np.bincount(assign[covered], minlength=n_client)
    assert sizes.sum() == covered.sum() and (sizes > 0).all()
    # captured split_shards rows reassemble to the masked flat vector
    leaves = jax.tree.leaves(params)
    layouts = pv.view_layouts(params, n_client)
    captured = {str(lay.index): np.asarray(
        split_shards(jnp.asarray(leaves[lay.index]), lay.dim, n_client)
    )[:, None, :] for lay in layouts if lay.dim >= 0}     # K=1 client
    flat_v = pv.flat_views_from_leaves(captured, params, n_client)
    assert flat_v.shape == (n_client, 1, flat.shape[0])
    for a in range(n_client):
        np.testing.assert_allclose(flat_v[a, 0],
                                   np.where(assign == a, flat, 0.0))


def test_mesh_assignment_and_reassembly_under_tp():
    """tp > 1 geometry: the tap emits, per captured leaf, the model
    positions' segment rows concatenated along the last dim (the
    shard_map out-spec places 'model' there, mesh-position order ==
    contiguous-chunk order).  Reassembly must land every value on its
    flat coordinate — for TP-sharded leaves (disjoint model chunks) AND
    model-replicated leaves (duplicate chunks, first one read)."""
    from repro.dist.sharding import split_shards
    from repro.models.shard_plan import TPSpec
    from repro.privacy import views as pv
    n_client, tp, K = 4, 2, 3
    params = {"w": jnp.arange(48.0).reshape(2, 24),     # TP col @ dim 1
              "b": jnp.arange(100.0, 116.0)}            # replicated
    specs = {"w": TPSpec(dim=1, kind="col"),
             "b": TPSpec(dim=-1, kind="replicate")}
    flat = np.concatenate([np.asarray(v).ravel()
                           for v in jax.tree.leaves(params)])
    assign = pv.mesh_flat_assignment(params, n_client, tp=tp,
                                     tp_specs=specs)
    assert (assign >= 0).all()

    def emulate_tap(leaf, spec):
        """What fsa_body captures for one client: per model position,
        split_shards of the TP-LOCAL leaf, concatenated on the last dim
        (duplicate chunks for replicated leaves)."""
        chunks = (jnp.split(leaf, tp, axis=spec.dim) if spec.dim >= 0
                  else [leaf] * tp)
        dim_l = pv.scatter_dim_for(chunks[0].shape, n_client)
        return np.concatenate(
            [np.asarray(split_shards(c, dim_l, n_client))
             for c in chunks], axis=-1)

    # K clients transmit scaled copies so client identity is checkable
    captured = {}
    for i, (name, leaf) in enumerate(sorted(params.items())):
        rows = emulate_tap(leaf, specs[name])            # (A, tp*m_loc)
        captured[str(i)] = np.stack(
            [(k + 1) * rows for k in range(K)], axis=1)  # (A, K, ...)
    flat_v = pv.flat_views_from_leaves(captured, params, n_client,
                                       tp=tp, tp_specs=specs)
    assert flat_v.shape == (n_client, K, flat.shape[0])
    for a in range(n_client):
        for k in range(K):
            np.testing.assert_allclose(
                flat_v[a, k],
                np.where(assign == a, (k + 1) * flat, 0.0))


def test_colluding_view_union():
    from repro.privacy import views as pv
    v = np.zeros((3, 2, 6))
    v[0, :, 0] = 1.0
    v[2, :, 5] = 2.0
    got = pv.colluding_view(v, [0, 2])
    assert got.shape == (2, 6)
    np.testing.assert_allclose(got[:, 0], 1.0)
    np.testing.assert_allclose(got[:, 5], 2.0)
    assert got[:, 1:5].sum() == 0
