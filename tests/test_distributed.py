"""Distributed runtime execution tests (subprocess with 8 host devices so
the main test process keeps its single-device view)."""
import json
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config
    from repro.data import lm_token_batches
    from repro.launch.mesh import make_host_mesh
    from repro.launch.train import TrainSettings, make_train_step
    from repro.models import transformer as tr
    from repro.optim import adam

    KEY = jax.random.PRNGKey(0)
    cfg = get_config("qwen2-0.5b").smoke()
    toks = lm_token_batches(KEY, 1, 8, 32, cfg.vocab)[0]
    batch = {"tokens": toks}
    opt = adam(1e-2)

    # ---- single-device FedAvg reference (centralized aggregation) ----
    params_ref = tr.init_params(KEY, cfg)
    opt_ref = opt.init(params_ref)
    def ref_step(params, state, batch):
        loss, g = jax.value_and_grad(
            lambda p: tr.loss_fn(p, cfg, batch))(params)
        delta, state = opt.update(g, state, params)
        return jax.tree.map(jnp.add, params, delta), state, loss
    ref_losses = []
    rp, rs = params_ref, opt_ref
    for i in range(5):
        rp, rs, l = jax.jit(ref_step)(rp, rs, batch)
        ref_losses.append(float(l))

    # ---- distributed FSA on a (4, 2) mesh ----
    mesh = make_host_mesh(data=4, model=2)
    settings = TrainSettings(grad_dtype="float32")
    step, shardings = make_train_step(cfg, mesh, opt, settings)
    with mesh:
        params = jax.device_put(tr.init_params(KEY, cfg),
                                shardings["store"])
        opt_state = opt.init(params)
        dsc_ref = jax.tree.map(lambda p: jnp.zeros((), jnp.float32), params)
        fsa_losses = []
        jstep = jax.jit(step)
        for i in range(5):
            params, opt_state, dsc_ref, m = jstep(
                params, opt_state, dsc_ref, batch, jax.random.PRNGKey(i))
            fsa_losses.append(float(m["loss"]))
    print(json.dumps({"ref": ref_losses, "fsa": fsa_losses}))
""")


@pytest.mark.slow
def test_fsa_distributed_matches_fedavg_reference():
    """Theorem B.1 on the production runtime: the FSA-sharded distributed
    train step follows the centralized FedAvg loss trajectory."""
    r = subprocess.run([sys.executable, "-c", SCRIPT],
                       capture_output=True, text=True, timeout=900,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    ref, fsa = out["ref"], out["fsa"]
    assert all(abs(a - b) / max(abs(a), 1e-6) < 0.05
               for a, b in zip(ref, fsa)), (ref, fsa)
    assert fsa[-1] < fsa[0]       # it actually trains
