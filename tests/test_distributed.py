"""Distributed runtime execution tests (subprocess with 8 host devices so
the main test process keeps its single-device view), plus the 512-device
lowering regression (subprocess with 512 placeholder devices)."""
import json
import subprocess
import sys
import textwrap

import pytest

from conftest import SUBPROC_ENV

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config
    from repro.data import lm_token_batches
    from repro.launch.mesh import make_host_mesh
    from repro.launch.train import TrainSettings, make_train_step
    from repro.models import transformer as tr
    from repro.optim import adam

    KEY = jax.random.PRNGKey(0)
    cfg = get_config("qwen2-0.5b").smoke()
    toks = lm_token_batches(KEY, 1, 8, 32, cfg.vocab)[0]
    batch = {"tokens": toks}
    opt = adam(1e-2)

    # ---- single-device FedAvg reference (centralized aggregation) ----
    params_ref = tr.init_params(KEY, cfg)
    opt_ref = opt.init(params_ref)
    def ref_step(params, state, batch):
        loss, g = jax.value_and_grad(
            lambda p: tr.loss_fn(p, cfg, batch))(params)
        delta, state = opt.update(g, state, params)
        return jax.tree.map(jnp.add, params, delta), state, loss
    ref_losses = []
    rp, rs = params_ref, opt_ref
    for i in range(5):
        rp, rs, l = jax.jit(ref_step)(rp, rs, batch)
        ref_losses.append(float(l))

    # ---- distributed FSA on a (4, 2) mesh ----
    mesh = make_host_mesh(data=4, model=2)
    settings = TrainSettings(grad_dtype="float32")
    step, shardings = make_train_step(cfg, mesh, opt, settings)
    with mesh:
        params = jax.device_put(tr.init_params(KEY, cfg),
                                shardings["store"])
        opt_state = opt.init(params)
        dsc_ref = jax.tree.map(lambda p: jnp.zeros((), jnp.float32), params)
        fsa_losses = []
        jstep = jax.jit(step)
        for i in range(5):
            params, opt_state, dsc_ref, m = jstep(
                params, opt_state, dsc_ref, batch, jax.random.PRNGKey(i))
            fsa_losses.append(float(m["loss"]))
    print(json.dumps({"ref": ref_losses, "fsa": fsa_losses}))
""")


@pytest.mark.slow
def test_fsa_distributed_matches_fedavg_reference():
    """Theorem B.1 on the production runtime: the FSA-sharded distributed
    train step follows the centralized FedAvg loss trajectory."""
    r = subprocess.run([sys.executable, "-c", SCRIPT],
                       capture_output=True, text=True, timeout=900,
                       env=SUBPROC_ENV)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    ref, fsa = out["ref"], out["fsa"]
    assert all(abs(a - b) / max(abs(a), 1e-6) < 0.05
               for a, b in zip(ref, fsa)), (ref, fsa)
    assert fsa[-1] < fsa[0]       # it actually trains


@pytest.mark.slow
def test_fsa_int8_wire_matches_simulator():
    """The int8-wire FSA runtime (quantize -> all_to_all int8 blocks +
    f32 scales -> dequantize aggregator-side) lands on the simulator's
    ``int8_wire`` trajectory: same stage list, independent rounding
    draws, so final params agree to the quantization tolerance.  Reuses
    the three-engine subprocess harness from test_parity_engines (one
    shared setup, two wire formats across the two files)."""
    import numpy as np
    from test_parity_engines import _run_parity
    out = _run_parity(int8=True)
    sim, dist = np.asarray(out["sim"]), np.asarray(out["dist"])
    x0 = np.asarray(out["x0"])
    np.testing.assert_allclose(dist, sim, atol=1e-2)
    np.testing.assert_allclose(np.asarray(out["scan"]), sim,
                               rtol=1e-5, atol=1e-5)
    assert np.abs(dist - x0).max() > 1e-3       # it actually trains


ASYNC_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.flatten_util import ravel_pytree
    from repro.configs import get_config
    from repro.core.fl import FLConfig, FLRun
    from repro.data import lm_token_batches
    from repro.launch.mesh import make_host_mesh
    from repro.launch.train import (TrainSettings, init_dsc_state,
                                    make_train_step)
    from repro.models import transformer as tr
    from repro.optim import sgd

    LR, STEPS, CADENCE = 0.05, 4, 2
    KEY = jax.random.PRNGKey(0)
    cfg = get_config("qwen2-0.5b").smoke()
    toks = lm_token_batches(KEY, 1, 8, 32, cfg.vocab)[0]
    batch = {"tokens": toks}
    params0 = tr.init_params(KEY, cfg)

    # ---- simulator + scan engines: eris_async, cadence 2, int8 wire ----
    fl_cfg = FLConfig(method="eris_async", K=4, A=4, lr=LR, int8_wire=True,
                      buffer_cadence=CADENCE, rounds=STEPS)
    loss_fn = lambda p, b: tr.loss_fn(p, cfg, b)
    client_batches = {"tokens": toks.reshape(4, 2, 32)}
    sim = FLRun(fl_cfg, params0, loss_fn)
    sim_traj = []
    for _ in range(STEPS):
        sim.step(client_batches)
        sim_traj.append(np.asarray(sim.x))
    scan = FLRun(fl_cfg, params0, loss_fn)
    stacked = jax.tree.map(lambda x: jnp.stack([x] * STEPS), client_batches)
    scan.run_scanned(stacked)

    # ---- distributed shard_map runtime with the FedBuff buffer ---------
    mesh = make_host_mesh(data=4, model=2)
    settings = TrainSettings(grad_dtype="float32", int8_wire=True,
                             async_buffer=True, buffer_cadence=CADENCE)
    step, shardings = make_train_step(cfg, mesh, sgd(LR), settings)
    with mesh:
        params = jax.device_put(params0, shardings["store"])
        opt_state = sgd(LR).init(params)
        state = init_dsc_state(cfg, mesh, settings)
        jstep = jax.jit(step)
        dist_traj = []
        for i in range(STEPS):
            params, opt_state, state, m = jstep(
                params, opt_state, state, batch, jax.random.PRNGKey(i))
            dist_traj.append(np.asarray(
                ravel_pytree(jax.device_get(params))[0]))
    out = {
        "sim": np.stack(sim_traj).tolist(),
        "scan": np.asarray(scan.x).tolist(),
        "dist": np.stack(dist_traj).tolist(),
        "x0": np.asarray(ravel_pytree(params0)[0]).tolist(),
    }
    print("ASYNC" + json.dumps(out))
""")


@pytest.mark.slow
def test_async_buffer_distributed_matches_simulator():
    """ISSUE 7 satellite: the distributed runtime's FedBuff buffer
    (``async_buffer`` + ``buffer_cadence=2`` + int8 wire, trivial
    arrivals) follows the simulator's ``eris_async`` trajectory on 8
    devices — same buffer fold and cadence gate, independent int8
    rounding draws, so per-round params agree to the quantization
    tolerance and the model provably holds still between apply rounds."""
    import numpy as np
    r = subprocess.run([sys.executable, "-c", ASYNC_SCRIPT],
                       capture_output=True, text=True, timeout=900,
                       env=SUBPROC_ENV)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [ln for ln in r.stdout.splitlines() if ln.startswith("ASYNC")][-1]
    out = json.loads(line[len("ASYNC"):])
    sim, dist = np.asarray(out["sim"]), np.asarray(out["dist"])
    x0 = np.asarray(out["x0"])
    # engines sharing the stage list agree tightly on the final iterate
    np.testing.assert_allclose(np.asarray(out["scan"]), sim[-1],
                               rtol=1e-5, atol=1e-5)
    # distributed buffer fold lands in the int8 rounding band, per round
    np.testing.assert_allclose(dist, sim, atol=1e-2)
    # cadence gate: rounds 1 and 3 apply nothing, 2 and 4 move the model
    for traj in (sim, dist):
        steps = [traj[0]] + [traj[i] - traj[i - 1] for i in range(1, 4)]
        moved = [bool(np.abs(s - (x0 if i == 0 else 0)).max() > 0)
                 for i, s in enumerate(steps)]
        assert moved == [False, True, False, True], moved
    assert np.abs(sim[-1] - x0).max() > 1e-3    # it actually trains


TP4_INT8_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.flatten_util import ravel_pytree
    from repro.configs import get_config
    from repro.core.fl import FLConfig, FLRun
    from repro.data import lm_token_batches
    from repro.launch.mesh import make_host_mesh
    from repro.launch.train import TrainSettings, make_train_step
    from repro.models import transformer as tr
    from repro.optim import sgd

    LR, STEPS = 0.05, 4
    KEY = jax.random.PRNGKey(0)
    cfg = get_config("qwen2-0.5b").smoke()
    assert tr.tp_plan(cfg, 4).active        # ffn+vocab shard, attn falls back
    toks = lm_token_batches(KEY, 1, 8, 32, cfg.vocab)[0]
    batch = {"tokens": toks}
    params0 = tr.init_params(KEY, cfg)

    # ---- simulator: K=2 clients, one per client-axis group --------------
    fl_cfg = FLConfig(method="eris", K=2, A=2, lr=LR, int8_wire=True,
                      rounds=STEPS)
    loss_fn = lambda p, b: tr.loss_fn(p, cfg, b)
    sim = FLRun(fl_cfg, params0, loss_fn)
    for _ in range(STEPS):
        sim.step({"tokens": toks.reshape(2, 4, 32)})

    # ---- distributed runtime on a (2 data, 4 model) mesh ----------------
    mesh = make_host_mesh(data=2, model=4)
    settings = TrainSettings(grad_dtype="float32", int8_wire=True)
    step, shardings = make_train_step(cfg, mesh, sgd(LR), settings)
    with mesh:
        params = jax.device_put(params0, shardings["store"])
        opt_state = sgd(LR).init(params)
        dsc_ref = jax.tree.map(lambda p: jnp.zeros((), jnp.float32), params)
        jstep = jax.jit(step)
        for i in range(STEPS):
            params, opt_state, dsc_ref, m = jstep(
                params, opt_state, dsc_ref, batch, jax.random.PRNGKey(i))
        # the stored FFN weights really are 4-way model-sharded
        wd = params["blocks"]["w_down"]
        assert "model" in str(wd.sharding.spec), wd.sharding.spec
    dist_flat, _ = ravel_pytree(jax.device_get(params))
    out = {
        "sim": np.asarray(sim.x).tolist(),
        "dist": np.asarray(dist_flat).tolist(),
        "x0": np.asarray(ravel_pytree(params0)[0]).tolist(),
    }
    print("TP4INT8" + json.dumps(out))
""")


@pytest.mark.slow
def test_tp4_composes_with_int8_client_wire():
    """ISSUE satellite: 4-way model-axis TP (FFN + vocab sharded, GQA
    attention fallback) composed with the int8 client wire on 8 devices
    follows the simulator's int8 trajectory — the quantized FSA exchange
    operates on TP-local segments without breaking Theorem B.1."""
    import numpy as np
    r = subprocess.run([sys.executable, "-c", TP4_INT8_SCRIPT],
                       capture_output=True, text=True, timeout=900,
                       env=SUBPROC_ENV)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("TP4INT8")][-1]
    out = json.loads(line[len("TP4INT8"):])
    sim, dist = np.asarray(out["sim"]), np.asarray(out["dist"])
    x0 = np.asarray(out["x0"])
    np.testing.assert_allclose(dist, sim, atol=1e-2)
    assert np.abs(dist - x0).max() > 1e-3       # it actually trains
    assert np.abs(sim - x0).max() > 1e-3


@pytest.mark.slow
def test_512_device_lowering_int8_wire(tmp_path):
    """ROADMAP regression: the 2x16x16 (512-device) config must compile
    under the full-manual lowering (no ``IsManualSubgroup`` abort) WITH
    model-axis tensor parallelism and NO replicated group compute: FFN +
    vocab shard 16-way, and attention — whose heads (kv=2 < 16) can't
    divide — rides the context-parallel ppermute ring (sequence-sharded
    K/V rotation) instead of the old replicated fallback.  The FSA
    reduce-scatter stage's payload — read from the lowered HLO by
    ``hlo_analysis`` — must cross the mesh as int8, disjoint from the
    model-axis psum traffic."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen2-0.5b",
         "--shape", "train_1k", "--multi-pod", "--int8-wire",
         # per-layer psum counts below assume monolithic model-axis
         # all-reduces and the naive attention lowering — pin the
         # (now default-on) kernel knobs off for this regression
         "--opt", "flash_attention=false,overlap_collectives=false",
         "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=900,
        env=SUBPROC_ENV)
    assert r.returncode == 0, (r.stdout[-500:], r.stderr[-2000:])
    rec = json.loads((tmp_path / "qwen2-0_5b__train_1k_mp.json").read_text())
    assert rec["devices"] == 512
    assert rec["wire_dtype"] == "s8"        # the reduce-scatter stage dtype
    dtypes = rec["collective_bytes_per_device"]["dtypes"]
    # int8 blocks dominate the exchange; f32 appears only as the scales
    a2a = dtypes["all-to-all"]
    assert a2a.get("s8", 0) > 0
    assert a2a.get("s8", 0) > 10 * a2a.get("f32", 0)
    # the client wire never falls back to a wide-dtype reduce-scatter
    # (the ctx ring / grad-norm path may emit tiny model-axis f32 ones)
    cb = rec["collective_bytes_per_device"]
    assert "reduce-scatter" not in cb["axes"].get("client", {})
    assert dtypes["reduce-scatter"].get("s8", 0) == 0
    assert dtypes["reduce-scatter"].get("f32", 0) < 1e4
    # --- tensor parallelism actually engaged on the model axis ---------
    assert rec["tp"] == {"size": 16, "attn": False, "ffn": True,
                         "vocab": True, "moe": False, "mixer": False,
                         "seq": False, "ctx": 16, "seq_ce": False,
                         "sharded_leaves": 4}
    axes = rec["collective_bytes_per_device"]["axes"]
    counts = rec["collective_bytes_per_device"]["axis_counts"]
    # Megatron psums: >= one all-reduce per layer per direction (24
    # layers), carrying real activation bytes
    assert axes["model"]["all-reduce"] > 0
    assert counts["model"]["all-reduce"] >= 2 * 24
    # ring attention: the K/V rotation ppermutes n-1 hops per layer per
    # direction on the model axis, and EVERY ppermute classifies onto a
    # real axis (reverse-direction rings included — nothing priced at
    # the full 512-device ring)
    assert counts["model"]["collective-permute"] >= 24 * 15
    assert counts.get("all", {}).get("collective-permute", 0) == 0
    # the client wire (broadcast all-gather + int8 all-to-all) never
    # rides the model axis
    assert axes["client"]["all-gather"] > 0
    assert axes["client"]["all-to-all"] > 0
    assert "all-to-all" not in axes.get("model", {})


@pytest.mark.slow
def test_512_device_lowering_moe_expert_parallel(tmp_path):
    """ISSUE 4 regression: the 512-device lowering of an MoE config
    engages EXPERT parallelism on the model axis — stored expert weights
    are model-sharded on their expert dim, token dispatch/combine cross
    the model axis as ``all_to_all``s (disjoint from the client wire,
    which stays int8), and the router replicates with partial-grad
    psums."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "olmoe-1b-7b", "--shape", "train_1k", "--multi-pod",
         "--int8-wire",
         "--opt", "flash_attention=false,overlap_collectives=false",
         "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=1800,
        env=SUBPROC_ENV)
    assert r.returncode == 0, (r.stdout[-500:], r.stderr[-2000:])
    rec = json.loads(
        (tmp_path / "olmoe-1b-7b__train_1k_mp.json").read_text())
    assert rec["devices"] == 512
    tp = rec["tp"]
    assert tp["size"] == 16 and tp["moe"] and tp["vocab"] and tp["attn"]
    # olmoe shards attn (16 kv heads) + 3 expert leaves + embed/head:
    # wq wk wv wo w_gate w_up w_down embed lm_head
    assert tp["sharded_leaves"] == 9
    axes = rec["collective_bytes_per_device"]["axes"]
    counts = rec["collective_bytes_per_device"]["axis_counts"]
    # expert-parallel token traffic: >= 2 all_to_alls per layer per
    # direction (16 layers; dispatch + combine, fwd + transpose)
    assert axes["model"]["all-to-all"] > 0
    assert counts["model"]["all-to-all"] >= 4 * 16
    # the FSA client wire is still int8 and still client-only — the
    # model-axis token all_to_all must not masquerade as the wire
    assert rec["wire_dtype"] == "s8"
    a2a_model = rec["collective_bytes_per_device"]["axis_dtypes"][
        "model"]["all-to-all"]
    assert a2a_model.get("s8", 0) == 0          # tokens, not wire blocks
    assert axes["client"]["all-to-all"] > 0


@pytest.mark.slow
def test_512_device_lowering_seq_parallel(tmp_path):
    """ISSUE 4 regression: a sequence-parallel dense plan converts the
    per-region Megatron psum pairs into psum_scatter/all_gather
    conjugates — the per-region all-reduces collapse, every psum byte
    reappears as exactly one psum_scatter (reduce-scatter) byte, and
    the ring-weighted model-axis link cost stays within the full-remat
    allowance (the backward re-gathers each region entry; the base
    plan's remat recomputes the corresponding psums, but an entry psum
    is identity-forward so its recompute is free — one extra all-gather
    per region, bounded below).

    gptneo (16 MHA heads, d_ff 8192) is the arch whose ATTENTION also
    shards 16-way, so base and seq run the same set of sharded regions;
    the vocab override (50257 -> 50176) makes the vocab divisible, which
    a seq plan requires."""
    pin = ",flash_attention=false,overlap_collectives=false"
    for opt, tag in [("vocab=50176" + pin, "base"),
                     ("vocab=50176,seq_parallel=true" + pin, "seq")]:
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch",
             "eris-gptneo-1.3b", "--shape", "train_1k", "--multi-pod",
             "--int8-wire", "--opt", opt, "--tag", tag,
             "--out", str(tmp_path)],
            capture_output=True, text=True, timeout=1800,
            env=SUBPROC_ENV)
        assert r.returncode == 0, (tag, r.stdout[-500:], r.stderr[-2000:])
    base = json.loads(
        (tmp_path / "eris-gptneo-1_3b__train_1k_mp_base.json").read_text())
    seq = json.loads(
        (tmp_path / "eris-gptneo-1_3b__train_1k_mp_seq.json").read_text())
    assert not base["tp"]["seq"] and seq["tp"]["seq"]
    assert base["tp"]["attn"] and seq["tp"]["attn"]
    b_ax = base["collective_bytes_per_device"]["axes"]
    s_ax = seq["collective_bytes_per_device"]["axes"]
    b_cnt = base["collective_bytes_per_device"]["axis_counts"]
    s_cnt = seq["collective_bytes_per_device"]["axis_counts"]
    # the conjugate pair replaces the paired psums: byte-for-byte, the
    # base's model-axis all-reduce payload becomes reduce-scatter
    # payload (same multiset of region collectives, scatter halves)...
    assert b_cnt["model"].get("reduce-scatter", 0) == 0
    rs, ar = s_ax["model"]["reduce-scatter"], b_ax["model"]["all-reduce"]
    assert abs(rs - ar) / ar < 0.02, (rs, ar)
    # ...the per-region all-reduces are gone (only the CE scalar fields
    # remain)...
    assert s_cnt["model"]["all-reduce"] < b_cnt["model"]["all-reduce"] / 8
    assert s_ax["model"].get("all-gather", 0) > 0
    # ...and the ring-weighted model-axis link cost stays within the
    # remat re-gather allowance (AR costs RS + AG on the wire; the one
    # extra AG per region recompute bounds the overhead well under 25%).
    # Ring weights inline (mirrors benchmarks/roofline.py) so the test
    # stays hermetic — no sys.path mutation to import benchmarks/.
    def model_link_cost(rec):
        n = rec["tp"]["size"]
        w = {"all-reduce": 2 * (n - 1) / n, "all-gather": (n - 1) / n,
             "reduce-scatter": (n - 1) / n, "all-to-all": (n - 1) / n}
        model = rec["collective_bytes_per_device"]["axes"]["model"]
        return sum(v * w.get(k, 1.0) for k, v in model.items())

    assert model_link_cost(seq) <= model_link_cost(base) * 1.25, (
        model_link_cost(seq), model_link_cost(base))
    # the client wire format is untouched by the activation re-layout
    assert seq["wire_dtype"] == "s8"
    assert s_ax["client"]["all-to-all"] > 0


@pytest.mark.slow
def test_512_device_lowering_26b_pipeline(tmp_path):
    """ISSUE 9 acceptance: a >=26B-parameter config lowers AND compiles
    at 512 devices with an ACTIVE pipeline plan — the 2x4x4x16
    (pod, data, pipe, model) mesh runs qwen3-32b's 64 layers as 4
    contiguous stages of 16 under the microbatched 1F1B scan, the
    stage-boundary activation sends classify onto the ``pipe`` axis
    (m + p - 1 wavefront ticks), and the per-device resident parameter
    bytes shrink with the pipe x TP product."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "qwen3-32b", "--shape", "train_4k", "--multi-pod",
         "--pp", "4", "--microbatches", "8",
         "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=1800,
        env=SUBPROC_ENV)
    assert r.returncode == 0, (r.stdout[-500:], r.stderr[-2000:])
    rec = json.loads(
        (tmp_path / "qwen3-32b__train_4k_mp_pp4.json").read_text())
    assert rec["devices"] == 512 and rec["mesh"] == "2x4x4x16"
    assert rec["params"] > 26e9
    # the pipeline plan engaged: 64 layers / 4 stages, 8 microbatches
    assert rec["pp"] == {"size": 4, "microbatches": 8,
                         "layers_per_stage": 16,
                         "bubble_fraction": pytest.approx(3 / 11)}
    tp = rec["tp"]
    assert tp["size"] == 16 and tp["ffn"] and tp["vocab"]
    # qwen3's GQA kv heads don't divide 16 -> ring attention, not the
    # replicated fallback (ISSUE 9 closes the PR 4 gap at scale)
    assert not tp["attn"] and tp["ctx"] == 16
    axes = rec["collective_bytes_per_device"]["axes"]
    counts = rec["collective_bytes_per_device"]["axis_counts"]
    # stage-boundary ppermutes ride the pipe axis: one send per 1F1B
    # wavefront tick (m + p - 1 = 11), real activation bytes
    assert counts["pipe"]["collective-permute"] >= 11
    assert axes["pipe"]["collective-permute"] > 0
    # non-block grads (embed/lm_head/ln_f) psum over pipe
    assert counts["pipe"]["all-reduce"] > 0
    # every ppermute classifies onto a real axis (model ring / pipe
    # boundary / client) — nothing priced at the 512-device ring
    assert counts.get("all", {}).get("collective-permute", 0) == 0
    # resident params shrink with the pipe x TP product: within 2.5x of
    # the uniform total/(tp*pp) floor (ring attention leaves the attn
    # weights model-replicated, so exactly uniform is unreachable), and
    # far below a pipe-only split
    total_bytes = 4 * rec["params"]
    per_dev = rec["param_bytes_per_device"]
    assert per_dev <= 2.5 * total_bytes / (16 * 4), per_dev
    assert per_dev < total_bytes / 8
