"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + properties.

Kernels run in interpret mode on CPU (the kernel body itself executes),
asserted allclose against repro.kernels.ref oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.dsc_quantize import dsc_quantize
from repro.kernels.dsc_update import dsc_update
from repro.kernels.flash_attention import flash_attention
from repro.kernels.quantize import QBLOCK, dequantize, quantize

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------- DSC
@pytest.mark.parametrize("n,block_rows", [(1024, 1), (4096, 2), (8192, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("p", [0.1, 0.5, 1.0])
def test_dsc_update_matches_ref(n, block_rows, dtype, p):
    g = jax.random.normal(KEY, (n,), jnp.float32).astype(dtype)
    s = jax.random.normal(jax.random.fold_in(KEY, 1), (n,))
    seed = jnp.uint32(42)
    v, s_new = dsc_update(g, s, seed, p=p, gamma=0.5,
                          block_rows=block_rows, interpret=True)
    v_ref, s_ref = ref.dsc_update_ref(g, s, seed, p=p, gamma=0.5)
    np.testing.assert_allclose(np.asarray(v, np.float32),
                               np.asarray(v_ref, np.float32),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_new), np.asarray(s_ref),
                               rtol=1e-5, atol=1e-5)


def test_dsc_update_retention_and_unbiasedness():
    n, p = 64 * 1024, 0.25
    g = jax.random.normal(KEY, (n,))
    s = jnp.zeros(n)
    vs = []
    for seed in range(30):
        v, _ = dsc_update(g, s, jnp.uint32(seed), p=p, gamma=0.5,
                          interpret=True)
        vs.append(np.asarray(v))
    frac = np.mean([np.mean(v != 0) for v in vs])
    assert abs(frac - p) < 0.02
    err = np.abs(np.mean(vs, 0) - np.asarray(g)).mean()
    assert err < 0.5   # MC mean approaches g (unbiased compressor)


# ------------------------------------------------------------- quantize
@pytest.mark.parametrize("n", [QBLOCK, 4 * QBLOCK, 64 * QBLOCK])
def test_quantize_matches_ref(n):
    x = 3.0 * jax.random.normal(KEY, (n,))
    seed = jnp.uint32(7)
    q, sc = quantize(x, seed, interpret=True)
    q_ref, sc_ref = ref.quantize_ref(x, seed)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q_ref)[:n])
    np.testing.assert_allclose(np.asarray(sc), np.asarray(sc_ref), rtol=1e-6)
    # dequantize roundtrip error bounded by one quantization step
    xd = dequantize(q, sc, interpret=True)
    step = np.repeat(np.asarray(sc), QBLOCK)
    assert np.all(np.abs(np.asarray(xd) - np.asarray(x)) <= step + 1e-6)


def test_quantize_unbiased():
    n = 8 * QBLOCK
    x = jax.random.normal(KEY, (n,))
    outs = []
    for seed in range(50):
        q, sc = quantize(x, jnp.uint32(seed), interpret=True)
        outs.append(np.asarray(dequantize(q, sc, interpret=True)))
    err = np.abs(np.mean(outs, 0) - np.asarray(x)).mean()
    scale_mean = np.asarray(sc).mean()
    assert err < 0.6 * scale_mean  # MC mean within a fraction of one step


def test_quantize_zero_block_safe():
    x = jnp.zeros(QBLOCK)
    q, sc = quantize(x, jnp.uint32(0), interpret=True)
    assert not np.any(np.asarray(q))
    assert float(sc[0]) == 0.0


# ------------------------------------------- masked-tail (ragged) contract
# The counter-based RNG indexes the FLAT GLOBAL element position, so a
# kernel's internal zero-padding must never displace a real element's
# draw: kernel(x[:n]) == ref-on-exactly-n for ANY n, not just tiles.
@settings(max_examples=12, deadline=None)
@given(n=st.integers(1, 5000), seed=st.integers(0, 1000))
def test_dsc_update_ragged_matches_ref(n, seed):
    g = jax.random.normal(jax.random.PRNGKey(seed), (n,))
    s = jax.random.normal(jax.random.PRNGKey(seed + 1), (n,))
    v, s_new = dsc_update(g, s, jnp.uint32(seed), p=0.3, gamma=0.5,
                          interpret=True)
    v_ref, s_ref = ref.dsc_update_ref(g, s, jnp.uint32(seed), p=0.3,
                                      gamma=0.5)
    assert v.shape == (n,) and s_new.shape == (n,)
    np.testing.assert_allclose(np.asarray(v), np.asarray(v_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_new), np.asarray(s_ref),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=12, deadline=None)
@given(n=st.integers(1, 4 * QBLOCK + 37), seed=st.integers(0, 1000))
def test_quantize_ragged_matches_ref(n, seed):
    x = 2.0 * jax.random.normal(jax.random.PRNGKey(seed), (n,))
    q, sc = quantize(x, jnp.uint32(seed), interpret=True)
    q_ref, sc_ref = ref.quantize_ref(x, jnp.uint32(seed))
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q_ref))
    np.testing.assert_allclose(np.asarray(sc), np.asarray(sc_ref),
                               rtol=1e-6)
    # padded tail must quantize to exact zeros (scale of a zero block = 0)
    pad = (-n) % QBLOCK
    if pad:
        assert not np.any(np.asarray(q)[n:])


# ------------------------------------------------- fused DSC -> int8 wire
@pytest.mark.parametrize("n", [8 * QBLOCK, 2305, 511])
@pytest.mark.parametrize("p", [0.25, 1.0])
def test_dsc_quantize_matches_ref(n, p):
    g = jax.random.normal(KEY, (n,))
    s = 0.1 * jax.random.normal(jax.random.fold_in(KEY, 1), (n,))
    sm, sr = jnp.uint32(11), jnp.uint32(12)
    q, sc, s_new = dsc_quantize(g, s, sm, sr, p=p, gamma=0.5,
                                interpret=True)
    q_ref, sc_ref, s_ref = ref.dsc_quantize_ref(g, s, sm, sr, p=p,
                                                gamma=0.5)
    # bit-exact: same RNG indices, same blockmax, same stochastic round
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q_ref))
    np.testing.assert_allclose(np.asarray(sc), np.asarray(sc_ref),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(s_new), np.asarray(s_ref),
                               rtol=1e-5, atol=1e-6)


def test_dsc_quantize_matches_unfused_chain():
    """The one-pass kernel == dsc_update -> quantize -> dequantize ->
    shift update composed from the standalone kernels (the unfused wire
    path it replaces), sharing the same two seeds."""
    n, p, gamma = 4 * QBLOCK, 0.5, 0.7
    g = jax.random.normal(KEY, (n,))
    s = 0.2 * jax.random.normal(jax.random.fold_in(KEY, 2), (n,))
    sm, sr = jnp.uint32(3), jnp.uint32(4)
    q, sc, s_new = dsc_quantize(g, s, sm, sr, p=p, gamma=gamma,
                                interpret=True)
    v, _ = dsc_update(g, s, sm, p=p, gamma=gamma, interpret=True)
    q2, sc2 = quantize(v, sr, interpret=True)
    v_hat = dequantize(q2, sc2, interpret=True)[:n]
    np.testing.assert_array_equal(np.asarray(q)[:n], np.asarray(q2)[:n])
    np.testing.assert_allclose(np.asarray(sc), np.asarray(sc2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(s_new),
                               np.asarray(s + gamma * v_hat),
                               rtol=1e-5, atol=1e-6)


# ------------------------------------------------------- flash attention
@pytest.mark.parametrize("S,bq,bk", [(128, 128, 128), (256, 128, 64),
                                     (256, 64, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(S, bq, bk, causal):
    B, H, d = 2, 3, 64
    q = jax.random.normal(KEY, (B, H, S, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, H, S, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, H, S, d))
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                          interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=8, deadline=None)
@given(s_blocks=st.integers(1, 4), d=st.sampled_from([32, 64, 128]),
       seed=st.integers(0, 100))
def test_flash_attention_property_sweep(s_blocks, d, seed):
    B, H, bq = 1, 2, 64
    S = s_blocks * bq
    kk = jax.random.PRNGKey(seed)
    q, k, v = (jax.random.normal(jax.random.fold_in(kk, i), (B, H, S, d))
               for i in range(3))
    out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bq,
                          interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=3e-4, atol=3e-4)


def test_flash_attention_bf16():
    B, H, S, d = 1, 2, 128, 64
    q, k, v = (jax.random.normal(jax.random.fold_in(KEY, i), (B, H, S, d)
                                 ).astype(jnp.bfloat16) for i in range(3))
    out = flash_attention(q, k, v, causal=True, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=3e-2, atol=3e-2)
