"""Cross-silo scenario pack: key discipline, exact mask cancellation,
composition identities, failure injection, the RDP accountant, and the
tier-1 quick smoke over the defense x failure matrix."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import accountant as acct
from repro.core import baselines as bl
from repro.core import eris
from repro.core import pipeline as pl
from repro.core import secure_agg as sa
from repro.core.fl import FLConfig, FLRun
from repro.core.rounds import DEFENSES, FAILURES, Scenario, scenario_matrix
from repro.core.rounds import scenarios as sc

KEY = jax.random.PRNGKey(0)


def quad_problem(key, K=6, n=40):
    ka, kb = jax.random.split(key)
    a = 1.0 + jax.random.uniform(ka, (K, n))
    b = jax.random.normal(kb, (K, n))

    def loss_fn(params, batch):
        aa, bb = batch
        return 0.5 * jnp.mean((aa * params - bb) ** 2)

    return jnp.zeros(n), loss_fn, (a, b)


# ----------------------------------------------------- RNG key discipline
@given(role_bits=st.integers(0, 7), seed=st.integers(0, 2 ** 16))
@settings(max_examples=30, deadline=None)
def test_role_keys_pairwise_independent(role_bits, seed):
    """Active roles get keys distinct from k_comp AND from each other;
    inactive roles alias k_comp bit-exactly (pure-eris compatibility)."""
    all_roles = sorted(eris.ROLE_SALTS)
    roles = {r for i, r in enumerate(all_roles) if role_bits >> i & 1}
    k_mask, k_comp = jax.random.split(jax.random.PRNGKey(seed))
    keys = eris._round_keys(k_mask, k_comp, active=frozenset(roles))
    by_role = {"noise": keys.noise, "fail": keys.fail, "part": keys.part}
    seen = [np.asarray(k_comp)]
    for role, k in by_role.items():
        k = np.asarray(k)
        if role in roles:
            assert not any(np.array_equal(k, s) for s in seen), role
            seen.append(k)
        else:
            np.testing.assert_array_equal(k, np.asarray(k_comp))


def test_pure_eris_keys_bit_compatible():
    """No active stage -> every per-role key IS k_comp (frozen seed
    trajectories stay valid)."""
    k_mask, k_comp = jax.random.split(KEY)
    keys = eris._round_keys(k_mask, k_comp)
    for k in (keys.noise, keys.fail, keys.part):
        np.testing.assert_array_equal(np.asarray(k), np.asarray(k_comp))


@given(seed=st.integers(0, 2 ** 16), frac=st.floats(0.005, 0.05))
@settings(max_examples=10, deadline=None)
def test_participation_always_nonempty(seed, frac):
    w = pl.participation_weights(jax.random.PRNGKey(seed), 16, frac)
    assert float(jnp.sum(w)) >= 1.0


def test_participation_force_decoupled_from_mask():
    """Regression for the participation_weights key reuse: the forced
    fallback index must come from its OWN key stream, not the bernoulli
    mask's.  At a tiny fraction the mask is almost always empty, so the
    forced index dominates — across many rounds it must cover the cohort
    roughly uniformly instead of tracking the mask draw."""
    K, T = 8, 400
    counts = np.zeros(K)
    for t in range(T):
        key = jax.random.PRNGKey(t)
        w = np.asarray(pl.participation_weights(key, K, 0.01))
        if w.sum() == 1.0:                     # forced-singleton round
            counts[int(np.argmax(w))] += 1
        # the OLD coupled draw: randint on the undivided round key
        old = int(jax.random.randint(key, (), 0, K))
        assert w.sum() >= 1.0 and (old or True)
    assert (counts > 0).all(), counts          # every index reachable
    expect = counts.sum() / K
    assert counts.max() < 2.5 * expect, counts # roughly uniform


# ------------------------------------------------ exact mask cancellation
@given(K=st.integers(2, 24), n=st.integers(1, 300),
       scale=st.sampled_from([1.0, 100.0, 1e4]),
       seed=st.integers(0, 2 ** 16))
@settings(max_examples=30, deadline=None)
def test_pairwise_masks_cancel_exactly_under_jit(K, n, scale, seed):
    """Fixed-point pairwise masks sum to EXACTLY zero for any cohort
    size, any coordinate count, any summation order jit picks."""
    masks = jax.jit(sa.pairwise_masks, static_argnums=(1, 2, 3))(
        jax.random.PRNGKey(seed), K, n, scale)
    total = np.asarray(jax.jit(lambda m: m.sum(0))(masks))
    assert (total == 0.0).all()
    assert float(np.abs(np.asarray(masks)).mean()) > 0.05 * scale


@given(K=st.integers(2, 12), seed=st.integers(0, 2 ** 16))
@settings(max_examples=20, deadline=None)
def test_pairwise_mask_row_matches_matrix(K, seed):
    """The per-participant row form (what the distributed engine adds at
    position aidx) is exactly the matrix row."""
    key = jax.random.PRNGKey(seed)
    full = np.asarray(sa.pairwise_masks(key, K, 17))
    for i in range(K):
        row = np.asarray(sa.pairwise_mask_row(key, i, K, 17))
        np.testing.assert_array_equal(row, full[i])


def test_secure_agg_refuses_weighted_cohort():
    """SecureAggAggregate must fail loudly on any weighted/partial
    cohort — unpaired masks would leave O(scale) garbage in the mean."""
    key = jax.random.PRNGKey(3)
    v = jax.random.normal(key, (4, 20))
    stage = pl.SecureAggAggregate()
    keys = pl.split_round_keys(key)
    state = pl.RoundState(x=jnp.zeros(20), dsc=None, ef=None, server=None)
    with pytest.raises(ValueError, match="full-cohort"):
        stage.apply(keys, state, v, jnp.ones(4).at[0].set(0.0))
    res = stage.apply(keys, state, v, None)
    np.testing.assert_allclose(np.asarray(res.update),
                               np.asarray(v.mean(0)), atol=1e-4)


# ----------------------------------------------- composition identities
def test_scenario_matrix_shape():
    cells = scenario_matrix(feasible_only=False)
    assert len(cells) == len(DEFENSES) * len(FAILURES) == 18
    feasible = scenario_matrix()
    assert len(feasible) == 15
    for cell in cells:
        assert cell.feasible == (cell.refusal is None)


def test_infeasible_cells_refuse_loudly():
    for name in ("secure_agg+agg_fail", "secure_agg+client_drop",
                 "dsc_int8+client_drop"):
        cell = sc.get(name)
        assert not cell.feasible
        with pytest.raises(ValueError, match="infeasible"):
            cell.fl_config()


def test_secure_mask_composition_refused_in_registry():
    x0, loss_fn, batches = quad_problem(KEY)
    with pytest.raises(ValueError, match="mask"):
        FLRun(FLConfig(method="eris", K=6, A=4, secure_mask=True,
                       participation=0.5), x0, loss_fn)


def test_secure_agg_scenario_matches_undefended_trajectory():
    """secure_agg+none == none+none up to the f32 absorption error of
    the mask magnitude: masks cancel in the cohort sum, so the defense
    changes the wire, not the aggregate."""
    x0, loss_fn, batches = quad_problem(KEY)
    runs = {}
    for name in ("none+none", "secure_agg+none"):
        run = FLRun(sc.get(name).fl_config(K=6, A=4, rounds=1, lr=0.3),
                    x0, loss_fn)
        for _ in range(6):
            run.step(batches)
        runs[name] = np.asarray(run.x)
    np.testing.assert_allclose(runs["secure_agg+none"], runs["none+none"],
                               atol=1e-4)
    assert np.abs(runs["none+none"]).max() > 1e-3


def test_eris_ldp_equals_fedavg_ldp_at_A1():
    """Composed LDP on the eris wire with a single aggregator IS
    fedavg_ldp: same noise role key, FSA(A=1) aggregation == mean."""
    x0, loss_fn, batches = quad_problem(jax.random.fold_in(KEY, 7))
    ldp = sc.SCENARIO_LDP
    run_e = FLRun(FLConfig(method="eris", K=6, A=1, lr=0.1, ldp=ldp),
                  x0, loss_fn)
    run_f = FLRun(FLConfig(method="fedavg_ldp", K=6, A=1, lr=0.1, ldp=ldp),
                  x0, loss_fn)
    for _ in range(4):
        run_e.step(batches)
        run_f.step(batches)
    np.testing.assert_allclose(np.asarray(run_e.x), np.asarray(run_f.x),
                               atol=1e-5)


def test_failure_views_zeroed_and_training_continues():
    """none+agg_fail with captured views: the adversary tap shows whole
    rows zeroed (dead links / dead aggregators) and the run still
    optimizes."""
    x0, loss_fn, batches = quad_problem(KEY, K=6, n=40)
    cell = sc.get("none+agg_fail")
    run = FLRun(cell.fl_config(K=6, A=4, rounds=8, lr=0.3,
                               keep_views=True), x0, loss_fn)
    stacked = jax.tree.map(lambda b: jnp.stack([b] * 8), batches)
    xs, views = run.run_scanned(stacked, collect_views=True)
    v = np.asarray(views)
    assert v.shape == (8, 4, 6, 40)
    row_max = np.abs(v).max(axis=-1)            # (T, A, K)
    assert (row_max == 0.0).any()               # some links/aggs died
    assert (row_max > 0.0).any()                # but not all
    loss0 = float(loss_fn(run.unravel(jnp.zeros_like(run.x)),
                          jax.tree.map(lambda b: b[0], batches)))
    lossT = float(loss_fn(run.unravel(xs[-1]),
                          jax.tree.map(lambda b: b[0], batches)))
    assert lossT < loss0


# ------------------------------------------------------- RDP accountant
def test_accountant_eps_monotone_in_rounds():
    accs = [acct.ldp_cumulative_epsilon(sc.SCENARIO_LDP, T)["eps"]
            for T in (1, 5, 20, 80)]
    assert all(a < b for a, b in zip(accs, accs[1:])), accs
    assert np.isfinite(accs[-1])


def test_accountant_eps_decreasing_in_noise():
    a = acct.RDPAccountant()
    a.step(noise_multiplier=0.6, q=1.0, steps=10)
    b = acct.RDPAccountant()
    b.step(noise_multiplier=2.0, q=1.0, steps=10)
    assert b.epsilon(1e-5) < a.epsilon(1e-5)


def test_accountant_subsampling_amplifies():
    full = acct.ldp_cumulative_epsilon(sc.SCENARIO_LDP, 20, q=1.0)["eps"]
    sub = acct.ldp_cumulative_epsilon(sc.SCENARIO_LDP, 20, q=0.75)["eps"]
    assert sub < full


def test_accountant_none_for_noiseless():
    assert acct.ldp_cumulative_epsilon(None, 20) is None


def test_rdp_gaussian_known_value():
    # alpha/(2 z^2): the Renyi divergence of N(0,z^2) vs N(1,z^2)
    assert acct.rdp_gaussian(8, 2.0) == pytest.approx(1.0)


# -------------------------------------------------- tier-1 quick smoke
@pytest.mark.parametrize("name", ["none+none", "ldp+none",
                                  "secure_agg+none", "int8+agg_fail",
                                  "int8+client_drop"])
def test_scenario_smoke_step_scan_parity(name):
    """Representative matrix cells: 3 rounds, the step engine and the
    scan engine land on the same iterate (one round implementation)."""
    x0, loss_fn, batches = quad_problem(KEY, K=6, n=30)
    cell = sc.get(name)
    cfg = cell.fl_config(K=6, A=4, rounds=3, lr=0.2)
    run_step = FLRun(cfg, x0, loss_fn)
    for _ in range(3):
        run_step.step(batches)
    run_scan = FLRun(cfg, x0, loss_fn)
    stacked = jax.tree.map(lambda b: jnp.stack([b] * 3), batches)
    xs = run_scan.run_scanned(stacked)
    np.testing.assert_allclose(np.asarray(run_step.x), np.asarray(xs[-1]),
                               rtol=1e-5, atol=1e-5)
    assert np.isfinite(np.asarray(xs)).all()
