"""Test-suite bootstrap.

``hypothesis`` is a dev-only dependency (see requirements-dev.txt).  When
it is absent the property-based modules must still collect and run, so
this conftest installs a minimal *deterministic-examples* shim before
collection: ``@given`` re-runs the test over a fixed pseudo-random sweep
of ``max_examples`` draws (seeded per example index), which preserves the
property-test coverage — just without shrinking or example databases.
"""
from __future__ import annotations

import sys
import types

# Environment for the multi-device subprocess tests: hermetic, but with
# the backend pinned to CPU — images that bake in libtpu otherwise burn
# ~8 minutes per subprocess timing out on TPU discovery before falling
# back (the host-platform device count only applies to the CPU backend).
SUBPROC_ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
               "JAX_PLATFORMS": "cpu"}


def _install_hypothesis_shim():
    import numpy as np

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.randint(min_value, max_value + 1)))

    def floats(min_value, max_value, **kw):
        return _Strategy(
            lambda rng: float(rng.uniform(min_value, max_value)))

    def sampled_from(elements):
        seq = list(elements)
        return _Strategy(lambda rng: seq[rng.randint(len(seq))])

    def booleans():
        return _Strategy(lambda rng: bool(rng.randint(2)))

    def settings(max_examples=10, deadline=None, **kw):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            def runner():
                n = getattr(runner, "_shim_max_examples", 10)
                for i in range(n):
                    rng = np.random.RandomState(0x5EED + 7919 * i)
                    fn(**{name: s.draw(rng)
                          for name, s in strategies.items()})
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            runner._hypothesis_shim = True
            return runner
        return deco

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.strategies = types.ModuleType("hypothesis.strategies")
    mod.strategies.integers = integers
    mod.strategies.floats = floats
    mod.strategies.sampled_from = sampled_from
    mod.strategies.booleans = booleans
    mod.__shim__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = mod.strategies


try:  # pragma: no cover - prefer the real thing when installed
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_shim()
