"""Stage-pipeline refactor safety net.

1. Per-method parity: every ``FLConfig.method`` trajectory through the
   declarative RoundPipeline matches a frozen copy of the pre-refactor
   monolithic round (the seed engine's if/elif chain, reproduced verbatim
   below as ``SeedReference``) — allclose over 20 rounds, including
   adversary views.
2. Driver parity: the scan-compiled multi-round driver produces the same
   trajectory as the per-round step driver.
3. Kernel-backed stages (pallas DSC / int8 wire) run and train.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines as bl
from repro.core import dsc as dsc_lib
from repro.core import error_feedback as ef_lib
from repro.core import fsa as fsa_lib
from repro.core import masks as masks_lib
from repro.core import secure_agg as sa_lib
from repro.core import server_opt as so_lib
from repro.core.compressors import QSGD, RandP, TopK
from repro.core.fl import FLConfig, FLRun, run_fl, run_fl_scan
from repro.data import federated_classification

KEY = jax.random.PRNGKey(0)
DIM, CLASSES, K, S = 8, 3, 6, 32


def init_mlp(key, dim=DIM, hidden=16, classes=CLASSES):
    k1, k2 = jax.random.split(key)
    return {"w1": 0.3 * jax.random.normal(k1, (dim, hidden)),
            "b1": jnp.zeros(hidden),
            "w2": 0.3 * jax.random.normal(k2, (hidden, classes)),
            "b2": jnp.zeros(classes)}


def loss_fn(params, batch):
    x, y = batch
    h = jnp.tanh(x @ params["w1"] + params["b1"])
    logits = h @ params["w2"] + params["b2"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, y[:, None], 1).mean()


@pytest.fixture(scope="module")
def data():
    return federated_classification(KEY, K, S, dim=DIM, n_classes=CLASSES)


class SeedReference:
    """Frozen copy of the pre-pipeline ``FLRun`` round (the monolithic
    if/elif engine this PR deleted).  DO NOT refactor this class to use
    the pipeline — its whole point is to be the independent oracle."""

    def __init__(self, cfg: FLConfig, params0, loss_fn):
        from jax.flatten_util import ravel_pytree
        self.cfg = cfg
        flat0, self.unravel = ravel_pytree(params0)
        self.n = flat0.shape[0]
        self.x = flat0
        self.key = jax.random.PRNGKey(cfg.seed)
        self._grad = jax.grad(lambda x, b: loss_fn(self.unravel(x), b))
        self.dsc = dsc_lib.init_state(cfg.K, self.n)
        self.ef = ef_lib.init_state(cfg.K, self.n)
        self.server = so_lib.get_server_opt(cfg.server_opt, cfg.lr)
        self.server_state = self.server.init(flat0)
        self._round = jax.jit(self._round_impl)

    def _round_impl(self, key, x, dsc, ef, server_state, batches):
        cfg = self.cfg
        grads = jax.vmap(lambda b: self._grad(x, b))(batches)
        k_m, k_c, k_n, k_f, k_p = jax.random.split(key, 5)
        views = None
        ef_new = ef
        if cfg.participation < 1.0:
            # mirrors pipeline.participation_weights' key split (the draw
            # and the forced index consume distinct sub-keys)
            k_draw, k_force = jax.random.split(k_p)
            part = jax.random.bernoulli(k_draw, cfg.participation, (cfg.K,))
            part = part.at[
                jax.random.randint(k_force, (), 0, cfg.K)].set(True)
            weights = part.astype(jnp.float32)
        else:
            weights = None
        if cfg.method in ("fedavg", "min_leakage"):
            x_new, dsc_new = bl.fedavg_round(x, grads, cfg.lr,
                                             weights=weights), dsc
            views = grads if cfg.method == "fedavg" else None
        elif cfg.method == "secure_agg":
            x_new, views = sa_lib.secure_agg_round(k_c, x, grads, cfg.lr)
            dsc_new = dsc
        elif cfg.method == "fedavg_ldp":
            noised = bl.ldp_perturb(k_n, grads, cfg.ldp or bl.LDPConfig())
            x_new, dsc_new, views = bl.fedavg_round(x, noised, cfg.lr), dsc, \
                noised
        elif cfg.method == "soteriafl":
            gamma = cfg.gamma if cfg.gamma is not None else \
                dsc_lib.gamma_star(cfg.compressor.omega(self.n))
            x_new, st = bl.soteriafl_round(
                k_c, x, grads, cfg.lr, bl.SoteriaState(dsc),
                cfg.compressor, gamma, cfg.ldp)
            dsc_new, views = st.dsc, None
        elif cfg.method == "priprune":
            x_new, dsc_new = bl.priprune_round(x, grads, cfg.lr,
                                               cfg.prune_rate), dsc
        elif cfg.method == "shatter":
            x_new, dsc_new = bl.shatter_round(
                k_c, x, grads, cfg.lr, cfg.shatter_chunks, cfg.shatter_r), dsc
        elif cfg.method == "eris":
            gamma = cfg.gamma if cfg.gamma is not None else (
                dsc_lib.gamma_star(cfg.compressor.omega(self.n))
                if cfg.use_dsc else 0.0)
            if cfg.use_dsc:
                v, s_clients = dsc_lib.client_compress(
                    dsc, grads, cfg.compressor, gamma, k_c)
            elif cfg.use_ef:
                v, ef_new = ef_lib.client_compress(ef, grads,
                                                   cfg.compressor, k_c)
                s_clients = dsc.s_clients
            else:
                v, s_clients = grads, dsc.s_clients
            assign = masks_lib.make_assignment(self.n, cfg.A, cfg.mask_scheme)
            if cfg.agg_dropout > 0 or cfg.link_failure > 0:
                ka, kl = jax.random.split(k_f)
                agg_alive = jax.random.bernoulli(
                    ka, 1.0 - cfg.agg_dropout, (cfg.A,))
                link_alive = jax.random.bernoulli(
                    kl, 1.0 - cfg.link_failure, (cfg.K, cfg.A))
                x_acc = fsa_lib.fsa_round_with_failures(
                    jnp.zeros(self.n), v, assign, cfg.A, 1.0,
                    agg_alive, link_alive)
                mean_v = -x_acc
                v_global = (dsc.s_agg + mean_v) if cfg.use_dsc else mean_v
                s_agg = dsc.s_agg + gamma * mean_v if cfg.use_dsc \
                    else dsc.s_agg
            else:
                v_global, s_agg = dsc_lib.aggregate(
                    dsc if cfg.use_dsc else dsc._replace(
                        s_agg=jnp.zeros_like(dsc.s_agg)), v, gamma,
                    weights=weights)
                if not cfg.use_dsc:
                    s_agg = dsc.s_agg
            if cfg.server_opt != "fedavg":
                delta, server_state = self.server.update(v_global,
                                                         server_state)
                x_new = x + delta
            else:
                x_new = x - cfg.lr * v_global
            dsc_new = dsc_lib.DSCState(s_clients, s_agg)
            views = v
        else:
            raise ValueError(cfg.method)
        return x_new, dsc_new, ef_new, server_state, views

    def step(self, batches):
        self.key, sub = jax.random.split(self.key)
        x, dsc, ef, sstate, views = self._round(
            sub, self.x, self.dsc, self.ef, self.server_state, batches)
        self.x, self.dsc, self.ef, self.server_state = x, dsc, ef, sstate
        return views


CASES = [
    ("fedavg", {}),
    ("min_leakage", {}),
    ("secure_agg", {}),
    ("fedavg_ldp", {"ldp": bl.LDPConfig(eps=10.0, clip=5.0)}),
    ("soteriafl", {"compressor": RandP(p=0.3)}),
    ("soteriafl", {"compressor": RandP(p=0.3),
                   "ldp": bl.LDPConfig(eps=10.0, clip=5.0)}),
    ("priprune", {"prune_rate": 0.05}),
    ("shatter", {"shatter_chunks": 4, "shatter_r": 3}),
    ("eris", {"A": 4}),
    ("eris", {"A": 4, "use_dsc": True, "compressor": RandP(p=0.3)}),
    ("eris", {"A": 4, "use_dsc": True, "compressor": QSGD(s=8),
              "participation": 0.5}),
    ("eris", {"A": 4, "use_ef": True, "compressor": TopK(k=16)}),
    ("eris", {"A": 8, "agg_dropout": 0.3, "link_failure": 0.2, "seed": 3}),
    ("eris", {"A": 8, "agg_dropout": 0.3, "use_dsc": True,
              "compressor": RandP(p=0.5), "seed": 3}),
    ("eris", {"A": 4, "server_opt": "fedadam", "lr": 0.05}),
    ("eris", {"A": 4, "server_opt": "fedyogi", "lr": 0.05}),
    ("eris", {"A": 4, "participation": 0.5}),
]


@pytest.mark.parametrize("method,kw", CASES)
def test_pipeline_matches_seed_engine(data, method, kw):
    """Trajectory + adversary-view parity of the declarative pipeline vs
    the frozen monolithic round, 20 rounds."""
    kwargs = dict(method=method, K=K, rounds=20, lr=0.3)
    kwargs.update(kw)
    cfg = FLConfig(**kwargs)
    new = FLRun(cfg, init_mlp(KEY), loss_fn)
    ref = SeedReference(cfg, init_mlp(KEY), loss_fn)
    for t in range(cfg.rounds):
        v_new = new.step(data, collect_views=True)
        v_ref = ref.step(data)
        np.testing.assert_allclose(np.asarray(new.x), np.asarray(ref.x),
                                   atol=1e-6, err_msg=f"{method} round {t}")
        assert (v_new is None) == (v_ref is None), (method, t)
        if v_new is not None:
            np.testing.assert_allclose(np.asarray(v_new), np.asarray(v_ref),
                                       atol=1e-6, err_msg=f"views {method}")


@pytest.mark.parametrize("method,kw", [
    ("fedavg", {}),
    ("eris", {"A": 4, "use_dsc": True, "compressor": RandP(p=0.3)}),
    ("eris", {"A": 4, "participation": 0.5}),
    ("soteriafl", {"compressor": RandP(p=0.3)}),
])
def test_scan_driver_matches_step_driver(data, method, kw):
    """The scan-compiled T-round program is trajectory-identical to T
    per-round jitted steps."""
    full = (data[0].reshape(-1, DIM), data[1].reshape(-1))
    cfg = FLConfig(method=method, K=K, rounds=25, lr=0.3, **kw)
    batches = lambda t, k: data
    r_step, l_step = run_fl(cfg, init_mlp(KEY), loss_fn, batches,
                            eval_batch=full)
    r_scan, l_scan = run_fl_scan(cfg, init_mlp(KEY), loss_fn, batches,
                                 eval_batch=full)
    np.testing.assert_allclose(np.asarray(r_step.x), np.asarray(r_scan.x),
                               atol=1e-6)
    assert [t for t, _ in l_step] == [t for t, _ in l_scan]
    np.testing.assert_allclose([l for _, l in l_step],
                               [l for _, l in l_scan], atol=1e-5)


def test_pallas_dsc_stage_trains(data):
    """FLConfig(compress_impl='pallas') routes client compression through
    the fused kernels/dsc_update Pallas kernel (interpret mode on CPU)."""
    full = (data[0].reshape(-1, DIM), data[1].reshape(-1))
    cfg = FLConfig(method="eris", K=K, A=4, rounds=30, lr=0.3,
                   use_dsc=True, compressor=RandP(p=0.3),
                   compress_impl="pallas")
    run, losses = run_fl(cfg, init_mlp(KEY), loss_fn, lambda t, k: data,
                         eval_batch=full)
    assert losses[-1][1] < losses[0][1]
    # the shifted references actually moved (the kernel's s' output is used)
    assert float(jnp.abs(run.dsc.s_clients).max()) > 0


def test_int8_wire_stage_trains(data):
    """The Pallas int8 quantize->dequantize wire stage composes with the
    FSA aggregate and still trains (unbiased omega-compressor)."""
    full = (data[0].reshape(-1, DIM), data[1].reshape(-1))
    cfg = FLConfig(method="eris", K=K, A=4, rounds=30, lr=0.3,
                   int8_wire=True)
    run, losses = run_fl(cfg, init_mlp(KEY), loss_fn, lambda t, k: data,
                         eval_batch=full)
    assert losses[-1][1] < losses[0][1]


def test_int8_wire_composes_with_dsc(data):
    """With DSC + int8 wire, the wire round-trip must sit INSIDE the
    shifted compressor so client references update with exactly what the
    aggregators received — the Eq. 4 invariant s_agg == mean_k s_k then
    holds exactly (it random-walks apart if quantization is applied after
    the s_k update)."""
    full = (data[0].reshape(-1, DIM), data[1].reshape(-1))
    cfg = FLConfig(method="eris", K=K, A=4, rounds=40, lr=0.3,
                   use_dsc=True, compressor=RandP(p=0.3), int8_wire=True)
    run, losses = run_fl(cfg, init_mlp(KEY), loss_fn, lambda t, k: data,
                         eval_batch=full)
    assert losses[-1][1] < losses[0][1]
    np.testing.assert_allclose(np.asarray(run.dsc.s_agg),
                               np.asarray(run.dsc.s_clients.mean(0)),
                               atol=1e-5)


def test_fused_wire_stage_trains_and_scan_matches_step(data):
    """compress_impl='fused' (the one-pass kernels/dsc_quantize wire
    kernel, interpret mode on CPU): trains, preserves the Eq. 4
    invariant s_agg == mean_k s_k (the shift updates with exactly the
    dequantized wire value, in-register), and the scan-compiled driver
    is trajectory-identical to the step driver through it.  No bit
    parity with compress_impl='jnp' is asserted: the kernel's
    counter-based RNG and the composed Int8RoundTrip's threefry draws
    are different (equally unbiased) sample paths."""
    full = (data[0].reshape(-1, DIM), data[1].reshape(-1))
    kw = dict(method="eris", K=K, A=4, rounds=30, lr=0.3,
              use_dsc=True, compressor=RandP(p=0.3), int8_wire=True,
              compress_impl="fused")
    batches = lambda t, k: data
    r_fus, l_fus = run_fl(FLConfig(**kw), init_mlp(KEY), loss_fn, batches,
                          eval_batch=full)
    assert l_fus[-1][1] < l_fus[0][1]
    np.testing.assert_allclose(np.asarray(r_fus.dsc.s_agg),
                               np.asarray(r_fus.dsc.s_clients.mean(0)),
                               atol=1e-5)
    r_scan, l_scan = run_fl_scan(FLConfig(**kw), init_mlp(KEY), loss_fn,
                                 batches, eval_batch=full)
    np.testing.assert_allclose(np.asarray(r_scan.x), np.asarray(r_fus.x),
                               atol=1e-6)
    np.testing.assert_allclose([l for _, l in l_scan],
                               [l for _, l in l_fus], atol=1e-5)


def test_fsa_sharded_stage_matches_mean(data):
    """FSASharded (literal Algorithm 1) == AggregateStage mean
    (Theorem B.1) at stage granularity."""
    from repro.core.pipeline import AggregateStage, FSASharded, \
        split_round_keys
    v = jax.random.normal(KEY, (K, 40))
    keys = split_round_keys(KEY)
    mean = AggregateStage().mean(v, None)
    sharded = FSASharded(A=5).apply(keys, None, v, None)
    np.testing.assert_allclose(np.asarray(sharded.update), np.asarray(mean),
                               atol=1e-6)
    assert sharded.views.shape == (5, K, 40)


def test_unknown_method_raises():
    from repro.core import rounds as rounds_lib
    with pytest.raises(ValueError):
        rounds_lib.build_round(FLConfig(method="nope"), 8)
