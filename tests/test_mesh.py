"""make_host_mesh factorization validation (ISSUE: it used to silently
build a wrong-sized mesh when model didn't divide the device count)."""
import jax
import pytest

from repro.launch.mesh import make_host_mesh


def test_default_mesh_uses_all_devices():
    mesh = make_host_mesh()
    assert mesh.axis_names == ("data", "model")
    assert int(mesh.devices.size) == len(jax.devices())


def test_model_axis_must_divide_device_count():
    n = len(jax.devices())
    with pytest.raises(ValueError, match="model axis size"):
        make_host_mesh(model=n + 1)
    with pytest.raises(ValueError, match="model axis size"):
        make_host_mesh(model=0)


def test_explicit_data_axis_must_factorize():
    n = len(jax.devices())
    with pytest.raises(ValueError, match="devices"):
        make_host_mesh(data=n + 1, model=1)
    # valid factorization still works
    mesh = make_host_mesh(data=n, model=1)
    assert int(mesh.devices.size) == n
