"""Server optimizers, error feedback, secure aggregation, partial
participation — the paper's Sec. 5 'Benefits' + baselines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import error_feedback as ef_lib
from repro.core import secure_agg as sa_lib
from repro.core import server_opt as so_lib
from repro.core import masks as masks_lib
from repro.core.compressors import TopK
from repro.core.fl import FLConfig, run_fl
from repro.data import federated_classification

KEY = jax.random.PRNGKey(0)


def _problem():
    x, y = federated_classification(KEY, 6, 16, dim=8, n_classes=3)

    def init(key):
        k1, k2 = jax.random.split(key)
        return {"w1": 0.3 * jax.random.normal(k1, (8, 16)),
                "b1": jnp.zeros(16),
                "w2": 0.3 * jax.random.normal(k2, (16, 3)),
                "b2": jnp.zeros(3)}

    def loss_fn(p, batch):
        xx, yy = batch
        h = jnp.tanh(xx @ p["w1"] + p["b1"])
        return -jnp.take_along_axis(jax.nn.log_softmax(h @ p["w2"] + p["b2"]),
                                    yy[:, None], 1).mean()
    return (x, y), init, loss_fn


# ------------------------------------------------ server opt equivalence
@pytest.mark.parametrize("name", ["fedadam", "fedyogi"])
def test_server_opt_segment_wise_equals_centralized(name):
    """FSA property for adaptive server optimizers: running the optimizer
    per disjoint segment == centralized (they're coordinate-wise)."""
    n, A, T = 64, 4, 15
    opt_c = so_lib.get_server_opt(name, 0.1)
    opt_s = so_lib.get_server_opt(name, 0.1)
    assign = masks_lib.make_assignment(n, A, "strided")
    m = masks_lib.masks_stacked(assign, A)
    x = jax.random.normal(KEY, (n,))
    s_c = opt_c.init(x)
    s_s = [opt_s.init(x) for _ in range(A)]
    x_c = x_s = x
    for t in range(T):
        v = jax.random.normal(jax.random.fold_in(KEY, t), (n,))
        d_c, s_c = opt_c.update(v, s_c)
        x_c = x_c + d_c
        segs = []
        for a in range(A):
            d_a, s_s[a] = opt_s.update(v * m[a], s_s[a])
            segs.append(d_a * m[a])
        x_s = x_s + sum(segs)
        np.testing.assert_allclose(np.asarray(x_s), np.asarray(x_c),
                                   atol=1e-5)


@pytest.mark.parametrize("server", ["fedadam", "fedyogi"])
def test_eris_with_adaptive_server_trains(server):
    data, init, loss_fn = _problem()
    cfg = FLConfig(method="eris", K=6, A=4, rounds=60, lr=0.05,
                   server_opt=server)
    run, losses = run_fl(cfg, init(KEY), loss_fn, lambda t, k: data,
                         eval_batch=(data[0].reshape(-1, 8),
                                     data[1].reshape(-1)))
    assert losses[-1][1] < losses[0][1]


def test_fednova_scale():
    taus = jnp.array([1, 2, 4])
    np.testing.assert_allclose(np.asarray(so_lib.fednova_scale(taus)),
                               [1.0, 0.5, 0.25])


# --------------------------------------------------------- error feedback
def test_ef_accumulates_residual_and_is_lossless_over_time():
    """EF transmits everything eventually: sum_t v_t ~ sum_t g_t."""
    K, n, T = 2, 64, 60
    comp = TopK(k=4)                  # heavily biased
    state = ef_lib.init_state(K, n)
    g = jax.random.normal(KEY, (K, n))   # constant gradient field
    sent = jnp.zeros((K, n))
    for t in range(T):
        v, state = ef_lib.client_compress(state, g,
                                          comp, jax.random.fold_in(KEY, t))
        sent = sent + v
    avg_sent = sent / T
    err = float(jnp.abs(avg_sent - g).max() / jnp.abs(g).max())
    assert err < 0.25     # residual memory keeps long-run average unbiased


def test_eris_ef_topk_converges_where_plain_topk_stalls():
    data, init, loss_fn = _problem()
    full = (data[0].reshape(-1, 8), data[1].reshape(-1))
    final = {}
    for use_ef in (True, False):
        comp = TopK(k=8)              # ~2% of coordinates
        cfg = FLConfig(method="eris", K=6, A=4, rounds=150, lr=0.3,
                       use_ef=use_ef, use_dsc=False, compressor=comp,
                       seed=3)
        run, losses = run_fl(cfg, init(KEY), loss_fn, lambda t, k: data,
                             eval_batch=full)
        final[use_ef] = losses[-1][1]
    assert final[True] < final[False] * 1.05   # EF at least as good
    assert final[True] < 0.5                   # and actually converges


# ------------------------------------------------------ secure aggregation
def test_pairwise_masks_cancel_exactly():
    K, n = 5, 128
    masks = sa_lib.pairwise_masks(KEY, K, n)
    np.testing.assert_allclose(np.asarray(masks.sum(0)), np.zeros(n),
                               atol=1e-4)
    # each individual mask is large (hides the update)
    assert float(jnp.abs(masks).mean()) > 0.5


def test_secure_agg_equals_fedavg_but_masks_views():
    from repro.core import baselines
    K, n = 4, 64
    x = jax.random.normal(KEY, (n,))
    g = jax.random.normal(jax.random.fold_in(KEY, 1), (K, n))
    x_new, views = sa_lib.secure_agg_round(KEY, x, g, 0.1)
    ref = baselines.fedavg_round(x, g, 0.1)
    np.testing.assert_allclose(np.asarray(x_new), np.asarray(ref),
                               atol=1e-4)
    # views are decorrelated from the true updates
    corr = float(jnp.abs(jnp.vdot(views[0], g[0])) /
                 (jnp.linalg.norm(views[0]) * jnp.linalg.norm(g[0])))
    assert corr < 0.5


# --------------------------------------------------- partial participation
def test_partial_participation_trains():
    data, init, loss_fn = _problem()
    cfg = FLConfig(method="eris", K=6, A=4, rounds=100, lr=0.3,
                   participation=0.5, seed=5)
    run, losses = run_fl(cfg, init(KEY), loss_fn, lambda t, k: data,
                         eval_batch=(data[0].reshape(-1, 8),
                                     data[1].reshape(-1)))
    assert losses[-1][1] < losses[0][1]
