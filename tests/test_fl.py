"""End-to-end FL engine: all methods train a real (tiny MLP) model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines as bl
from repro.core.compressors import RandP
from repro.core.fl import FLConfig, run_fl
from repro.data import federated_classification

KEY = jax.random.PRNGKey(0)
DIM, CLASSES, K, S = 8, 3, 6, 32


def init_mlp(key, dim=DIM, hidden=16, classes=CLASSES):
    k1, k2 = jax.random.split(key)
    return {"w1": 0.3 * jax.random.normal(k1, (dim, hidden)),
            "b1": jnp.zeros(hidden),
            "w2": 0.3 * jax.random.normal(k2, (hidden, classes)),
            "b2": jnp.zeros(classes)}


def loss_fn(params, batch):
    x, y = batch
    h = jnp.tanh(x @ params["w1"] + params["b1"])
    logits = h @ params["w2"] + params["b2"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, y[:, None], 1).mean()


def accuracy(params, batch):
    x, y = batch
    h = jnp.tanh(x @ params["w1"] + params["b1"])
    return float((jnp.argmax(h @ params["w2"] + params["b2"], -1) == y).mean())


@pytest.fixture(scope="module")
def data():
    x, y = federated_classification(KEY, K, S, dim=DIM, n_classes=CLASSES)
    return x, y


def batches_fn(data):
    x, y = data
    return lambda t, key: (x, y)   # full local batches (unbiased estimator)


@pytest.mark.parametrize("method,kw", [
    ("fedavg", {}),
    ("eris", {"A": 4}),
    ("eris", {"A": 4, "use_dsc": True, "compressor": RandP(p=0.3)}),
    ("fedavg_ldp", {"ldp": bl.LDPConfig(eps=10.0, clip=5.0)}),
    ("soteriafl", {"compressor": RandP(p=0.3)}),
    ("priprune", {"prune_rate": 0.05}),
    ("shatter", {"shatter_chunks": 4, "shatter_r": 3}),
    ("min_leakage", {}),
])
def test_method_trains(data, method, kw):
    cfg = FLConfig(method=method, K=K, rounds=60, lr=0.3, **kw)
    run, losses = run_fl(cfg, init_mlp(KEY), loss_fn, batches_fn(data),
                         eval_batch=(data[0].reshape(-1, DIM),
                                     data[1].reshape(-1)))
    first, last = losses[0][1], losses[-1][1]
    assert np.isfinite(last)
    if method not in ("fedavg_ldp",):   # heavy DP noise may stall (paper Tab.1)
        assert last < first, (method, first, last)


def test_eris_matches_fedavg_accuracy(data):
    """Table 1 headline: ERIS reaches FedAvg-level utility."""
    full = (data[0].reshape(-1, DIM), data[1].reshape(-1))
    accs = {}
    for method, kw in [("fedavg", {}), ("eris", {"A": 4})]:
        cfg = FLConfig(method=method, K=K, rounds=120, lr=0.3, seed=7, **kw)
        run, _ = run_fl(cfg, init_mlp(KEY), loss_fn, batches_fn(data))
        accs[method] = accuracy(run.params(), full)
    assert abs(accs["eris"] - accs["fedavg"]) < 1e-3   # identical trajectories
    assert accs["fedavg"] > 0.6


def test_eris_with_failures_still_trains(data):
    cfg = FLConfig(method="eris", K=K, A=8, rounds=80, lr=0.3,
                   agg_dropout=0.3, link_failure=0.2, seed=3)
    run, losses = run_fl(cfg, init_mlp(KEY), loss_fn, batches_fn(data),
                         eval_batch=(data[0].reshape(-1, DIM),
                                     data[1].reshape(-1)))
    assert losses[-1][1] < losses[0][1]


def test_noniid_partition_trains(data):
    x, y = federated_classification(jax.random.PRNGKey(5), K, S, dim=DIM,
                                    n_classes=CLASSES, alpha=0.2)
    cfg = FLConfig(method="eris", K=K, A=4, rounds=80, lr=0.2)
    run, losses = run_fl(cfg, init_mlp(KEY), loss_fn,
                         lambda t, k: (x, y),
                         eval_batch=(x.reshape(-1, DIM), y.reshape(-1)))
    assert losses[-1][1] < losses[0][1]
