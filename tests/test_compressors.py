"""Property tests for omega-compressors (Definition 3.1): unbiasedness and
variance bound, checked by Monte-Carlo over many keys."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.compressors import QSGD, RandK, RandP, TopK, Identity, get_compressor


def mc_moments(comp, x, n_trials=400, seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), n_trials)
    ys = jax.vmap(lambda k: comp(k, x))(keys)
    mean = ys.mean(0)
    mse = ((ys - x[None]) ** 2).sum(-1).mean()
    return np.asarray(mean), float(mse)


@pytest.mark.parametrize("comp", [RandP(p=0.25), RandP(p=0.7),
                                  RandK(k=16), QSGD(s=4), QSGD(s=16),
                                  Identity()])
def test_unbiased_and_variance_bound(comp):
    n = 64
    x = jax.random.normal(jax.random.PRNGKey(42), (n,))
    mean, mse = mc_moments(comp, x)
    norm2 = float(jnp.sum(x * x))
    # unbiasedness: MC mean within 5 sigma of x
    np.testing.assert_allclose(mean, np.asarray(x),
                               atol=5 * np.sqrt(comp.omega(n) + 1) *
                               np.abs(np.asarray(x)).max() / np.sqrt(400) + 1e-6)
    # variance bound E||C(x)-x||^2 <= omega ||x||^2 (with MC slack)
    assert mse <= (comp.omega(n) + 1e-9) * norm2 * 1.25 + 1e-9


@settings(max_examples=20, deadline=None)
@given(p=st.floats(0.05, 0.95), seed=st.integers(0, 1000))
def test_randp_retention(p, seed):
    n = 512
    comp = RandP(p=p)
    x = jnp.ones(n)
    y = comp(jax.random.PRNGKey(seed), x)
    frac = float((y != 0).mean())
    assert abs(frac - p) < 0.15
    # surviving coordinates are scaled by exactly 1/p
    nz = np.asarray(y)[np.asarray(y) != 0]
    np.testing.assert_allclose(nz, 1.0 / p, rtol=1e-5)


def test_randk_exact_k():
    comp = RandK(k=20)
    y = comp(jax.random.PRNGKey(0), jnp.ones(256))
    assert int((y != 0).sum()) == 20


def test_qsgd_levels():
    comp = QSGD(s=4)
    x = jax.random.normal(jax.random.PRNGKey(3), (128,))
    y = comp(jax.random.PRNGKey(4), x)
    norm = float(jnp.linalg.norm(x))
    levels = np.abs(np.asarray(y)) / norm * 4
    np.testing.assert_allclose(levels, np.round(levels), atol=1e-4)


def test_topk_is_biased_but_sparse():
    comp = TopK(k=8)
    x = jax.random.normal(jax.random.PRNGKey(5), (64,))
    y = comp(jax.random.PRNGKey(6), x)
    assert int((y != 0).sum()) == 8
    assert not comp.unbiased
    # keeps the largest magnitudes
    kept = np.abs(np.asarray(y))[np.asarray(y) != 0].min()
    dropped = np.abs(np.asarray(x))[np.asarray(y) == 0].max()
    assert kept >= dropped - 1e-6


def test_zero_vector_safe():
    for comp in [RandP(p=0.3), RandK(k=4), QSGD(s=8), TopK(k=4)]:
        y = comp(jax.random.PRNGKey(0), jnp.zeros(32))
        assert not bool(jnp.any(jnp.isnan(y)))
        np.testing.assert_array_equal(np.asarray(y), np.zeros(32))


def test_registry():
    assert get_compressor("rand_p", p=0.5).p == 0.5
    assert get_compressor("qsgd", s=8).s == 8
    assert get_compressor("identity").omega(10) == 0.0
    with pytest.raises(ValueError):
        get_compressor("bogus")
