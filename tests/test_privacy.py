"""Privacy machinery: MI bound algebra, MIA audit discrimination, DLG."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import privacy
from repro.core.fl import FLConfig, FLRun
from repro.core import masks as masks_lib
from repro.data import federated_classification

KEY = jax.random.PRNGKey(0)


def test_mi_bound_scaling():
    base = privacy.mi_bound(n=1000, T=10, p=1.0, A=1)
    assert privacy.mi_bound(1000, 10, 1.0, 4) == pytest.approx(base / 4)
    assert privacy.mi_bound(1000, 10, 0.1, 4) == pytest.approx(base / 40)
    # collusion (Cor. D.2): A_c colluders scale leakage back up
    assert privacy.mi_bound(1000, 10, 1.0, 4, a_c=4) == pytest.approx(base)
    assert privacy.gaussian_cmax(0.0) == 0.0
    assert privacy.gaussian_cmax(3.0) == pytest.approx(0.5 * np.log(4.0))


def _linear_model(dim=8, classes=3):
    def init(key):
        k1, k2 = jax.random.split(key)
        return {"w": 0.3 * jax.random.normal(k1, (dim, classes)),
                "b": jnp.zeros(classes)}

    def loss_fn(p, batch):
        xx, yy = batch
        logits = xx @ p["w"] + p["b"]
        return -jnp.take_along_axis(jax.nn.log_softmax(logits),
                                    yy[:, None], 1).mean()
    return init, loss_fn


def _small_problem(K=4, S=8, dim=8, classes=3):
    x, y = federated_classification(KEY, K, S, dim=dim, n_classes=classes)
    init, loss_fn = _linear_model(dim, classes)
    return (x, y), init, loss_fn


def test_mia_audit_separates_members():
    """Full-view adversary (A=1) must discriminate members clearly;
    a small-shard adversary (A=8) must discriminate less."""
    M = 8                                          # members per client
    dim = 32
    init, loss_fn = _linear_model(dim=dim)
    # Steinke-style canaries: out-of-distribution Gaussian inputs with
    # random labels; half are included in training (members, memorized)
    # and half held out.  OOD inputs keep cross-canary gradient overlap
    # ~1/sqrt(dim) so the per-sample signal in the transmitted update
    # dominates (the paper's low-data overfitting regime, Fig. 3).
    x = jax.random.normal(jax.random.fold_in(KEY, 2), (4, 2 * M, dim))
    y_can = jax.random.randint(jax.random.fold_in(KEY, 3), (4, 2 * M), 0, 3)
    x_tr = x[:, :M]
    y_tr = y_can[:, :M]                            # mislabeled members
    aucs = {}
    for A in (1, 8):
        cfg = FLConfig(method="eris", K=4, A=A, rounds=40, lr=0.4, seed=1)
        run = FLRun(cfg, init(KEY), loss_fn)
        xs, views = [], []
        for t in range(cfg.rounds):
            xs.append(run.x)
            v = run.step((x_tr, y_tr), collect_views=True)
            views.append(v[0])                     # client 0 transmissions
        assign = masks_lib.make_assignment(run.n, A, "strided")
        obs = masks_lib.mask_for(assign, 0)        # aggregator 0's view
        grad_fn = jax.grad(lambda xf, c: loss_fn(
            run.unravel(xf), (c[0][None], c[1][None].astype(jnp.int32))))

        def canary_grad(xf, c):
            return grad_fn(xf, (c[:-1], c[-1]))

        members = jnp.concatenate([x[0, :M], y_can[0, :M, None]], axis=1)
        non = jnp.concatenate([x[0, M:], y_can[0, M:, None]], axis=1)
        res = privacy.mia_audit(KEY, canary_grad, jnp.stack(xs),
                                jnp.stack(views) * obs, obs, members, non)
        aucs[A] = res["auc"]
    assert aucs[1] > 0.85          # full view: strong attack
    assert aucs[8] <= aucs[1]      # sharded view: weaker or equal


def test_dlg_reconstruction_full_vs_masked():
    """DLG recovers the input from a full gradient far better than from a
    1/8 FSA shard (Fig. 12 trend)."""
    dim, classes = 36, 3
    k1, k2, k3 = jax.random.split(KEY, 3)
    params0 = {"w": 0.5 * jax.random.normal(k1, (dim, classes)),
               "b": jnp.zeros(classes)}
    from jax.flatten_util import ravel_pytree
    x_flat, unravel = ravel_pytree(params0)

    def loss_single(xf, inp, label):
        p = unravel(xf)
        logits = inp @ p["w"] + p["b"]
        return -jax.nn.log_softmax(logits)[label]

    grad_fn = jax.grad(loss_single)
    target = jax.random.normal(k2, (dim,))
    label = jnp.int32(1)
    g_true = grad_fn(x_flat, target, label)
    errs = {}
    for A in (1, 8):
        assign = masks_lib.make_assignment(x_flat.shape[0], A, "strided")
        obs = masks_lib.mask_for(assign, 0)
        out = privacy.dlg_attack(k3, grad_fn, x_flat, g_true * obs, obs,
                                 (dim,), label, steps=400, lr=0.05)
        errs[A] = privacy.reconstruction_mse(out["reconstruction"], target)
    assert errs[1] < 0.5           # near-perfect reconstruction
    assert errs[8] > 2 * errs[1]   # sharding degrades the attack


def test_observed_fraction():
    assert privacy.observed_fraction(1.0, 4) == 0.25
    assert privacy.observed_fraction(0.1, 50) == pytest.approx(0.002)
