"""Three-engine differential harness (the ROADMAP's parity promise).

One round implementation, three engines: the interactive simulator
(``FLRun.step``), the scan-compiled driver (``FLRun.run_scanned`` /
``run_fl_scan``), and the distributed ``shard_map`` runtime
(``launch/train.py``).  These tests sweep method x compressor x
aggregator count x wire format and assert the engines produce the same
trajectories:

  * property-based (fast): step driver vs scan driver must match to
    float tolerance for EVERY FLConfig draw — they execute the identical
    stage list, so any drift is a bug;
  * slow (8 host devices, subprocess): the shard_map runtime follows the
    simulator's trajectory on a smoke transformer for the f32 and int8
    wire formats (tolerance covers independent quantization draws).
"""
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import SUBPROC_ENV
from hypothesis import given, settings, strategies as st

from repro.core.compressors import Identity, RandP
from repro.core.fl import FLConfig, FLRun

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------- problem
def quad_problem(K: int = 4, n: int = 96):
    """Per-client least squares on a tiny pytree model."""
    ka, kb = jax.random.split(KEY)
    a = 1.0 + jax.random.uniform(ka, (K, n))
    b = jax.random.normal(kb, (K, n))

    def loss_fn(params, batch):
        r = batch["a"] * (params["w"] + params["s"].sum()) - batch["b"]
        return 0.5 * jnp.mean(r * r)

    params0 = {"w": jnp.zeros(n), "s": jnp.zeros(4)}
    batches = {"a": a, "b": b}
    return params0, loss_fn, batches


def config_from_draw(method, A, use_dsc, int8_wire, fresh_masks, p,
                     server_opt, participation):
    if method == "secure_agg":
        # pairwise masks cancel only in the unweighted full cohort;
        # SecureAggAggregate (correctly) raises on weighted aggregation
        participation = 1.0
    kw = dict(method=method, K=4, A=A, lr=0.05, participation=participation,
              seed=3)
    if method == "eris":
        kw.update(use_dsc=use_dsc, int8_wire=int8_wire,
                  fresh_masks=fresh_masks, server_opt=server_opt,
                  compressor=RandP(p=p) if use_dsc else Identity())
    elif method == "soteriafl":
        kw.update(compressor=RandP(p=p))
    return FLConfig(**kw)


# ------------------------------------------------- step vs scan (property)
@given(method=st.sampled_from(["fedavg", "eris", "soteriafl", "fedavg_ldp",
                               "priprune", "secure_agg", "shatter"]),
       A=st.sampled_from([1, 2, 4]),
       use_dsc=st.booleans(),
       int8_wire=st.booleans(),
       fresh_masks=st.booleans(),
       p=st.sampled_from([0.3, 1.0]),
       server_opt=st.sampled_from(["fedavg", "fedadam"]),
       participation=st.sampled_from([1.0, 0.75]))
@settings(max_examples=12, deadline=None)
def test_step_and_scan_drivers_match(method, A, use_dsc, int8_wire,
                                     fresh_masks, p, server_opt,
                                     participation):
    cfg = config_from_draw(method, A, use_dsc, int8_wire, fresh_masks, p,
                           server_opt, participation)
    params0, loss_fn, batches = quad_problem(K=cfg.K)
    T = 4

    run_a = FLRun(cfg, params0, loss_fn)
    traj = []
    for _ in range(T):
        run_a.step(batches)
        traj.append(np.asarray(run_a.x))

    run_b = FLRun(cfg, params0, loss_fn)
    stacked = jax.tree.map(lambda x: jnp.stack([x] * T), batches)
    xs = run_b.run_scanned(stacked)

    assert not np.any(np.isnan(traj[-1]))
    np.testing.assert_allclose(np.asarray(xs), np.stack(traj),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(run_b.x), traj[-1],
                               rtol=1e-5, atol=1e-5)


@given(method=st.sampled_from(["fedbuff", "eris_async"]),
       A=st.sampled_from([1, 2]),
       cadence=st.sampled_from([1, 2, 3]),
       population=st.sampled_from([0, 12]),
       delay_max=st.integers(0, 3),
       dropout=st.sampled_from([0.0, 0.5]),
       alpha=st.floats(0.0, 2.0),
       int8_wire=st.booleans())
@settings(max_examples=12, deadline=None)
def test_async_step_and_scan_drivers_match(method, A, cadence, population,
                                           delay_max, dropout, alpha,
                                           int8_wire):
    """ISSUE 7 tentpole contract: the async runtime rides the SAME two
    drivers.  The buffer/arrival state threaded through the scan carry
    must reproduce the stepped trajectory for EVERY knob draw — staleness
    discount, cadence-gated apply, dropout, the int8 wire, and keyed
    cohort sampling over a 12-client population included."""
    cfg = FLConfig(method=method, K=4, A=A, lr=0.05, seed=5,
                   population=population, buffer_cadence=cadence,
                   staleness_alpha=alpha, delay_max=delay_max,
                   client_dropout=dropout, int8_wire=int8_wire)
    # population-scale: batches carry ALL clients; the cohort is drawn
    params0, loss_fn, batches = quad_problem(K=population or cfg.K)
    T = 6

    run_a = FLRun(cfg, params0, loss_fn)
    traj = []
    for _ in range(T):
        run_a.step(batches)
        traj.append(np.asarray(run_a.x))

    run_b = FLRun(cfg, params0, loss_fn)
    stacked = jax.tree.map(lambda x: jnp.stack([x] * T), batches)
    xs = run_b.run_scanned(stacked)

    assert not np.any(np.isnan(traj[-1]))
    np.testing.assert_allclose(np.asarray(xs), np.stack(traj),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(run_b.x), traj[-1],
                               rtol=1e-5, atol=1e-5)


def test_eris_round_step_matches_pipeline_stages():
    """The eris engine's stage list is the registry's: with static masks
    and DSC both compose DSCCompress + the shift-compensated mean, so the
    two engines' single-round updates agree exactly."""
    from repro.core import eris
    n, K = 64, 4
    params0, loss_fn, batches = quad_problem(K=K, n=n)
    # flat quad problem for the eris engine (vector model, same gradients)
    a, b = batches["a"], batches["b"]

    def grad_fn(x, batch):
        aa, bb = batch
        return aa * (aa * x - bb) / n

    cfg_e = eris.ErisConfig(A=2, lr=0.05, use_dsc=True,
                            compressor=RandP(p=0.5), gamma=0.5)
    state = eris.init(KEY, jnp.zeros(n), K)
    state2, aux = eris.round_step(state, cfg_e, grad_fn, (a, b))
    # identical stage math, computed by hand from the stage objects
    from repro.core import pipeline as pl
    key, k_mask, k_comp = jax.random.split(state.key, 3)
    grads = jax.vmap(lambda ba, bb: grad_fn(state.x, (ba, bb)))(a, b)
    stage = pl.DSCCompress(compressor=RandP(p=0.5), gamma=0.5)
    v, dsc = stage.compress(k_comp, state.dsc, grads)
    u, s_agg = (dsc.s_agg + v.mean(0), dsc.s_agg + 0.5 * v.mean(0))
    np.testing.assert_allclose(np.asarray(state2.x),
                               np.asarray(state.x - 0.05 * u),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(state2.dsc.s_agg),
                               np.asarray(s_agg), rtol=1e-6, atol=1e-6)


def test_fresh_mask_path_runs_fsa_sharded():
    """fresh_masks routes aggregation through the literal FSASharded stage
    in BOTH the registry build and the eris engine, with the keyed m^t
    draw, and stays trajectory-consistent with the algebraic mean."""
    from repro.core import eris
    from repro.core.pipeline import FSASharded
    from repro.core.rounds import build_round
    cfg = FLConfig(method="eris", K=4, A=3, fresh_masks=True, lr=0.05)
    pipe = build_round(cfg, 96)
    assert isinstance(pipe.aggregate, FSASharded)
    assert pipe.aggregate.fresh_masks

    params0, loss_fn, batches = quad_problem(K=4)
    run = FLRun(cfg, params0, loss_fn)
    cfg_static = FLConfig(method="eris", K=4, A=3, fresh_masks=False,
                          lr=0.05)
    run_s = FLRun(cfg_static, params0, loss_fn)
    for _ in range(3):
        run.step(batches)
        run_s.step(batches)
    # masks partition coordinates completely, so the sharded aggregate
    # equals the mean no matter the assignment draw (Theorem B.1)
    np.testing.assert_allclose(np.asarray(run.x), np.asarray(run_s.x),
                               rtol=1e-5, atol=1e-5)

    # eris engine: same FSASharded stage, keyed assignment is reproducible
    _, agg = eris.stages(eris.ErisConfig(A=3, fresh_masks=True), 96)
    assert isinstance(agg, FSASharded) and agg.fresh_masks


# ----------------------------------------- distributed engine (subprocess)
PARITY_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.flatten_util import ravel_pytree
    from repro.configs import get_config
    from repro.core.fl import FLConfig, FLRun
    from repro.data import lm_token_batches
    from repro.launch.mesh import make_host_mesh
    from repro.launch.train import TrainSettings, make_train_step
    from repro.models import transformer as tr
    from repro.optim import sgd

    INT8 = %(int8)s
    LR, STEPS = 0.05, 4
    KEY = jax.random.PRNGKey(0)
    cfg = get_config("qwen2-0.5b").smoke()
    toks = lm_token_batches(KEY, 1, 8, 32, cfg.vocab)[0]
    batch = {"tokens": toks}
    params0 = tr.init_params(KEY, cfg)

    # ---- simulator + scan engines: K=4 clients, one per client group ----
    fl_cfg = FLConfig(method="eris", K=4, A=4, lr=LR, int8_wire=INT8,
                      rounds=STEPS)
    loss_fn = lambda p, b: tr.loss_fn(p, cfg, b)
    client_batches = {"tokens": toks.reshape(4, 2, 32)}
    sim = FLRun(fl_cfg, params0, loss_fn)
    for _ in range(STEPS):
        sim.step(client_batches)
    scan = FLRun(fl_cfg, params0, loss_fn)
    stacked = jax.tree.map(lambda x: jnp.stack([x] * STEPS), client_batches)
    scan.run_scanned(stacked)

    # ---- distributed shard_map runtime on a (4, 2) mesh -----------------
    mesh = make_host_mesh(data=4, model=2)
    settings = TrainSettings(grad_dtype="float32", int8_wire=INT8)
    step, shardings = make_train_step(cfg, mesh, sgd(LR), settings)
    with mesh:
        params = jax.device_put(params0, shardings["store"])
        opt_state = sgd(LR).init(params)
        dsc_ref = jax.tree.map(lambda p: jnp.zeros((), jnp.float32), params)
        jstep = jax.jit(step)
        for i in range(STEPS):
            params, opt_state, dsc_ref, m = jstep(
                params, opt_state, dsc_ref, batch, jax.random.PRNGKey(i))
    dist_flat, _ = ravel_pytree(jax.device_get(params))

    out = {
        "sim": np.asarray(sim.x).tolist(),
        "scan": np.asarray(scan.x).tolist(),
        "dist": np.asarray(dist_flat).tolist(),
        "x0": np.asarray(ravel_pytree(params0)[0]).tolist(),
    }
    print("PARITY" + json.dumps(out))
""")


def _run_parity(int8: bool) -> dict:
    r = subprocess.run([sys.executable, "-c", PARITY_SCRIPT % {"int8": int8}],
                       capture_output=True, text=True, timeout=900,
                       env=SUBPROC_ENV)
    assert r.returncode == 0, r.stderr[-2000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("PARITY")][-1]
    return json.loads(line[len("PARITY"):])


VIEW_PARITY_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses as dc
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.flatten_util import ravel_pytree
    from repro.core.compressors import RandP
    from repro.core.fl import FLConfig, FLRun
    from repro.data import lm_token_batches
    from repro.launch.mesh import make_host_mesh
    from repro.launch.train import (TrainSettings, init_dsc_state,
                                    make_train_step)
    from repro.models import transformer as tr
    from repro.optim import sgd
    from repro.privacy import views as pv
    from repro.privacy.harness import tiny_lm_config

    LR, STEPS, A = 0.05, 3, 4
    KEY = jax.random.PRNGKey(0)
    cfg = tiny_lm_config()
    toks = lm_token_batches(KEY, 1, 8, 32, cfg.vocab)[0]
    batch = {"tokens": toks}
    params0 = tr.init_params(KEY, cfg)
    params_abs = jax.eval_shape(lambda k: tr.init_params(k, cfg), KEY)
    # the flat assignment induced by the mesh's per-leaf segment layout;
    # every tiny-lm leaf has a 4-divisible dim, so it is complete
    assign = pv.mesh_flat_assignment(params_abs, A)
    assert (assign >= 0).all()

    # ---- simulator + scan engines under the SAME (mesh) masks ----------
    # RandP(p=1) == the distributed dsc_p=1.0 stage, deterministically
    fl_cfg = FLConfig(method="eris", K=A, A=A, lr=LR, use_dsc=True,
                      gamma=0.5, int8_wire=True, keep_views=True,
                      rounds=STEPS, compressor=RandP(p=1.0))
    loss_fn = lambda p, b: tr.loss_fn(p, cfg, b)
    client_batches = {"tokens": toks.reshape(A, 2, 32)}
    def with_mesh_masks(run):
        agg = dc.replace(run.pipeline.aggregate,
                         assign_override=jnp.asarray(assign))
        run.pipeline = dc.replace(run.pipeline, aggregate=agg)
        return run
    sim = with_mesh_masks(FLRun(fl_cfg, params0, loss_fn))
    sim_views = [np.asarray(sim.step(client_batches, collect_views=True))
                 for _ in range(STEPS)]
    scan = with_mesh_masks(FLRun(fl_cfg, params0, loss_fn))
    stacked = jax.tree.map(lambda x: jnp.stack([x] * STEPS),
                           client_batches)
    _, scan_views = scan.run_scanned(stacked, collect_views=True)

    # ---- distributed runtime: adversary-view tap on (4 data, 1 model) --
    mesh = make_host_mesh(data=A, model=1)
    settings = TrainSettings(grad_dtype="float32", int8_wire=True,
                             use_dsc=True, dsc_p=1.0, dsc_gamma=0.5,
                             capture_views=True)
    step, shardings = make_train_step(cfg, mesh, sgd(LR), settings)
    with mesh:
        params = jax.device_put(params0, shardings["store"])
        opt_state = sgd(LR).init(params)
        dsc_ref = init_dsc_state(cfg, mesh, settings)
        jstep = jax.jit(step)
        dist_views = []
        for i in range(STEPS):
            params, opt_state, dsc_ref, m, v = jstep(
                params, opt_state, dsc_ref, batch, jax.random.PRNGKey(i))
            dist_views.append(pv.flat_views_from_leaves(
                jax.device_get(v), params_abs, A))
    out = {
        "assign": assign.tolist(),
        "sim": np.stack(sim_views).tolist(),
        "scan": np.asarray(scan_views).tolist(),
        "dist": np.stack(dist_views).tolist(),
        "sim_x": np.asarray(sim.x).tolist(),
        "scan_x": np.asarray(scan.x).tolist(),
        "dist_x": np.asarray(
            ravel_pytree(jax.device_get(params))[0]).tolist(),
        "x0": np.asarray(ravel_pytree(params0)[0]).tolist(),
    }
    print("VIEWS" + json.dumps(out))
""")


@pytest.mark.slow
def test_three_engines_adversary_views_agree():
    """ISSUE 5 satellite: the per-aggregator views captured from the
    simulator (``keep_views``), the scan engine (``collect_views``) and
    the distributed runtime's tap (``capture_views``) agree for
    eris x DSC x int8-wire — same masks (the simulator pinned to the
    mesh-induced assignment via ``assign_override``), values within the
    int8 round-trip band (independent stochastic-rounding draws), and
    supports exactly disjoint.  Also gates the Eq. 4 aggregator-side
    shift fix: final params of all three engines coincide."""
    r = subprocess.run([sys.executable, "-c", VIEW_PARITY_SCRIPT],
                       capture_output=True, text=True, timeout=900,
                       env=SUBPROC_ENV)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [ln for ln in r.stdout.splitlines() if ln.startswith("VIEWS")][-1]
    out = json.loads(line[len("VIEWS"):])
    sim, scan, dist = (np.asarray(out[k], dtype=np.float32)
                       for k in ("sim", "scan", "dist"))
    assign = np.asarray(out["assign"])
    # engines sharing the stage list agree exactly
    np.testing.assert_allclose(scan, sim, rtol=1e-5, atol=1e-6)
    # the distributed tap lands inside the int8 rounding band, view-for-
    # view: (T, A, K, n) aligned per aggregator thanks to the shared masks
    np.testing.assert_allclose(dist, sim, atol=3e-2)
    assert np.abs(dist - sim).mean() < 1e-3
    # per-aggregator supports: exactly zero off each aggregator's mask
    for a in range(dist.shape[1]):
        assert np.abs(dist[:, a][:, :, assign != a]).max() == 0
        assert np.abs(sim[:, a][:, :, assign != a]).max() == 0
    # Eq. 4 end-to-end: the DSC-compensated distributed model follows the
    # simulator (quantization tolerance), and everyone actually moved
    sim_x, dist_x, x0 = (np.asarray(out[k])
                         for k in ("sim_x", "dist_x", "x0"))
    np.testing.assert_allclose(np.asarray(out["scan_x"]), sim_x,
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(dist_x, sim_x, atol=1e-2)
    assert np.abs(sim_x - x0).max() > 1e-3


@pytest.mark.slow
@pytest.mark.parametrize("int8", [False])
def test_three_engines_agree(int8):
    """Simulator, scan driver, and the 8-device shard_map runtime land on
    the same parameters (f32 wire: tight tolerance — identical math up to
    collective reduction order).  The int8-wire engine pair is covered by
    tests/test_distributed.py::test_fsa_int8_wire_matches_simulator, and
    int8 sim-vs-scan by the fast property sweep above, so the expensive
    int8 subprocess is not duplicated here."""
    out = _run_parity(int8)
    sim, scan, dist = (np.asarray(out[k]) for k in ("sim", "scan", "dist"))
    x0 = np.asarray(out["x0"])
    np.testing.assert_allclose(scan, sim, rtol=1e-5, atol=1e-5)
    atol = 1e-2 if int8 else 1e-4
    np.testing.assert_allclose(dist, sim, atol=atol)
    # all engines actually moved off the init
    assert np.abs(sim - x0).max() > 1e-3
