"""SSM mixers: chunked parallel forms == naive step recurrences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm

KEY = jax.random.PRNGKey(0)


def naive_ssm(u, dt, B, C, A_log, D_skip):
    Bt, T, Di = u.shape
    N = B.shape[-1]
    A = -np.exp(np.asarray(A_log, np.float64))
    h = np.zeros((Bt, Di, N))
    ys = []
    u_, dt_, B_, C_ = (np.asarray(a, np.float64) for a in (u, dt, B, C))
    for t in range(T):
        a = np.exp(dt_[:, t][..., None] * A)
        h = a * h + (dt_[:, t] * u_[:, t])[..., None] * B_[:, t][:, None, :]
        y = np.einsum("bdn,bn->bd", h, C_[:, t]) + np.asarray(D_skip) * u_[:, t]
        ys.append(y)
    return np.stack(ys, 1), h


@pytest.mark.parametrize("T,chunk", [(32, 32), (32, 8), (64, 16),
                                     (33, 8), (17, 32)])
def test_ssm_scan_matches_recurrence(T, chunk):
    """Includes indivisible T (ISSUE 4 satellite): time is padded with
    dt=0 identity steps, so y AND h_final stay exact."""
    Bt, Di, N = 2, 6, 4
    ks = jax.random.split(KEY, 5)
    u = jax.random.normal(ks[0], (Bt, T, Di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bt, T, Di)))
    B = jax.random.normal(ks[2], (Bt, T, N))
    C = jax.random.normal(ks[3], (Bt, T, N))
    A_log = jax.random.normal(ks[4], (Di, N)) * 0.5
    D_skip = jnp.ones(Di) * 0.3
    y, h = ssm.ssm_scan(u, dt, B, C, A_log, D_skip, chunk=chunk)
    y_ref, h_ref = naive_ssm(u, dt, B, C, A_log, D_skip)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h), h_ref, atol=1e-4, rtol=1e-4)


def test_ssm_decode_matches_scan():
    Bt, T, Di, N = 1, 12, 4, 3
    ks = jax.random.split(KEY, 5)
    u = jax.random.normal(ks[0], (Bt, T, Di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bt, T, Di)))
    B = jax.random.normal(ks[2], (Bt, T, N))
    C = jax.random.normal(ks[3], (Bt, T, N))
    A_log = jax.random.normal(ks[4], (Di, N)) * 0.5
    D_skip = jnp.zeros(Di)
    y_par, _ = ssm.ssm_scan(u, dt, B, C, A_log, D_skip, chunk=4)
    h = jnp.zeros((Bt, Di, N))
    for t in range(T):
        h, y = ssm.ssm_decode_step(h, u[:, t], dt[:, t], B[:, t], C[:, t],
                                   A_log, D_skip)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_par[:, t]),
                                   atol=1e-4, err_msg=f"t={t}")


@pytest.mark.parametrize("T,chunk", [(16, 16), (33, 8)])
def test_mlstm_parallel_matches_decode(T, chunk):
    """Quadratic stabilized mLSTM == step recurrence, including the
    stabilizer bookkeeping."""
    B, H, hd = 2, 3, 8
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, T, H, hd))
    k = jax.random.normal(ks[1], (B, T, H, hd))
    v = jax.random.normal(ks[2], (B, T, H, hd))
    i_pre = jax.random.normal(ks[3], (B, T, H))
    f_pre = jax.random.normal(ks[4], (B, T, H)) + 1.0
    h_par = ssm.mlstm_parallel(q, k, v, i_pre, f_pre, chunk=chunk)
    state = {"C": jnp.zeros((B, H, hd, hd)), "n": jnp.zeros((B, H, hd)),
             "m": jnp.full((B, H), -1e30)}
    for t in range(T):
        state, h = ssm.mlstm_decode_step(state, q[:, t], k[:, t], v[:, t],
                                         i_pre[:, t], f_pre[:, t])
        np.testing.assert_allclose(np.asarray(h), np.asarray(h_par[:, t]),
                                   atol=2e-4, rtol=2e-3, err_msg=f"t={t}")


def test_mlstm_forget_gate_decays_history():
    """Strongly negative forget preactivation ==> output ~ only current kv."""
    B, T, H, hd = 1, 8, 1, 4
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, T, H, hd))
    k = jax.random.normal(ks[1], (B, T, H, hd))
    v = jax.random.normal(ks[2], (B, T, H, hd))
    i_pre = jnp.zeros((B, T, H))
    f_pre = jnp.full((B, T, H), -30.0)       # forget everything
    h = ssm.mlstm_parallel(q, k, v, i_pre, f_pre, chunk=4)
    # each step sees only its own (k_t, v_t)
    for t in range(T):
        scale = hd ** -0.5
        w = float((q[0, t, 0] * k[0, t, 0]).sum()) * scale
        expect = w * np.asarray(v[0, t, 0]) / max(abs(w), 1.0)
        np.testing.assert_allclose(np.asarray(h[0, t, 0]), expect, atol=1e-3)
