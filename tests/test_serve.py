"""Serving engine acceptance battery.

* batching invariance: >= 8 concurrent requests decode token-identically
  to each request served alone (the engine's core contract);
* paged prefill-then-decode equals the full-sequence forward per paged
  zoo family, incl. GQA and a sliding window;
* Pallas paged-attention kernel vs the gather reference;
* sampling properties (greedy/top-k/top-p/beam) and the preemption
  replay path;
* the unified Settings API: ServeSettings validation, AsyncSettings
  extraction shared by FLConfig/TrainSettings.
"""
import dataclasses
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import SUBPROC_ENV

from repro.configs import get_config
from repro.models import transformer as tr
from repro.serve import (SamplingParams, ServeEngine, ServeSettings,
                         beam_search, pages_for, sample)

KEY = jax.random.PRNGKey(0)


def tiny_cfg(arch="qwen2-0.5b", **over):
    cfg = dataclasses.replace(get_config(arch).smoke(), n_layers=2,
                              dtype="float32")
    return dataclasses.replace(cfg, **over) if over else cfg


def tiny_settings(**over):
    kw = dict(max_concurrency=8, block_size=8, num_blocks=64,
              max_model_len=48, prefill_bucket=16, max_new_tokens=6,
              cache_dtype="float32")
    kw.update(over)
    return ServeSettings(**kw)


def prompts_for(cfg, n, seed=0, lo=3, hi=11):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab,
                         size=int(rng.integers(lo, hi))).tolist()
            for _ in range(n)]


# ------------------------------------------------- batching invariance
def test_batched_8way_token_identical_to_unbatched():
    """ACCEPTANCE: >= 8 requests decode concurrently (continuous
    batching over one fixed-shape jit) and every request's stream is
    token-identical to serving it alone — including sampled (nonzero
    temperature) requests, whose per-token keys ride with the request.
    The paged pool never exceeds its block budget."""
    cfg = tiny_cfg()
    params = tr.init_params(KEY, cfg)
    prompts = prompts_for(cfg, 10)
    samps = [SamplingParams() if i % 2 == 0 else
             SamplingParams(temperature=0.8, top_k=5)
             for i in range(len(prompts))]

    eng = ServeEngine(cfg, params, tiny_settings())
    for i, p in enumerate(prompts):
        eng.submit(p, sampling=samps[i], seed=i)
    outs, max_active = [], 0
    while eng.waiting or eng._active():
        outs.extend(eng.step())
        max_active = max(max_active, len(eng._active()))
    outs = sorted(outs, key=lambda o: o.rid)
    assert max_active == 8                      # slots actually shared
    st = eng.stats()
    assert st["peak_blocks"] <= st["block_capacity"]
    assert st["peak_blocks"] > pages_for(48, 8)  # > one request's worth

    for i, p in enumerate(prompts):
        solo = ServeEngine(cfg, params, tiny_settings(max_concurrency=1))
        solo.submit(p, sampling=samps[i], seed=i)
        ref = solo.run()
        assert outs[i].tokens == ref[0].tokens, f"request {i} diverged"
        assert outs[i].finish_reason == "length"


def test_preemption_replays_identically():
    """A pool too small for all admitted requests forces preempt-youngest;
    the replayed requests still emit the same streams as an unconstrained
    run."""
    cfg = tiny_cfg()
    params = tr.init_params(KEY, cfg)
    prompts = prompts_for(cfg, 4, seed=3, lo=8, hi=12)
    big = ServeEngine(cfg, params, tiny_settings(max_concurrency=4,
                                                 max_new_tokens=10))
    ref = big.run(prompts)
    # 9 usable blocks of 8: four requests at ~18-22 tokens cannot all
    # stay resident
    small = ServeEngine(cfg, params, tiny_settings(
        max_concurrency=4, num_blocks=10, max_model_len=24,
        max_new_tokens=10))
    outs = small.run(prompts)
    assert sum(o.preemptions for o in outs) > 0
    assert [o.tokens for o in outs] == [o.tokens for o in ref]
    st = small.stats()
    assert st["peak_blocks"] <= st["block_capacity"] == 9


def test_submit_validation():
    cfg = tiny_cfg()
    eng = ServeEngine(cfg, tr.init_params(KEY, cfg), tiny_settings())
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit([])
    with pytest.raises(ValueError, match="max_model_len"):
        eng.submit(list(range(40)), max_new_tokens=40)
    small = ServeEngine(cfg, tr.init_params(KEY, cfg),
                        tiny_settings(num_blocks=3, max_model_len=48))
    with pytest.raises(ValueError, match="blocks"):
        small.submit(list(range(30)), max_new_tokens=10)


def test_eos_stops_early():
    cfg = tiny_cfg()
    params = tr.init_params(KEY, cfg)
    probe = ServeEngine(cfg, params, tiny_settings())
    tok0 = probe.run([prompts_for(cfg, 1)[0]])[0].tokens[0]
    eng = ServeEngine(cfg, params, tiny_settings(eos_id=tok0))
    out = eng.run([prompts_for(cfg, 1)[0]])[0]
    assert out.finish_reason == "stop"
    assert out.tokens[-1] == tok0 and len(out.tokens) == 1


# ------------------------------------- paged decode vs full forward
@pytest.mark.parametrize("arch,window", [
    ("qwen2-0.5b", None),        # dense, GQA
    ("qwen2-0.5b", 8),           # dense, sliding window
    ("olmoe-1b-7b", None),       # moe
    ("musicgen-medium", None),   # audio frontend (LM decode path)
])
def test_paged_prefill_then_decode_matches_forward(arch, window):
    """Prefill S0 tokens into the paged pools, then decode the rest one
    token at a time through ``paged_decode_step`` — every step's logits
    must match the full-sequence forward at that position."""
    cfg = tiny_cfg(arch)
    if cfg.family == "moe":
        # ample capacity => no token dropping => decode matches exactly;
        # capacity-dropped tokens diverging between the 12-token forward
        # and 1-token decode routing calls is expected MoE semantics
        # (same treatment as test_decode_consistent_with_forward).
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = tr.init_params(KEY, cfg)
    T, S0, bs = 12, 5, 4
    toks = jax.random.randint(jax.random.fold_in(KEY, 7), (1, T), 0,
                              cfg.vocab)
    full, _, _ = tr.forward(params, cfg, toks, mode="prefill",
                            window=window)

    pools = tr.init_paged_pools(cfg, num_blocks=8, block_size=bs,
                                dtype=jnp.float32)
    from repro.serve.cache import BlockAllocator, write_prefill
    alloc = BlockAllocator(8, bs)
    pages = np.asarray(alloc.alloc(pages_for(T, bs)), np.int32)
    _, caches, _ = tr.forward(params, cfg, toks[:, :S0], mode="prefill",
                              window=window)
    pools = write_prefill(pools, caches["kv"]["k"][:, 0],
                          caches["kv"]["v"][:, 0], jnp.asarray(pages), bs)
    tables = jnp.zeros((1, len(pages)), jnp.int32).at[0].set(pages)
    for t in range(S0, T):
        logits, pools = tr.paged_decode_step(
            params, cfg, pools, tables, jnp.asarray([t], jnp.int32),
            toks[:, t:t + 1], window=window)
        np.testing.assert_allclose(np.asarray(logits[0, 0]),
                                   np.asarray(full[0, t]),
                                   atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("B,H,KV,bs,P,hd,window", [
    (2, 4, 2, 8, 3, 16, None),     # GQA
    (3, 8, 8, 16, 4, 32, None),    # MHA
    (4, 6, 2, 8, 5, 16, 7),        # GQA + sliding window
    (3, 4, 1, 16, 3, 32, None),    # MQA
])
def test_paged_kernel_matches_reference(B, H, KV, bs, P, hd, window):
    """Pallas (interpret) paged-attention kernel vs the dense gather
    reference, incl. an inactive (ctx 0) row that must emit zeros."""
    from repro.kernels import paged_attention as pa
    N = P * B + 1
    key = jax.random.PRNGKey(B * 100 + H)
    q = jax.random.normal(key, (B, H, hd), jnp.float32)
    kp = jax.random.normal(jax.random.fold_in(key, 1), (N, KV, bs, hd))
    vp = jax.random.normal(jax.random.fold_in(key, 2), (N, KV, bs, hd))
    tbl = jnp.arange(1, N).reshape(B, P).astype(jnp.int32)
    ctx = jnp.asarray(
        np.random.default_rng(0).integers(1, P * bs + 1, size=B), jnp.int32)
    ctx = ctx.at[0].set(0)                       # inactive slot
    assert pa.supports(H, KV, hd)
    out = pa.paged_attention(q, kp, vp, tbl, ctx, window=window,
                             interpret=True)
    ref = pa.paged_attention_ref(q, kp, vp, tbl, ctx, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
    assert not np.asarray(out[0]).any()          # inactive row is zero


# ----------------------------------------------------------- sampling
def test_sample_greedy_and_topk1_are_argmax():
    logits = jax.random.normal(KEY, (5, 33))
    am = np.asarray(jnp.argmax(logits, -1))
    keys = jnp.stack([jax.random.PRNGKey(i) for i in range(5)])
    greedy = sample(keys, logits, jnp.zeros(5), jnp.zeros(5, jnp.int32),
                    jnp.ones(5))
    np.testing.assert_array_equal(np.asarray(greedy), am)
    topk1 = sample(keys, logits, jnp.full((5,), 1.3),
                   jnp.ones(5, jnp.int32), jnp.ones(5))
    np.testing.assert_array_equal(np.asarray(topk1), am)


def test_sample_topk_topp_support_and_determinism():
    logits = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 64))
    top5 = set(np.asarray(jnp.argsort(-logits[0])[:5]).tolist())
    for i in range(20):
        k = jax.random.PRNGKey(i)[None]
        t = sample(k, logits, jnp.asarray([1.5]),
                   jnp.asarray([5], jnp.int32), jnp.asarray([1.0]))
        assert int(t[0]) in top5
        # tiny top_p keeps only the head of the distribution
        t = sample(k, logits, jnp.asarray([2.0]),
                   jnp.asarray([0], jnp.int32), jnp.asarray([1e-6]))
        assert int(t[0]) == int(jnp.argmax(logits))
    k = jax.random.PRNGKey(3)[None]
    args = (logits, jnp.asarray([1.0]), jnp.asarray([0], jnp.int32),
            jnp.asarray([0.9]))
    assert int(sample(k, *args)[0]) == int(sample(k, *args)[0])
    draws = {int(sample(jax.random.PRNGKey(i)[None], *args)[0])
             for i in range(25)}
    assert len(draws) > 1                       # it actually samples


def test_sampling_params_validation():
    with pytest.raises(ValueError, match="temperature"):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError, match="top_k"):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(top_p=0.0)


def test_beam1_equals_greedy_engine_decode():
    cfg = tiny_cfg()
    params = tr.init_params(KEY, cfg)
    prompt = prompts_for(cfg, 1, seed=5)[0]
    greedy = ServeEngine(cfg, params, tiny_settings()).run([prompt])[0]
    seq, score = beam_search(params, cfg, jnp.asarray(prompt),
                             n_beams=1, max_new_tokens=6)
    assert np.asarray(seq).tolist() == greedy.tokens
    assert np.isfinite(float(score))


def test_beam_width_scores_monotone():
    """A wider beam never returns a worse-scoring sequence."""
    cfg = tiny_cfg()
    params = tr.init_params(KEY, cfg)
    prompt = jnp.asarray(prompts_for(cfg, 1, seed=9)[0])
    _, s1 = beam_search(params, cfg, prompt, n_beams=1, max_new_tokens=5)
    _, s4 = beam_search(params, cfg, prompt, n_beams=4, max_new_tokens=5)
    assert float(s4) >= float(s1) - 1e-5


# -------------------------------------------------- settings surface
def test_serve_settings_validation():
    for bad in (dict(max_concurrency=0), dict(num_blocks=1),
                dict(block_size=0), dict(max_model_len=0),
                dict(prefill_bucket=0), dict(decode_kernel="cuda")):
        with pytest.raises(ValueError, match="ServeSettings"):
            ServeSettings(**bad)
    assert ServeSettings(max_model_len=100, block_size=16).max_pages == 7


def test_engine_rejects_recurrent_families():
    cfg = tiny_cfg("xlstm-350m")
    with pytest.raises(ValueError, match="families"):
        ServeEngine(cfg, tr.init_params(KEY, cfg), tiny_settings())


def test_launch_serve_unified_surface():
    from repro.launch import serve as serve_lib
    assert serve_lib.ServeSettings is ServeSettings
    # the one-release deprecated make_*_step/lower_serve_step shims are
    # gone; lower_step is the only lowering entry point
    for name in ("make_prefill_step", "make_decode_step",
                 "lower_serve_step"):
        assert not hasattr(serve_lib, name)


def test_async_settings_validation_names_fields():
    from repro.core.settings import AsyncSettings
    with pytest.raises(ValueError, match="AsyncSettings.buffer_cadence"):
        AsyncSettings(buffer_cadence=0)
    with pytest.raises(ValueError, match="AsyncSettings.population"):
        AsyncSettings(population=-1)
    with pytest.raises(ValueError, match="AsyncSettings.client_dropout"):
        AsyncSettings(client_dropout=1.5)
    with pytest.raises(ValueError, match="AsyncSettings.staleness_alpha"):
        AsyncSettings(staleness_alpha=-0.5)
    with pytest.raises(ValueError, match="AsyncSettings.delay_max"):
        AsyncSettings(delay_max=-1)


def test_async_settings_conflict_detection():
    from repro.core.fl import FLConfig
    from repro.core.settings import AsyncSettings
    from repro.launch.train import TrainSettings
    explicit = AsyncSettings(population=32, buffer_cadence=2)
    # explicit + defaulted flat fields: fine, explicit wins
    fl = FLConfig(K=4, A=2, async_=explicit)
    assert fl.async_settings() is explicit
    # conflicting flat field is named in the error
    fl_bad = FLConfig(K=4, A=2, async_=explicit, delay_max=3)
    with pytest.raises(ValueError, match=r"FLConfig\.delay_max"):
        fl_bad.async_settings()
    ts = TrainSettings(async_=explicit, buffer_cadence=4)
    with pytest.raises(ValueError, match=r"TrainSettings\.buffer_cadence"):
        ts.async_settings()
    # flat-only path still resolves (legacy)
    flat = FLConfig(K=4, A=2, population=16).async_settings()
    assert flat.population == 16 and flat.buffer_cadence == 1


def test_async_settings_cohort_guard():
    from repro.core.settings import AsyncSettings
    a = AsyncSettings(population=8)
    assert a.cohort(4) is not None
    with pytest.raises(ValueError, match="population"):
        a.cohort(16)
    assert AsyncSettings().cohort(4) is None    # population 0: no cohorts


# ------------------------------------------- checkpoint + mesh smoke
def test_from_checkpoint_handoff(tmp_path):
    from repro.checkpoint import msgpack_ckpt as ck
    cfg = tiny_cfg()
    params = tr.init_params(KEY, cfg)
    prompts = prompts_for(cfg, 2, seed=11)
    ref = ServeEngine(cfg, params, tiny_settings()).run(prompts)
    ck.save_sharded(tmp_path / "ckpt", params)
    eng = ServeEngine.from_checkpoint(tmp_path / "ckpt", cfg,
                                      tiny_settings())
    outs = eng.run(prompts)
    assert [o.tokens for o in outs] == [o.tokens for o in ref]


MESH_SERVE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.configs import get_config
    from repro.models import transformer as tr
    from repro.serve import ServeEngine, ServeSettings

    cfg = dataclasses.replace(get_config("qwen2-0.5b").smoke(),
                              n_layers=2, dtype="float32")
    params = tr.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab,
                            size=int(rng.integers(3, 11))).tolist()
               for _ in range(8)]
    ss = ServeSettings(max_concurrency=8, block_size=8, num_blocks=64,
                       max_model_len=48, prefill_bucket=16,
                       max_new_tokens=5, cache_dtype="float32")
    ref = ServeEngine(cfg, params, ss).run(prompts)
    devs = np.array(jax.devices())
    mesh = Mesh(devs.reshape(4, 2), ("data", "model"))
    eng = ServeEngine(cfg, params, ss, mesh=mesh)
    outs = eng.run(prompts)
    print("MESH" + json.dumps({
        "ok": [o.tokens for o in outs] == [o.tokens for o in ref],
        "manual": eng._manual,
        "kernel": eng._use_kernel,
        "attn_sharded": eng._tp_plan.attn,
        "peak": eng.stats()["peak_blocks"],
        "cap": eng.stats()["block_capacity"]}))
""")


def test_small_mesh_serving_smoke():
    """Tier-1 serving smoke on a (4, 2) host mesh: decode runs the
    fully-manual shard_map body (params at the TP-plan layout, pools
    kv-head-sharded over 'model', slots over 'data') with the paged
    Pallas kernel path ENGAGED under TP — token-identical to the
    meshless engine."""
    r = subprocess.run([sys.executable, "-c", MESH_SERVE_SCRIPT],
                       capture_output=True, text=True, timeout=900,
                       env=SUBPROC_ENV)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [ln for ln in r.stdout.splitlines() if ln.startswith("MESH")][-1]
    out = json.loads(line[len("MESH"):])
    assert out["manual"], "manual decode body should engage on (4, 2)"
    assert out["kernel"], "paged kernel should engage under the manual body"
    assert out["attn_sharded"], "qwen2 heads divide model=2 — attn TP"
    assert out["ok"]
    assert out["peak"] <= out["cap"]
