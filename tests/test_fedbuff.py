"""Buffered-aggregate (FedBuff-style) property battery.

The async runtime's correctness contract, locked down as properties:
bit-exact degeneracy to the synchronous engines (trivial arrivals +
cadence 1), the closed-form staleness discount, the T-round buffer fold
against an unrolled NumPy reference, dropout contributing exactly
nothing, and the registry-level composition rules (DSC/EF refuse the
async wrapper, cohort knobs validate).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import pipeline as pl
from repro.core.fl import FLConfig, FLRun
from repro.core.rounds import build_round

KEY = jax.random.PRNGKey(0)


def quad_problem(K: int = 4, n: int = 48):
    ka, kb = jax.random.split(KEY)
    a = 1.0 + jax.random.uniform(ka, (K, n))
    b = jax.random.normal(kb, (K, n))

    def loss_fn(params, batch):
        r = batch["a"] * params["w"] - batch["b"]
        return 0.5 * jnp.mean(r * r)

    return {"w": jnp.zeros(n)}, loss_fn, {"a": a, "b": b}


# ------------------------------------------------- closed-form weights
@settings(max_examples=10, deadline=None)
@given(alpha=st.floats(0.0, 3.0), delay_max=st.integers(0, 6),
       dropout=st.floats(0.0, 0.9), seed=st.integers(0, 2 ** 16))
def test_staleness_weights_match_closed_form(alpha, delay_max, dropout,
                                             seed):
    """omega_k = alive_k / (1 + tau_k)^alpha, tau in {0..delay_max}."""
    am = pl.ArrivalModel(delay_max=delay_max, dropout=dropout, alpha=alpha)
    tau, alive, omega = am.draw(jax.random.PRNGKey(seed), 32)
    tau, alive, omega = (np.asarray(z) for z in (tau, alive, omega))
    assert tau.min() >= 0 and tau.max() <= delay_max
    np.testing.assert_allclose(
        omega, alive * (1.0 + tau) ** (-alpha), rtol=1e-6)
    # trivial exactly when no staleness AND no dropout
    assert am.trivial == (delay_max == 0 and dropout == 0.0)


# ------------------------------------------ bit-exact degenerate cases
def _trajectory(cfg, T=5):
    params0, loss_fn, batches = quad_problem(K=cfg.K)
    run = FLRun(cfg, params0, loss_fn)
    stacked = jax.tree.map(lambda x: jnp.stack([x] * T), batches)
    xs = run.run_scanned(stacked)
    return np.asarray(xs)


def test_fedbuff_degenerates_to_fedavg_bit_exact():
    """Trivial arrivals + cadence 1: the buffer fold is `0 + 1.0*u` and
    `u / 1.0` — IEEE-exact identities — so fedbuff IS fedavg, bitwise."""
    sync = _trajectory(FLConfig(method="fedavg", K=4, lr=0.05, seed=7))
    async_ = _trajectory(FLConfig(method="fedbuff", K=4, lr=0.05, seed=7))
    assert np.array_equal(sync, async_)


def test_eris_async_degenerates_to_eris_bit_exact():
    sync = _trajectory(FLConfig(method="eris", K=4, A=2, lr=0.05, seed=7))
    async_ = _trajectory(FLConfig(method="eris_async", K=4, A=2, lr=0.05,
                                  seed=7))
    assert np.array_equal(sync, async_)


def test_int8_wire_composes_with_fedbuff_bit_exact():
    """The int8 wire is stateless, so it rides through the buffered
    wrapper unchanged — degenerate fedbuff+int8 == the synchronous
    int8 pipeline (eris with A=1-style mean aggregation; plain fedavg
    does not consume ``int8_wire``)."""
    sync = _trajectory(FLConfig(method="eris", K=4, lr=0.05,
                                int8_wire=True, seed=9))
    async_ = _trajectory(FLConfig(method="fedbuff", K=4, lr=0.05,
                                  int8_wire=True, seed=9))
    assert np.array_equal(sync, async_)


# ------------------------------------------- unrolled NumPy reference
def _numpy_fold(stage, keys_list, vs, weights_list):
    """The BufferedAggregate contract, unrolled in NumPy float64."""
    n = vs[0].shape[1]
    u, w, t = np.zeros(n), 0.0, 0
    outs = []
    for keys, v, weights in zip(keys_list, vs, weights_list):
        K = v.shape[0]
        v = np.asarray(v, np.float64)
        if stage.arrival.trivial:
            base = (np.asarray(weights, np.float64) if weights is not None
                    else np.full(K, 1.0 / K))
            contrib = (base / base.sum()) @ v
            w_round = 1.0
        else:
            k_arr = jax.random.fold_in(getattr(keys, stage.key_role),
                                       pl.ARRIVAL_SALT)
            _, alive, omega = stage.arrival.draw(k_arr, K)
            alive = np.asarray(alive)
            omega = np.asarray(omega, np.float64)
            base = (np.asarray(weights, np.float64) if weights is not None
                    else np.ones(K))
            w_eff = base * omega
            v = v * alive[:, None]
            if w_eff.sum() > 0:
                contrib = (w_eff / w_eff.sum()) @ v
                w_round = w_eff.sum() / base.sum()
            else:
                contrib, w_round = np.zeros(n), 0.0
        u = u + w_round * contrib
        w = w + w_round
        t += 1
        if t % stage.cadence == 0:
            outs.append(u / max(w, 1e-12))
            u, w = np.zeros(n), 0.0
        else:
            outs.append(np.zeros(n))
    return outs


@settings(max_examples=8, deadline=None)
@given(cadence=st.sampled_from([1, 2, 3]),
       delay_max=st.integers(0, 4),
       dropout=st.sampled_from([0.0, 0.4]),
       alpha=st.floats(0.3, 2.0),
       weighted=st.booleans(),
       seed=st.integers(0, 2 ** 16))
def test_buffer_fold_matches_unrolled_numpy(cadence, delay_max, dropout,
                                            alpha, weighted, seed):
    """T rounds through BufferedAggregate.apply == the unrolled NumPy
    reference: same arrival draws (shared key discipline), same
    staleness-weighted buffer mass, same cadence-gated emission."""
    K, n, T = 5, 12, 6
    stage = pl.BufferedAggregate(
        inner=pl.AggregateStage(use_weights=True),
        arrival=pl.ArrivalModel(delay_max=delay_max, dropout=dropout,
                                alpha=alpha),
        cadence=cadence)
    state = pl.RoundPipeline(aggregate=stage).init_state(jnp.zeros(n), K)
    key = jax.random.PRNGKey(seed)
    keys_list, vs, ws = [], [], []
    for r in range(T):
        kr = jax.random.fold_in(key, r)
        keys_list.append(pl.split_round_keys(kr))
        vs.append(jax.random.normal(jax.random.fold_in(kr, 1), (K, n)))
        ws.append(1.0 + jax.random.uniform(jax.random.fold_in(kr, 2),
                                           (K,)) if weighted else None)
    want = _numpy_fold(stage, keys_list, vs, ws)
    got = []
    for keys, v, w in zip(keys_list, vs, ws):
        res = stage.apply(keys, state, v, w)
        state = res.state
        got.append(np.asarray(res.update))
    np.testing.assert_allclose(np.stack(got), np.stack(want),
                               rtol=1e-5, atol=1e-6)
    # the buffer reset exactly on apply rounds
    if T % cadence == 0:
        assert float(state.buf.w) == 0.0
        np.testing.assert_array_equal(np.asarray(state.buf.u), 0.0)
    assert int(state.buf.t) == T


def test_cadence_gates_server_movement():
    """Between apply rounds the emitted update is exactly zero: the
    model moves only every `cadence` rounds."""
    cfg = FLConfig(method="fedbuff", K=4, lr=0.05, buffer_cadence=3,
                   seed=1)
    params0, loss_fn, batches = quad_problem()
    run = FLRun(cfg, params0, loss_fn)
    prev = np.asarray(run.x)
    moved = []
    for _ in range(6):
        run.step(batches)
        cur = np.asarray(run.x)
        moved.append(not np.array_equal(cur, prev))
        prev = cur
    assert moved == [False, False, True, False, False, True]


def test_dropout_never_contributes():
    """dropout=1.0: every arrival dies, w_round == 0, the buffer stays
    empty, and the model NEVER moves — a dropped client (and a fully
    dropped cohort) contributes nothing, not a zero-mean something."""
    cfg = FLConfig(method="fedbuff", K=4, lr=0.05, client_dropout=1.0,
                   seed=2)
    params0, loss_fn, batches = quad_problem()
    run = FLRun(cfg, params0, loss_fn)
    x0 = np.asarray(run.x)
    for _ in range(4):
        run.step(batches)
    assert np.array_equal(np.asarray(run.x), x0)

    # direct stage check: the buffer mass stays identically zero
    stage = pl.BufferedAggregate(arrival=pl.ArrivalModel(dropout=1.0))
    state = pl.RoundPipeline(aggregate=stage).init_state(jnp.zeros(8), 3)
    keys = pl.split_round_keys(KEY)
    res = stage.apply(keys, state, jnp.ones((3, 8)), None)
    assert float(res.state.buf.w) == 0.0
    np.testing.assert_array_equal(np.asarray(res.update), 0.0)


def test_partial_dropout_masks_dead_rows():
    """A dropped client's transmitted row is hard-zeroed before the
    inner aggregate: resurrecting it in v must not change the result."""
    K, n = 6, 10
    stage = pl.BufferedAggregate(
        arrival=pl.ArrivalModel(dropout=0.5), cadence=1)
    keys = pl.split_round_keys(jax.random.fold_in(KEY, 3))
    k_arr = jax.random.fold_in(getattr(keys, stage.key_role),
                               pl.ARRIVAL_SALT)
    _, alive, _ = stage.arrival.draw(k_arr, K)
    alive = np.asarray(alive)
    assert 0 < alive.sum() < K          # seed chosen to mix dead/alive
    v = jax.random.normal(jax.random.fold_in(KEY, 4), (K, n))
    state = pl.RoundPipeline(aggregate=stage).init_state(jnp.zeros(n), K)
    poisoned = v.at[~alive].set(1e6)    # dead rows carry garbage
    a = stage.apply(keys, state, v, None)
    b = stage.apply(keys, state, poisoned, None)
    np.testing.assert_array_equal(np.asarray(a.update),
                                  np.asarray(b.update))


# ----------------------------------------------- composition contracts
def test_async_refuses_dsc_and_ef():
    """Cadence-delayed apply breaks the Eq. 4 shift-state bookkeeping
    (s_agg tracks per-round aggregator receipts), so the registry
    refuses to compose DSC or EF inside the async wrapper."""
    for kw in (dict(use_dsc=True), dict(use_ef=True)):
        try:
            build_round(FLConfig(method="eris_async", K=4, **kw), 16)
        except ValueError as e:
            assert "async" in str(e).lower() or "DSC" in str(e) \
                or "EF" in str(e)
        else:
            raise AssertionError(kw)


def test_buffered_aggregate_validates():
    try:
        pl.BufferedAggregate(cadence=0)
    except ValueError:
        pass
    else:
        raise AssertionError("cadence=0 must be rejected")
    try:
        pl.BufferedAggregate(inner=pl.AggregateStage(use_weights=False))
    except ValueError:
        pass
    else:
        raise AssertionError("weightless inner stage must be rejected")
    # missing buffer state fails loudly, not silently synchronous
    stage = pl.BufferedAggregate()
    state = pl.RoundPipeline().init_state(jnp.zeros(4), 2)
    try:
        stage.apply(pl.split_round_keys(KEY), state, jnp.ones((2, 4)),
                    None)
    except ValueError as e:
        assert "buf" in str(e)
    else:
        raise AssertionError("missing RoundState.buf must be rejected")


def test_population_requires_cohort_fits():
    try:
        build_round(FLConfig(method="fedbuff", K=8, population=4), 16)
    except ValueError:
        pass
    else:
        raise AssertionError("population < K must be rejected")
