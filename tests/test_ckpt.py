"""Checkpoint handoff: sharded save/restore roundtrips, the store->use
cross-mesh reshard (train saves on one mesh shape, serve restores under
another), and ``restore_any``'s format dispatch."""
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import SUBPROC_ENV

from repro.checkpoint import msgpack_ckpt as ck


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {"emb": jax.random.normal(k, (16, 8)),
            "blocks": {"w": jax.random.normal(jax.random.fold_in(k, 1),
                                              (3, 8, 8)),
                       "b": jnp.zeros((3, 8), jnp.float32)},
            "head": jnp.arange(24, dtype=jnp.int32).reshape(8, 3)}


def _assert_tree_equal(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_sharded_roundtrip_single_process(tmp_path):
    tree = _tree()
    d = tmp_path / "ckpt"
    ck.save_sharded(d, tree)
    assert (d / "manifest.msgpack").exists()
    assert (d / "shard-0.msgpack").exists()
    got = ck.restore_sharded(d, jax.eval_shape(lambda: tree))
    _assert_tree_equal(tree, got)
    # dtypes survive, not just values
    assert got["head"].dtype == jnp.int32


def test_restore_any_dispatches_dir_vs_file(tmp_path):
    tree = _tree()
    target = jax.eval_shape(lambda: tree)
    d = tmp_path / "dir_ckpt"
    f = tmp_path / "legacy.msgpack"
    ck.save_sharded(d, tree)
    ck.save(f, tree)
    _assert_tree_equal(tree, ck.restore_any(d, target))
    _assert_tree_equal(tree, ck.restore_any(f, target))


def test_sharded_shape_mismatch_raises(tmp_path):
    d = tmp_path / "ckpt"
    ck.save_sharded(d, {"w": jnp.zeros((4, 4))})
    with pytest.raises(ValueError, match="shape mismatch"):
        ck.restore_sharded(d, {"w": jnp.zeros((4, 5))})


CROSS_MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, functools
    import jax, numpy as np
    from jax.sharding import Mesh
    from repro.checkpoint import msgpack_ckpt as ck
    from repro.configs import get_config
    from repro.dist import sharding as sh
    from repro.models import transformer as tr

    out_dir = os.environ["CKPT_OUT"]
    devs = np.array(jax.devices())
    cfg = get_config("qwen2-0.5b").smoke()
    key = jax.random.PRNGKey(0)
    host = tr.init_params(key, cfg)

    # save from a 4x2 train mesh in the FSA *store* layout
    train_mesh = Mesh(devs.reshape(4, 2), ("data", "model"))
    p_store = jax.device_put(host,
                             sh.param_shardings(cfg, train_mesh, "store"))
    ck.save_sharded(out_dir, p_store)

    # restore under a DIFFERENT mesh shape's *use* layout
    serve_mesh = Mesh(devs.reshape(2, 4), ("data", "model"))
    use = sh.param_shardings(cfg, serve_mesh, "use")
    target = jax.eval_shape(functools.partial(tr.init_params, cfg=cfg), key)
    p_use = ck.restore_any(out_dir, target, shardings=use)

    ok = all(np.array_equal(np.asarray(a), np.asarray(b))
             for a, b in zip(jax.tree_util.tree_leaves(host),
                             jax.tree_util.tree_leaves(p_use)))
    n_sharded = sum(len(x.sharding.spec) > 0
                    for x in jax.tree_util.tree_leaves(p_use))
    print("CKPT" + json.dumps({"ok": ok, "n_sharded": n_sharded}))
""")


@pytest.mark.slow
def test_cross_mesh_store_to_use_parity(tmp_path):
    """Save on a (4, 2) train mesh in store layout, restore under a
    (2, 4) serve mesh's use layout: values identical to the host-side
    originals and the restored leaves actually carry the use sharding."""
    r = subprocess.run(
        [sys.executable, "-c", CROSS_MESH_SCRIPT],
        capture_output=True, text=True, timeout=900,
        env={**SUBPROC_ENV, "CKPT_OUT": str(tmp_path / "ckpt")})
    assert r.returncode == 0, r.stderr[-3000:]
    line = [ln for ln in r.stdout.splitlines() if ln.startswith("CKPT")][-1]
    out = json.loads(line[len("CKPT"):])
    assert out["ok"]
    assert out["n_sharded"] > 0
