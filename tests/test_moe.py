"""MoE dispatch correctness: grouped capacity dispatch vs a naive
per-token reference, load-balance loss, capacity dropping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import moe_ffn

KEY = jax.random.PRNGKey(0)


def naive_moe(x, router_w, w_gate, w_up, w_down, top_k):
    """Per-token dense reference with unlimited capacity."""
    B, S, D = x.shape
    E = router_w.shape[-1]
    logits = jnp.einsum("bsd,de->bse", x, router_w).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    # every token through every expert, combine by gates
    h = jax.nn.silu(jnp.einsum("bsd,edf->bsef", x, w_gate)) * \
        jnp.einsum("bsd,edf->bsef", x, w_up)
    y_all = jnp.einsum("bsef,efd->bsed", h, w_down)       # (B,S,E,D)
    sel = (jax.nn.one_hot(idx, E) * gate[..., None]).sum(2)  # (B,S,E)
    return jnp.einsum("bse,bsed->bsd", sel.astype(x.dtype), y_all)


def make_weights(key, D, E, F):
    ks = jax.random.split(key, 4)
    return (jax.random.normal(ks[0], (D, E)) * 0.2,
            jax.random.normal(ks[1], (E, D, F)) * D ** -0.5,
            jax.random.normal(ks[2], (E, D, F)) * D ** -0.5,
            jax.random.normal(ks[3], (E, F, D)) * F ** -0.5)


@pytest.mark.parametrize("top_k,E", [(1, 4), (2, 4), (2, 8)])
def test_grouped_dispatch_matches_naive_with_ample_capacity(top_k, E):
    B, S, D, F = 2, 16, 8, 16
    x = jax.random.normal(KEY, (B, S, D))
    rw, wg, wu, wd = make_weights(jax.random.fold_in(KEY, 1), D, E, F)
    y, aux = moe_ffn(x, rw, wg, wu, wd, top_k=top_k,
                     capacity_factor=float(E), group=16)
    ref = naive_moe(x, rw, wg, wu, wd, top_k)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)
    assert float(aux["dropped_frac"]) == 0.0


def test_capacity_drops():
    """Tiny capacity forces drops; output norm shrinks, fraction reported."""
    B, S, D, F, E = 1, 32, 8, 16, 4
    x = jax.random.normal(KEY, (B, S, D))
    rw, wg, wu, wd = make_weights(jax.random.fold_in(KEY, 2), D, E, F)
    y_full, aux_full = moe_ffn(x, rw, wg, wu, wd, top_k=2,
                               capacity_factor=8.0, group=32)
    y_tight, aux_tight = moe_ffn(x, rw, wg, wu, wd, top_k=2,
                                 capacity_factor=0.25, group=32)
    assert float(aux_tight["dropped_frac"]) > 0.0
    assert float(jnp.linalg.norm(y_tight)) < float(jnp.linalg.norm(y_full))


def test_load_balance_range():
    B, S, D, F, E = 2, 64, 8, 8, 8
    x = jax.random.normal(KEY, (B, S, D))
    rw, wg, wu, wd = make_weights(jax.random.fold_in(KEY, 3), D, E, F)
    _, aux = moe_ffn(x, rw, wg, wu, wd, top_k=2, group=64)
    # Switch aux loss is ~top_k for uniform routing, >= 1 always
    assert 0.9 <= float(aux["load_balance"]) < float(E * 2)


def test_moe_grad_flows_to_router():
    B, S, D, F, E = 1, 16, 4, 8, 4
    x = jax.random.normal(KEY, (B, S, D))
    rw, wg, wu, wd = make_weights(jax.random.fold_in(KEY, 4), D, E, F)

    def loss(rw):
        y, _ = moe_ffn(x, rw, wg, wu, wd, top_k=2, group=16)
        return jnp.sum(y ** 2)

    g = jax.grad(loss)(rw)
    assert float(jnp.abs(g).sum()) > 0
