"""MoE dispatch correctness: grouped capacity dispatch vs a naive
per-token reference, load-balance loss, capacity dropping, padding on
indivisible token counts, and hypothesis invariants of the dispatch
tensors (capacity respected, dropped tokens zeroed, combine weights
sum <= 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis import given, settings, strategies as st

from repro.models.moe import moe_ffn, route_tokens

KEY = jax.random.PRNGKey(0)


def naive_moe(x, router_w, w_gate, w_up, w_down, top_k):
    """Per-token dense reference with unlimited capacity."""
    B, S, D = x.shape
    E = router_w.shape[-1]
    logits = jnp.einsum("bsd,de->bse", x, router_w).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    # every token through every expert, combine by gates
    h = jax.nn.silu(jnp.einsum("bsd,edf->bsef", x, w_gate)) * \
        jnp.einsum("bsd,edf->bsef", x, w_up)
    y_all = jnp.einsum("bsef,efd->bsed", h, w_down)       # (B,S,E,D)
    sel = (jax.nn.one_hot(idx, E) * gate[..., None]).sum(2)  # (B,S,E)
    return jnp.einsum("bse,bsed->bsd", sel.astype(x.dtype), y_all)


def make_weights(key, D, E, F):
    ks = jax.random.split(key, 4)
    return (jax.random.normal(ks[0], (D, E)) * 0.2,
            jax.random.normal(ks[1], (E, D, F)) * D ** -0.5,
            jax.random.normal(ks[2], (E, D, F)) * D ** -0.5,
            jax.random.normal(ks[3], (E, F, D)) * F ** -0.5)


@pytest.mark.parametrize("top_k,E", [(1, 4), (2, 4), (2, 8)])
def test_grouped_dispatch_matches_naive_with_ample_capacity(top_k, E):
    B, S, D, F = 2, 16, 8, 16
    x = jax.random.normal(KEY, (B, S, D))
    rw, wg, wu, wd = make_weights(jax.random.fold_in(KEY, 1), D, E, F)
    y, aux = moe_ffn(x, rw, wg, wu, wd, top_k=top_k,
                     capacity_factor=float(E), group=16)
    ref = naive_moe(x, rw, wg, wu, wd, top_k)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)
    assert float(aux["dropped_frac"]) == 0.0


def test_capacity_drops():
    """Tiny capacity forces drops; output norm shrinks, fraction reported."""
    B, S, D, F, E = 1, 32, 8, 16, 4
    x = jax.random.normal(KEY, (B, S, D))
    rw, wg, wu, wd = make_weights(jax.random.fold_in(KEY, 2), D, E, F)
    y_full, aux_full = moe_ffn(x, rw, wg, wu, wd, top_k=2,
                               capacity_factor=8.0, group=32)
    y_tight, aux_tight = moe_ffn(x, rw, wg, wu, wd, top_k=2,
                                 capacity_factor=0.25, group=32)
    assert float(aux_tight["dropped_frac"]) > 0.0
    assert float(jnp.linalg.norm(y_tight)) < float(jnp.linalg.norm(y_full))


def test_load_balance_range():
    B, S, D, F, E = 2, 64, 8, 8, 8
    x = jax.random.normal(KEY, (B, S, D))
    rw, wg, wu, wd = make_weights(jax.random.fold_in(KEY, 3), D, E, F)
    _, aux = moe_ffn(x, rw, wg, wu, wd, top_k=2, group=64)
    # Switch aux loss is ~top_k for uniform routing, >= 1 always
    assert 0.9 <= float(aux["load_balance"]) < float(E * 2)


def test_indivisible_token_count_pads():
    """ISSUE 4 satellite: T % group != 0 pads (masked) instead of
    crashing; real tokens match the naive reference, padded tokens never
    claim capacity."""
    B, S, D, F, E = 1, 24, 8, 16, 4          # T=24, group=16 -> pad to 32
    x = jax.random.normal(KEY, (B, S, D))
    rw, wg, wu, wd = make_weights(jax.random.fold_in(KEY, 5), D, E, F)
    y, aux = moe_ffn(x, rw, wg, wu, wd, top_k=2,
                     capacity_factor=float(E), group=16)
    ref = naive_moe(x, rw, wg, wu, wd, 2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)
    assert float(aux["dropped_frac"]) == 0.0


def test_padded_groups_leave_aux_unchanged():
    """The masked aux terms weight each group by its VALID-token share:
    appending an all-padding group changes nothing."""
    g, t, D, E = 3, 8, 8, 4
    xg = jax.random.normal(jax.random.fold_in(KEY, 6), (g, t, D))
    rw = jax.random.normal(jax.random.fold_in(KEY, 7), (D, E)) * 0.2
    all_valid = jnp.ones((g, t), bool)
    _, _, aux = route_tokens(xg, rw, all_valid, top_k=2,
                             capacity_factor=2.0)
    xg_pad = jnp.concatenate([xg, jnp.zeros((1, t, D))])
    v_pad = jnp.concatenate([all_valid, jnp.zeros((1, t), bool)])
    _, _, aux_pad = route_tokens(xg_pad, rw, v_pad, top_k=2,
                                 capacity_factor=2.0)
    np.testing.assert_allclose(float(aux["load_balance"]),
                               float(aux_pad["load_balance"]), rtol=1e-6)
    np.testing.assert_allclose(float(aux["dropped_frac"]),
                               float(aux_pad["dropped_frac"]), atol=1e-7)


@given(seed=st.integers(0, 2**16), top_k=st.integers(1, 3),
       e_pow=st.integers(1, 3), cap_f=st.floats(0.2, 2.0),
       n_valid=st.integers(1, 32))
@settings(max_examples=20, deadline=None)
def test_route_invariants(seed, top_k, e_pow, cap_f, n_valid):
    """Dispatch invariants: (i) no expert ever receives more than its
    capacity; (ii) each (expert, slot) holds at most one token; (iii)
    per-token combine weights sum to <= 1; (iv) dropped and invalid
    tokens combine to exactly zero."""
    E = 2 ** e_pow
    top_k = min(top_k, E)
    g, t, D = 2, 16, 4
    key = jax.random.PRNGKey(seed)
    xg = jax.random.normal(key, (g, t, D))
    rw = jax.random.normal(jax.random.fold_in(key, 1), (D, E))
    valid = (jnp.arange(g * t) < n_valid).reshape(g, t)
    disp, comb, aux = route_tokens(xg, rw, valid, top_k=top_k,
                                   capacity_factor=cap_f)
    disp = np.asarray(disp)
    comb = np.asarray(comb)
    cap = disp.shape[-1]
    # (i) per-(group, expert) load <= capacity
    assert disp.sum((1, 3)).max() <= cap + 1e-6
    # (ii) each capacity slot holds at most one token
    assert disp.sum(1).max() <= 1 + 1e-6
    # (iii) combine weights per token sum to <= 1
    assert comb.sum((2, 3)).max() <= 1 + 1e-5
    # (iv) dropped or invalid tokens get zero combine weight
    routed = disp.sum((2, 3)) > 0
    assert np.all(comb.sum((2, 3))[~routed] == 0.0)
    assert np.all(comb[~np.asarray(valid)] == 0.0)
    assert np.all(disp[~np.asarray(valid)] == 0.0)
    assert 0.0 <= float(aux["dropped_frac"]) <= 1.0


def test_moe_grad_flows_to_router():
    B, S, D, F, E = 1, 16, 4, 8, 4
    x = jax.random.normal(KEY, (B, S, D))
    rw, wg, wu, wd = make_weights(jax.random.fold_in(KEY, 4), D, E, F)

    def loss(rw):
        y, _ = moe_ffn(x, rw, wg, wu, wd, top_k=2, group=16)
        return jnp.sum(y ** 2)

    g = jax.grad(loss)(rw)
    assert float(jnp.abs(g).sum()) > 0
