"""Pipeline (pipe axis) + ring-attention (ctx) contracts.

Two layers of coverage, mirroring tests/test_tp.py's split:

  * property tests (no devices): the ``PipelinePlan`` builder fallbacks,
    the 1F1B schedule enumerated by ``shard_plan.pipeline_schedule`` —
    every (stage, microbatch) cell exactly once per direction in a
    legal interleaved order — and the bubble-fraction bookkeeping the
    roofline consumes;
  * sharded-vs-replicated parity (subprocess, 8 host devices): the
    microbatched 1F1B ``pipeline_loss_fn`` under a manual shard_map
    over (pipe, model) against the replicated ``loss_fn`` — loss AND
    per-leaf gradients to fp32 tolerance — across pp={2,4} x tp x
    microbatch counts, including an indivisible-heads GQA config whose
    attention runs the ctx ppermute ring instead of the replicated
    fallback; plus the integrated ``make_train_step`` path (sharded
    loss + grad-norm vs replicated autodiff) with the composite
    client x pipe x model mesh.
"""
import json
import subprocess
import sys
import textwrap

import pytest

from conftest import SUBPROC_ENV
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.dist import sharding as sh
from repro.models import shard_plan as sp


# ------------------------------------------------------------ plan builder
def test_pipeline_plan_fallbacks():
    cfg = get_config("qwen2-0.5b").smoke()          # 2 layers
    assert not sp.build_pipeline_plan(cfg, 1, 4).active
    assert sp.build_pipeline_plan(cfg, 2, 4).active
    # 2 layers don't split into 4 contiguous stages -> inactive
    assert not sp.build_pipeline_plan(cfg, 4, 4).active
    with pytest.raises(ValueError, match="microbatches"):
        sp.build_pipeline_plan(cfg, 2, 0)


def test_pipeline_plan_geometry():
    cfg = get_config("qwen3-32b")                   # 64 layers
    plan = sp.build_pipeline_plan(cfg, 4, 8)
    assert plan.active and plan.layers_per_stage == 16
    assert plan.bubble_fraction == pytest.approx(3 / 11)
    assert sp.build_pipeline_plan(cfg, 1, 1).bubble_fraction == 0.0


def test_pipe_dims_mark_only_block_leaves():
    import jax
    from repro.models import transformer as tr
    cfg = get_config("qwen2-0.5b").smoke()
    pdims = sh.pipe_dims(cfg, 2)
    params = jax.eval_shape(lambda k: tr.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    assert (jax.tree_util.tree_structure(pdims)
            == jax.tree_util.tree_structure(params))
    flat = dict(zip([jax.tree_util.keystr(k) for k, _ in
                     jax.tree_util.tree_flatten_with_path(pdims)[0]],
                    jax.tree_util.tree_leaves(pdims)))
    for key, pd in flat.items():
        assert pd == (0 if "blocks" in key else -1), (key, pd)
    # pp == 1: nothing is pipe-sliced
    assert all(pd == -1 for pd in jax.tree_util.tree_leaves(
        sh.pipe_dims(cfg, 1)))


# --------------------------------------------------------- 1F1B schedule
@settings(max_examples=80, deadline=None)
@given(p=st.integers(1, 6), m=st.integers(1, 8))
def test_1f1b_schedule_legal_and_complete(p, m):
    """Every (stage, microbatch) cell appears exactly once per direction,
    in an order satisfying the pipeline's data dependencies:

      F(s, i) after F(s-1, i)   (activations flow down the stages)
      B(s, i) after B(s+1, i)   (cotangents flow back up)
      B(s, i) after F(s, i)     (a stage backs up only what it ran)
      per-stage F's and B's each in increasing microbatch order
    """
    order = sp.pipeline_schedule(p, m)
    assert len(order) == 2 * p * m
    pos = {}
    for t, (s, i, d) in enumerate(order):
        assert (s, i, d) not in pos, "duplicate cell"
        pos[(s, i, d)] = t
    for s in range(p):
        for i in range(m):
            assert (s, i, "F") in pos and (s, i, "B") in pos
            assert pos[(s, i, "B")] > pos[(s, i, "F")]
            if s > 0:
                assert pos[(s, i, "F")] > pos[(s - 1, i, "F")]
                assert pos[(s - 1, i, "B")] > pos[(s, i, "B")]
            if i > 0:
                assert pos[(s, i, "F")] > pos[(s, i - 1, "F")]
                assert pos[(s, i, "B")] > pos[(s, i - 1, "B")]


def test_1f1b_wavefront_matches_bubble_accounting():
    """The schedule's forward wavefront spans exactly m + p - 1 ticks —
    the denominator of ``PipelinePlan.bubble_fraction``."""
    for p, m in [(2, 2), (4, 8), (3, 5)]:
        order = sp.pipeline_schedule(p, m)
        # stage s's first forward is at wavefront tick s, its last at
        # s + m - 1; the global forward span is m + p - 1 ticks
        f_events = [(s, i) for s, i, d in order if d == "F"]
        by_stage = {}
        for s, i in f_events:
            by_stage.setdefault(s, []).append(i)
        assert all(v == sorted(v) for v in by_stage.values())
        assert len(by_stage) == p and all(len(v) == m
                                          for v in by_stage.values())


# ------------------------------------------------- subprocess parity
_PIPE_PRELUDE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.configs import get_config
    from repro.dist import sharding as sh
    from repro.models import shard_plan as sp
    from repro.models import transformer as tr

    def _shard_map(f, mesh, in_specs, out_specs):
        if hasattr(jax, "shard_map"):
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False)
        from jax.experimental.shard_map import shard_map as _sm
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)

    def run_case(name, tp, pipe, mb, cfg):
        B, S = 8, 32
        toks = jax.random.randint(jax.random.fold_in(
            jax.random.PRNGKey(7), hash(name) % 1000), (B, S), 0, cfg.vocab)
        batch = {"tokens": toks}
        params = tr.init_params(jax.random.PRNGKey(0), cfg)
        ref_loss, ref_grads = jax.value_and_grad(
            lambda p: tr.loss_fn(p, cfg, batch))(params)

        plan = tr.tp_plan(cfg, tp)
        pplan = sp.build_pipeline_plan(cfg, pipe, mb)
        assert pplan.active, (name, pplan)
        specs = sh.tp_specs(cfg, tp)
        pdims = sh.pipe_dims(cfg, pipe)

        def one(s, pd):
            hi = max(s.dim, pd)
            if hi < 0:
                return P()
            parts = [None] * (hi + 1)
            if pd >= 0:
                parts[pd] = "pipe"
            if s.dim >= 0:
                parts[s.dim] = "model"
            return P(*parts)

        pspec = jax.tree.map(one, specs, pdims)
        devs = np.array(jax.devices())[:pipe * tp]
        mesh = Mesh(devs.reshape(pipe, tp), ("pipe", "model"))

        def body(params, pidx, midx):
            tp_rt = (tr.TPRuntime("model", tp, midx[0], plan)
                     if plan.active else None)
            pipe_rt = sp.PipeRuntime("pipe", pipe, pidx[0], pplan)
            loss, grads = jax.value_and_grad(
                lambda p: tr.pipeline_loss_fn(p, cfg, batch, tp=tp_rt,
                                              pipe=pipe_rt))(params)
            if tp_rt is not None:
                grads = sh.tp_grad_sync(grads, specs, "model")
            grads = sh.pipe_grad_sync(grads, pdims, "pipe")
            return loss, grads

        fn = _shard_map(body, mesh,
                        in_specs=(pspec, P("pipe"), P("model")),
                        out_specs=(P(), pspec))
        with mesh:
            loss, grads = jax.jit(fn)(
                params, jnp.arange(pipe, dtype=jnp.int32),
                jnp.arange(tp, dtype=jnp.int32))
        errs = {"loss": abs(float(loss) - float(ref_loss)),
                "ring": plan.ctx > 1}
        worst = 0.0
        for g, r in zip(jax.tree.leaves(grads), jax.tree.leaves(ref_grads)):
            g, r = np.asarray(g, np.float64), np.asarray(r, np.float64)
            worst = max(worst, float(
                np.max(np.abs(g - r)) / max(np.max(np.abs(r)), 1e-4)))
        errs["grad_relerr"] = worst
        return errs
""")

PIPE_PARITY_SCRIPT = _PIPE_PRELUDE + textwrap.dedent("""
    # 4 layers so pp={2,4} both split into equal contiguous stages;
    # small width keeps the 8-device host subprocess fast-tier-cheap
    BASE = dataclasses.replace(
        get_config("qwen2-0.5b").smoke(), n_layers=4, d_model=128,
        head_dim=32, d_ff=256, vocab=256, attn_chunk=16)

    CASES = [
        ("pp2", 1, 2, 2, {}),            # pure pipeline, 2 microbatches
        ("pp2_tp2", 2, 2, 2, {}),        # pipe x model composite
        ("pp2_tp2_mb4", 2, 2, 4, {}),    # deeper 1F1B wavefront
        ("pp4_mb4", 1, 4, 4, {}),        # 4 stages, 1 layer each
        # GQA kv=2 < tp=4: heads don't divide, so attention runs the
        # ctx ppermute ring (online-softmax K/V rotation) INSIDE the
        # pipeline instead of falling back to replicated attention
        ("pp2_tp4_ring_gqa", 4, 2, 2, {}),
    ]

    out = {}
    for name, tp, pipe, mb, opts in CASES:
        cfg = dataclasses.replace(BASE, **opts)
        out[name] = run_case(name, tp, pipe, mb, cfg)
    assert out["pp2_tp4_ring_gqa"]["ring"]
    print("PPPARITY" + json.dumps(out))
""")

PIPE_TRAIN_STEP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.launch import train as lt
    from repro.models import transformer as tr
    from repro.optim import adam

    cfg = get_config("qwen2-0.5b").smoke()          # 2 layers -> pp=2
    out = {}
    for name, model, pipe, mb in [("client_pp2_tp2_mb4", 2, 2, 4),
                                  ("client_pp2_ring_gqa", 4, 2, 2)]:
        mesh = make_host_mesh(data=None, model=model, pipe=pipe)
        settings = lt.TrainSettings(grad_dtype="float32", microbatches=mb)
        opt = adam(1e-2)
        step, shardings = lt.make_train_step(cfg, mesh, opt, settings)
        params = tr.init_params(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                  cfg.vocab)
        ref_loss, ref_gr = jax.value_and_grad(
            lambda p: tr.loss_fn(p, cfg, {"tokens": toks}))(params)
        gn_ref = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                              for g in jax.tree.leaves(ref_gr)))
        with mesh:
            params_s = jax.device_put(params, shardings["store"])
            opt_state = opt.init(params_s)
            dsc_ref = lt.init_dsc_state(cfg, mesh, settings)
            _, _, _, m = jax.jit(step)(params_s, opt_state, dsc_ref,
                                       {"tokens": toks},
                                       jax.random.PRNGKey(2))
        out[name] = {
            "loss": abs(float(m["loss"]) - float(ref_loss)),
            "gnorm_relerr": abs(float(m["grad_norm"]) - float(gn_ref))
            / float(gn_ref)}
    print("PPPARITY" + json.dumps(out))
""")


def _run_parity_script(script: str) -> dict:
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, timeout=900,
                       env=SUBPROC_ENV)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("PPPARITY")][-1]
    return json.loads(line[len("PPPARITY"):])


def test_pipeline_loss_and_grads_match_replicated():
    """ISSUE 9 acceptance: the microbatched 1F1B pipeline body under a
    manual (pipe, model) shard_map reproduces the replicated loss AND
    per-leaf gradients to fp32 tolerance at pp={2,4} x tp x microbatch
    counts — including the GQA config whose attention rides the ctx
    ppermute ring instead of the replicated fallback."""
    out = _run_parity_script(PIPE_PARITY_SCRIPT)
    assert set(out) == {"pp2", "pp2_tp2", "pp2_tp2_mb4", "pp4_mb4",
                        "pp2_tp4_ring_gqa"}
    for name, errs in out.items():
        assert errs["loss"] < 1e-5, (name, errs)
        assert errs["grad_relerr"] < 1e-3, (name, errs)


def test_pipeline_train_step_matches_replicated():
    """The full train step (client x pipe x model mesh, FSA optimizer
    path, bucketed grad-norm) agrees with replicated autodiff on loss
    and gradient norm."""
    out = _run_parity_script(PIPE_TRAIN_STEP_SCRIPT)
    for name, errs in out.items():
        assert errs["loss"] < 1e-5, (name, errs)
        assert errs["gnorm_relerr"] < 1e-3, (name, errs)
