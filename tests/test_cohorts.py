"""Property battery for the population-scale cohort layer.

Covers the two primitives the async runtime stands on: the exact-once
Dirichlet population partition (``data.balanced_dirichlet_indices`` /
``data.federated_population``) and the keyed per-round cohort draw
(``pipeline.CohortSample``) — partition coverage, without-replacement
sampling, key determinism across engines, and the alpha-controlled
concentration trend of the non-IID split.
"""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import pipeline as pl
from repro.data import (balanced_dirichlet_indices, dirichlet_partition,
                        federated_population)

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------ exact-once partition
@settings(max_examples=12, deadline=None)
@given(K=st.sampled_from([2, 4, 6, 8]),
       alpha=st.floats(0.05, 8.0),
       n_classes=st.integers(2, 6),
       seed=st.integers(0, 2 ** 16))
def test_partition_covers_population_exactly_once(K, alpha, n_classes,
                                                  seed):
    """The concatenated client index lists are a PERMUTATION of
    arange(n): every sample lands on exactly one client, every client
    holds exactly its quota."""
    n = 24 * K
    key = jax.random.PRNGKey(seed)
    labels = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0,
                                n_classes)
    idx = balanced_dirichlet_indices(key, labels, K, alpha, n_classes)
    assert idx.shape == (K, n // K)
    flat = np.sort(np.asarray(idx).ravel())
    np.testing.assert_array_equal(flat, np.arange(n))


def test_partition_rejects_indivisible_population():
    labels = jnp.zeros(10, dtype=jnp.int32)
    try:
        balanced_dirichlet_indices(KEY, labels, 3, 0.5, 2)
    except ValueError as e:
        assert "divisible" in str(e)
    else:
        raise AssertionError("indivisible population must be rejected")


def test_partition_follows_dirichlet_owner_where_it_can():
    """Rebalancing only moves the surplus: clients the raw Dirichlet
    assignment left under quota keep every sample it gave them."""
    K, n_classes, n = 4, 3, 240
    labels = jax.random.randint(jax.random.fold_in(KEY, 1), (n,), 0,
                                n_classes)
    owner = np.asarray(dirichlet_partition(KEY, labels, K, 0.3, n_classes))
    idx = np.asarray(balanced_dirichlet_indices(KEY, labels, K, 0.3,
                                                n_classes))
    quota = n // K
    for k in range(K):
        raw = set(np.where(owner == k)[0].tolist())
        got = set(idx[k].tolist())
        if len(raw) <= quota:                 # deficit client: keeps all
            assert raw <= got
        else:                                 # surplus client: kept only
            assert got <= raw                 # its own samples


def test_federated_population_shapes_and_uniqueness():
    """(population, S, dim) / (population, S), and no sample row is
    handed to two clients (continuous features are a.s. distinct)."""
    x, y = federated_population(KEY, population=16, samples_per_client=5,
                                dim=6, n_classes=3, alpha=0.4)
    assert x.shape == (16, 5, 6) and y.shape == (16, 5)
    rows = np.asarray(x).reshape(-1, 6)
    assert len(np.unique(rows, axis=0)) == rows.shape[0]


# ----------------------------------------------- alpha => concentration
def test_concentration_monotone_in_alpha():
    """Smaller Dirichlet alpha => more label-skewed clients.  Measured
    as the mean (over clients and seeds) max-class fraction, the
    exact-coverage partition preserves the trend across a 100x alpha
    range."""
    K, n_classes, n = 8, 4, 960

    def concentration(alpha):
        vals = []
        for s in range(4):
            key = jax.random.PRNGKey(100 + s)
            labels = jax.random.randint(jax.random.fold_in(key, 1), (n,),
                                        0, n_classes)
            idx = np.asarray(balanced_dirichlet_indices(
                key, labels, K, alpha, n_classes))
            lab = np.asarray(labels)[idx]                 # (K, quota)
            frac = np.stack([(lab == c).mean(axis=1)
                             for c in range(n_classes)])  # (C, K)
            vals.append(frac.max(axis=0).mean())
        return float(np.mean(vals))

    c_skew, c_mid, c_iid = (concentration(a) for a in (0.05, 0.5, 5.0))
    assert c_skew > c_mid > c_iid, (c_skew, c_mid, c_iid)
    assert c_skew > 0.6                       # strongly skewed regime
    assert c_iid < 0.45                       # near-uniform regime


# ------------------------------------------------------- cohort draws
@settings(max_examples=16, deadline=None)
@given(population=st.integers(4, 64), frac=st.floats(0.1, 1.0),
       seed=st.integers(0, 2 ** 16))
def test_cohort_draw_without_replacement_and_exact_size(population, frac,
                                                        seed):
    cohort = max(1, int(population * frac))
    cs = pl.CohortSample(population=population, cohort=cohort)
    keys = pl.split_round_keys(jax.random.PRNGKey(seed))
    idx = np.asarray(cs.draw(keys))
    assert idx.shape == (cohort,)
    assert len(np.unique(idx)) == cohort                # no replacement
    assert idx.min() >= 0 and idx.max() < population


def test_cohort_draw_key_deterministic_and_round_varying():
    """Same round key => identical cohort (the cross-engine contract);
    different rounds => the draw actually varies."""
    cs = pl.CohortSample(population=40, cohort=8)
    draws = []
    for r in range(6):
        keys = pl.split_round_keys(jax.random.fold_in(KEY, r))
        again = pl.split_round_keys(jax.random.fold_in(KEY, r))
        d = np.asarray(cs.draw(keys))
        np.testing.assert_array_equal(d, np.asarray(cs.draw(again)))
        draws.append(tuple(d.tolist()))
    assert len(set(draws)) > 1


def test_cohort_draw_decorrelated_from_role_key_consumers():
    """The draw folds COHORT_SALT into the role key, so it never aliases
    a stage that consumes the raw role key (the eris engine maps every
    role to ``comp``)."""
    keys = pl.split_round_keys(KEY)
    cs = pl.CohortSample(population=32, cohort=32)
    raw = np.asarray(jax.random.permutation(getattr(keys, cs.key_role),
                                            32))
    assert tuple(np.asarray(cs.draw(keys))) != tuple(raw)


def test_cohort_gather_selects_rows():
    cs = pl.CohortSample(population=12, cohort=5)
    keys = pl.split_round_keys(KEY)
    batches = {"x": jnp.arange(12 * 3, dtype=jnp.float32).reshape(12, 3),
               "y": jnp.arange(12)}
    idx, got = cs.gather(keys, batches)
    np.testing.assert_array_equal(np.asarray(got["x"]),
                                  np.asarray(batches["x"])[np.asarray(idx)])
    np.testing.assert_array_equal(np.asarray(got["y"]),
                                  np.asarray(batches["y"])[np.asarray(idx)])


def test_cohort_size_validation():
    for population, cohort in ((4, 0), (4, 5), (0, 1)):
        try:
            pl.CohortSample(population=population, cohort=cohort)
        except ValueError:
            pass
        else:
            raise AssertionError((population, cohort))
