"""Flash-attention TRAINING path: custom-VJP backward gradient parity.

The forward is pinned to the oracle in test_kernels.py; here jax.grad
through the Pallas kernels (interpret mode on CPU) must match jax.grad
through the naive jnp reference — the blocked backward recomputes
p = exp(s - lse) per tile instead of saving the S x S score matrix, so
any drift in the recompute (mask bounds, GQA group sums, lse handling)
shows up as gradient error here and nowhere else.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention, supports
from repro.models import transformer as tr

KEY = jax.random.PRNGKey(0)


def _qkv(B, H, KV, S, d, seed=0):
    kk = jax.random.PRNGKey(seed)
    q = jax.random.normal(jax.random.fold_in(kk, 0), (B, H, S, d))
    k = jax.random.normal(jax.random.fold_in(kk, 1), (B, KV, S, d))
    v = jax.random.normal(jax.random.fold_in(kk, 2), (B, KV, S, d))
    return q, k, v


def _grad_parity(B, H, KV, S, d, *, causal, window, bq=64, bk=64):
    q, k, v = _qkv(B, H, KV, S, d)
    w = jax.random.normal(jax.random.fold_in(KEY, 9), (B, H, S, d))

    def loss_flash(q, k, v):
        o = flash_attention(q, k, v, causal=causal, window=window,
                            block_q=bq, block_k=bk, interpret=True)
        return jnp.sum(o * w)

    def loss_ref(q, k, v):
        return jnp.sum(ref.flash_attention_ref(
            q, k, v, causal=causal, window=window) * w)

    got = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    exp = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for name, g, e in zip("qkv", got, exp):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(e), rtol=5e-4, atol=5e-4,
            err_msg=f"d{name} B={B} H={H} KV={KV} S={S} d={d} "
                    f"causal={causal} window={window}")


# TP-local head counts: 8 heads at tp=1, the tp=2 shard (4 heads), and
# the tp=4 shard with grouped KV (the shapes _attn hands the kernel)
@pytest.mark.parametrize("H,KV", [(8, 8), (4, 2), (2, 1)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_backward_matches_ref(H, KV, causal):
    _grad_parity(2, H, KV, 128, 32, causal=causal, window=None)


@pytest.mark.parametrize("H,KV", [(4, 4), (4, 1)])
@pytest.mark.parametrize("window", [32, 64])
def test_flash_backward_sliding_window(H, KV, window):
    _grad_parity(1, H, KV, 128, 32, causal=True, window=window)


def test_flash_backward_uneven_blocks():
    # block_q != block_k exercises the asymmetric loop bounds in both
    # the dq and dkv kernels
    _grad_parity(1, 2, 2, 256, 32, causal=True, window=None, bq=128, bk=64)
    _grad_parity(1, 2, 2, 256, 32, causal=True, window=64, bq=64, bk=128)


def test_supports_gate():
    assert supports(128, 64) and supports(1024, 64)
    assert supports(64, 32)          # blocks clamp to S
    assert not supports(192, 32)     # 192 % min(128, 192) != 0


def test_model_train_grads_flash_vs_naive():
    """End-to-end: loss_fn grads with ModelConfig.flash_attention on ==
    the naive chunked-attention path (same params, same batch)."""
    cfg = get_config("qwen2-0.5b").smoke()
    assert supports(64, cfg.hd)
    params = tr.init_params(KEY, cfg)
    batch = {"tokens": jax.random.randint(jax.random.fold_in(KEY, 1),
                                          (2, 64), 0, cfg.vocab)}
    loss_n, g_n = jax.value_and_grad(tr.loss_fn)(params, cfg, batch)
    cfg_f = dataclasses.replace(cfg, flash_attention=True)
    loss_f, g_f = jax.value_and_grad(tr.loss_fn)(params, cfg_f, batch)
    np.testing.assert_allclose(float(loss_f), float(loss_n), rtol=1e-5)
    flat_n = jax.tree_util.tree_leaves(g_n)
    flat_f = jax.tree_util.tree_leaves(g_f)
    for a, b in zip(flat_f, flat_n):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)
