"""Wire-format contract for the int8 quantization pair (the FSA payload).

Three properties the communication claims rest on, checked on BOTH the
Pallas kernels (interpret mode) and the pure-jnp reference path:

  * bounded round-trip error: stochastic rounding moves a value by less
    than one grid step, so |dequantize(quantize(x)) - x| < scale_b
    coordinate-wise within each 256-block;
  * unbiasedness: E[dequantize(quantize(x))] = x over rounding draws
    (what makes Int8Wire an omega-compressor, Definition 3.1);
  * exact byte accounting: the payload is one int8 per (padded)
    coordinate + one f32 scale per 256-block — ~1.016 B/coord vs 2 B for
    the bf16 baseline — and ``wire_payload_bytes`` matches the actual
    buffers bit for bit.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.quantize import (QBLOCK, dequantize, quantize,
                                    wire_payload_bytes)

KEY = jax.random.PRNGKey(0)


def _round_trip(x, seed, path):
    if path == "pallas":
        q, sc = quantize(x, seed, interpret=True)
        return q, sc, dequantize(q, sc, interpret=True)
    q, sc = ref.quantize_ref(x, seed)
    return q, sc, ref.dequantize_ref(q, sc)


# ---------------------------------------------------------- error bound
@pytest.mark.parametrize("path", ["pallas", "ref"])
@pytest.mark.parametrize("n", [QBLOCK, 8 * QBLOCK])
def test_round_trip_error_bounded_per_block(path, n):
    x = 5.0 * jax.random.normal(KEY, (n,))
    _, sc, deq = _round_trip(x, jnp.uint32(3), path)
    err = np.abs(np.asarray(deq) - np.asarray(x)).reshape(-1, QBLOCK)
    scale = np.asarray(sc)[:, None]
    assert np.all(err <= scale * (1 + 1e-6)), (err.max(), scale.max())


@pytest.mark.parametrize("path", ["pallas", "ref"])
def test_zero_and_constant_blocks_exact(path):
    """A zero block has scale 0 and must round-trip exactly; a constant
    block sits exactly on the +-127 grid point."""
    x = jnp.concatenate([jnp.zeros(QBLOCK), jnp.full((QBLOCK,), 2.5)])
    _, _, deq = _round_trip(x, jnp.uint32(0), path)
    np.testing.assert_allclose(np.asarray(deq), np.asarray(x), rtol=1e-6)


# ---------------------------------------------------------- unbiasedness
@pytest.mark.parametrize("path", ["pallas", "ref"])
def test_stochastic_rounding_unbiased(path):
    n, trials = 4 * QBLOCK, 64
    x = jax.random.normal(KEY, (n,))
    acc = np.zeros(n)
    for s in range(trials):
        _, _, deq = _round_trip(x, jnp.uint32(s), path)
        acc += np.asarray(deq)
    mean = acc / trials
    scale = np.abs(np.asarray(x)).reshape(-1, QBLOCK).max(1) / 127.0
    # MC error of a Bernoulli grid draw: sd <= scale/2, so 4 sd over
    # sqrt(trials) is a comfortable per-coordinate bound
    bound = np.repeat(scale, QBLOCK) * (4.0 / (2 * np.sqrt(trials)))
    assert np.all(np.abs(mean - np.asarray(x)) <= bound + 1e-7)


@given(n_blocks=st.integers(1, 6), scale_pow=st.integers(-3, 3))
@settings(max_examples=10, deadline=None)
def test_round_trip_bound_property(n_blocks, scale_pow):
    """Property form over sizes and magnitudes (ref path: fast)."""
    n = n_blocks * QBLOCK
    x = (10.0 ** scale_pow) * jax.random.normal(
        jax.random.fold_in(KEY, n_blocks * 7 + scale_pow), (n,))
    q, sc, deq = _round_trip(x, jnp.uint32(11), "ref")
    err = np.abs(np.asarray(deq) - np.asarray(x)).reshape(-1, QBLOCK)
    assert np.all(err <= np.asarray(sc)[:, None] * (1 + 1e-6))
    assert np.asarray(q).dtype == np.int8


# -------------------------------------------------------- byte accounting
@pytest.mark.parametrize("path", ["pallas", "ref"])
@pytest.mark.parametrize("n", [QBLOCK, 17 * QBLOCK])
def test_exact_wire_bytes(path, n):
    """The transmitted buffers (int8 values + f32 scales) account to
    exactly ``wire_payload_bytes`` — and beat the bf16 baseline 2x-ish."""
    x = jax.random.normal(KEY, (n,))
    q, sc, _ = _round_trip(x, jnp.uint32(1), path)
    payload = np.asarray(q).nbytes + np.asarray(sc).nbytes
    assert payload == wire_payload_bytes(n) == n + 4 * (n // QBLOCK)
    bf16_baseline = 2 * n
    assert payload / bf16_baseline < 0.52


def test_wire_bytes_padding():
    """Non-block-aligned n pads up to the next 256 multiple."""
    n = QBLOCK + 7
    assert wire_payload_bytes(n) == 2 * QBLOCK + 4 * 2
    assert wire_payload_bytes(QBLOCK) == QBLOCK + 4


# ----------------------------------------------- distributed wire layouts
def test_wire_layout_matches_kernel_payload():
    """dist/sharding's per-leaf WireLayout (what launch/train.py
    quantizes and all_to_all's with) must agree with the kernel-level
    byte accounting: same QBLOCK, same padding, same payload bytes."""
    from repro.dist import sharding as sh
    assert sh.QBLOCK == QBLOCK
    for shape, n_client in [((512, 256), 4), ((300,), 4), ((64, 96), 8),
                            ((7,), 4)]:
        lay = sh.wire_layout_for(shape, n_client)
        if lay.dim < 0:
            assert shape == (7,)            # nothing divides -> psum path
            continue
        m = int(np.prod(shape)) // n_client
        assert lay.shard_elems == m
        assert lay.padded_elems % QBLOCK == 0
        assert lay.wire_bytes == wire_payload_bytes(m)


def test_mesh_wire_bytes_accounting():
    """Whole-model mesh payload: int8 layouts sum to n_client x the
    per-segment kernel payload for every scatterable leaf, and beat the
    bf16 baseline roughly 2x."""
    import jax
    from repro.configs import get_config
    from repro.dist import sharding as sh
    cfg = get_config("qwen2-0.5b").smoke()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    n_client = sh.client_count(mesh)
    expected = 0
    for lay in jax.tree.leaves(
            sh.int8_wire_layouts(cfg, mesh),
            is_leaf=lambda x: isinstance(x, sh.WireLayout)):
        assert lay.dim >= 0                 # n_client=1 divides everything
        expected += n_client * wire_payload_bytes(lay.shard_elems)
    got = sh.mesh_wire_bytes(cfg, mesh, int8=True)
    assert got == expected
    bf16 = sh.mesh_wire_bytes(cfg, mesh, int8=False, grad_bytes=2)
    assert 0.4 < got / bf16 < 0.6
