"""FSA correctness: Theorem B.1 (bit-exact equivalence with FedAvg),
mask properties, failure injection."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import baselines, fsa, masks

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("scheme", ["strided", "contiguous", "random"])
@pytest.mark.parametrize("n,A", [(16, 1), (17, 4), (256, 16), (100, 7)])
def test_masks_disjoint_complete(scheme, n, A):
    assign = masks.make_assignment(n, A, scheme, key=KEY)
    assert masks.check_disjoint_complete(assign, A)
    sizes = masks.shard_sizes(assign, A)
    assert int(sizes.sum()) == n
    assert int(sizes.max() - sizes.min()) <= int(np.ceil(n / A))


def test_shard_reassemble_roundtrip():
    n, A = 257, 5
    v = jax.random.normal(KEY, (n,))
    assign = masks.make_assignment(n, A, "strided")
    shards = fsa.shard_update(v, assign, A)
    # disjointness: per-coordinate at most one nonzero shard
    assert int(((shards != 0).sum(0) > 1).sum()) == 0
    np.testing.assert_array_equal(np.asarray(shards.sum(0)), np.asarray(v))


@settings(max_examples=25, deadline=None)
@given(n=st.integers(8, 200), A=st.integers(1, 8), K=st.integers(1, 6),
       seed=st.integers(0, 2**31 - 1))
def test_theorem_b1_fsa_equals_fedavg(n, A, K, seed):
    """Property: for any (n, A, K) the sharded round is BIT-IDENTICAL to
    the centralized FedAvg round (Theorem B.1)."""
    key = jax.random.PRNGKey(seed)
    kx, kg, kw = jax.random.split(key, 3)
    x = jax.random.normal(kx, (n,))
    grads = jax.random.normal(kg, (K, n))
    w = jax.random.uniform(kw, (K,), minval=0.5, maxval=2.0)
    assign = masks.make_assignment(n, A, "strided")
    lr = 0.31
    out = fsa.fsa_round_sharded(x, grads, assign, A, lr, weights=w)
    ref = baselines.fedavg_round(x, grads, lr, weights=w)
    np.testing.assert_allclose(np.asarray(out.x_new), np.asarray(ref),
                               rtol=0, atol=1e-6)


def test_theorem_b1_multi_round_trajectory():
    """Iterate equivalence over T rounds (induction step of Thm B.1)."""
    n, A, K, T = 64, 4, 3, 10
    key = jax.random.PRNGKey(1)
    x_fsa = x_avg = jax.random.normal(key, (n,))
    assign = masks.make_assignment(n, A, "contiguous")
    for t in range(T):
        g = jax.random.normal(jax.random.fold_in(key, t), (K, n))
        x_fsa = fsa.fsa_round_sharded(x_fsa, g, assign, A, 0.1).x_new
        x_avg = baselines.fedavg_round(x_avg, g, 0.1)
        np.testing.assert_allclose(np.asarray(x_fsa), np.asarray(x_avg),
                                   atol=1e-5)


def test_aggregator_view_is_masked():
    """A single aggregator observes only its shard of each client update
    (the privacy mechanism of Sec. 3.4)."""
    n, A, K = 64, 4, 3
    v = jax.random.normal(KEY, (K, n))
    assign = masks.make_assignment(n, A, "strided")
    out = fsa.fsa_round_sharded(jnp.zeros(n), v, assign, A, 1.0)
    views = out.shard_views                       # (A, K, n)
    for a in range(A):
        m = np.asarray(masks.mask_for(assign, a))
        np.testing.assert_array_equal(
            np.asarray(views[a]) * (1 - m), np.zeros((K, n)))
        frac = (np.asarray(views[a]) != 0).mean()
        assert frac <= 1.05 / A + 0.02            # observes ~n/A coords


def test_failures_no_failure_equals_fedavg():
    n, A, K = 48, 4, 5
    x = jax.random.normal(KEY, (n,))
    g = jax.random.normal(jax.random.fold_in(KEY, 1), (K, n))
    assign = masks.make_assignment(n, A, "strided")
    got = fsa.fsa_round_with_failures(
        x, g, assign, A, 0.2, jnp.ones(A, bool), jnp.ones((K, A), bool))
    ref = baselines.fedavg_round(x, g, 0.2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-6)


def test_aggregator_dropout_freezes_shard():
    """A dropped aggregator's coordinates stay at x^t for the round."""
    n, A, K = 40, 4, 3
    x = jax.random.normal(KEY, (n,))
    g = jax.random.normal(jax.random.fold_in(KEY, 2), (K, n))
    assign = masks.make_assignment(n, A, "strided")
    alive = jnp.array([True, False, True, True])
    got = fsa.fsa_round_with_failures(x, g, assign, A, 0.5, alive,
                                      jnp.ones((K, A), bool))
    m_dead = np.asarray(masks.mask_for(assign, 1)).astype(bool)
    np.testing.assert_array_equal(np.asarray(got)[m_dead],
                                  np.asarray(x)[m_dead])
    ref = baselines.fedavg_round(x, g, 0.5)
    np.testing.assert_allclose(np.asarray(got)[~m_dead],
                               np.asarray(ref)[~m_dead], atol=1e-6)


def test_link_failure_renormalizes():
    """With one dead link, that aggregator averages over the surviving
    clients only."""
    n, A, K = 12, 2, 4
    x = jnp.zeros(n)
    g = jax.random.normal(KEY, (K, n))
    assign = masks.make_assignment(n, A, "strided")
    links = jnp.ones((K, A), bool).at[0, 0].set(False)
    got = fsa.fsa_round_with_failures(x, g, assign, A, 1.0,
                                      jnp.ones(A, bool), links)
    m0 = np.asarray(masks.mask_for(assign, 0)).astype(bool)
    expect0 = -np.asarray(g[1:]).mean(0)[m0]          # client 0 missing
    np.testing.assert_allclose(np.asarray(got)[m0], expect0, atol=1e-6)
