"""Attention correctness: chunked == unchunked, GQA grouping, sliding
window, decode-vs-prefill consistency (incl. ring buffer)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L

KEY = jax.random.PRNGKey(0)


def naive_attention(q, k, v, window=None, q_offset=0):
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k) / np.sqrt(hd)
    qpos = q_offset + jnp.arange(Sq)
    kpos = jnp.arange(k.shape[1])
    mask = kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, -1)
    return jnp.einsum("bkgqs,bskh->bqkgh", w, v).reshape(B, Sq, H, hd)


@pytest.mark.parametrize("S,chunk", [(64, 64), (64, 16), (60, 16), (7, 3)])
@pytest.mark.parametrize("H,KV", [(4, 4), (4, 2), (6, 1)])
def test_chunked_equals_naive(S, chunk, H, KV):
    hd, B = 8, 2
    q = jax.random.normal(KEY, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, KV, hd))
    got = L.causal_attention(q, k, v, chunk=chunk)
    ref = naive_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_sliding_window_masks_far_keys():
    B, S, H, hd, W = 1, 32, 2, 4, 8
    q = jax.random.normal(KEY, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, H, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 4), (B, S, H, hd))
    got = L.causal_attention(q, k, v, window=W, chunk=16)
    ref = naive_attention(q, k, v, window=W)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)
    # changing a key outside every window must not change outputs
    k2 = k.at[:, 0].set(99.0)
    got2 = L.causal_attention(q, k2, v, window=W, chunk=16)
    np.testing.assert_allclose(np.asarray(got2[:, W:]),
                               np.asarray(got[:, W:]), atol=2e-5)


def test_decode_matches_prefill_row():
    """decode_attention at position p == row p of full causal attention."""
    B, S, H, KV, hd = 2, 24, 4, 2, 8
    q = jax.random.normal(KEY, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 5), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 6), (B, S, KV, hd))
    full = naive_attention(q, k, v)
    for p in [0, 5, 23]:
        got = L.decode_attention(q[:, p:p + 1], k, v, p)
        np.testing.assert_allclose(np.asarray(got[:, 0]),
                                   np.asarray(full[:, p]), atol=2e-5)


def test_decode_ring_buffer_window():
    """Ring-buffered sliding-window cache == windowed attention."""
    B, S, H, hd, W = 1, 20, 2, 4, 8
    q = jax.random.normal(KEY, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 7), (B, S, H, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 8), (B, S, H, hd))
    ref = naive_attention(q, k, v, window=W)
    k_cache = jnp.zeros((B, W, H, hd))
    v_cache = jnp.zeros((B, W, H, hd))
    for p in range(S):
        slot = p % W
        k_cache = k_cache.at[:, slot].set(k[:, p])
        v_cache = v_cache.at[:, slot].set(v[:, p])
        got = L.decode_attention(q[:, p:p + 1], k_cache, v_cache, p, window=W)
        np.testing.assert_allclose(np.asarray(got[:, 0]),
                                   np.asarray(ref[:, p]), atol=2e-5,
                                   err_msg=f"pos {p}")


def test_rope_relative():
    """RoPE: dot products depend only on relative distance."""
    hd = 16
    x = jax.random.normal(KEY, (1, 1, 1, hd))
    y = jax.random.normal(jax.random.fold_in(KEY, 9), (1, 1, 1, hd))
    def dot_at(p, q):
        xp = L.rope(x, jnp.array([[p]]))
        yq = L.rope(y, jnp.array([[q]]))
        return float((xp * yq).sum())
    assert dot_at(3, 1) == pytest.approx(dot_at(12, 10), abs=1e-4)
    assert dot_at(3, 1) != pytest.approx(dot_at(3, 2), abs=1e-3)
