"""Tensor-parallelism (model axis) contracts.

Two layers of coverage for the family-generic manual-collective TP
(``models/shard_plan``):

  * property tests (no devices): ``TPSpec`` maps EVERY entry of
    ``transformer.param_spec`` with tree congruence, shard dims divide,
    plan fallbacks (GQA kv < tp, indivisible experts/heads) and the
    composite model x client store spec — across all five families;
  * sharded-vs-replicated parity (subprocess, 4 host devices):
    ``loss_fn(tp=None)`` against the 2-way and 4-way TP lowering under a
    manual shard_map — loss AND gradients to fp32 tolerance.  One
    subprocess sweeps the dense-family plan variants (col/row/vocab/
    partial kinds), a second sweeps the family plans of ISSUE 4:
    expert-parallel MoE (token all_to_all dispatch), head-sharded mLSTM,
    channel-sharded hybrid mamba, and sequence-parallel dense (incl.
    the replicated-attention fallback inside a seq plan).
"""
import dataclasses
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import SUBPROC_ENV
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.dist import sharding as sh
from repro.models import shard_plan as sp
from repro.models import transformer as tr


def _smoke(arch: str):
    return get_config(arch).smoke()


# ------------------------------------------------------------ TPSpec map
@pytest.mark.parametrize("arch", ["qwen2-0.5b", "olmoe-1b-7b", "xlstm-350m",
                                  "hymba-1.5b", "internvl2-26b"])
@pytest.mark.parametrize("tp", [1, 2, 4])
def test_tp_specs_cover_param_tree(arch, tp):
    """Every param leaf gets a TPSpec (congruent trees), every sharded
    dim divides, and tp == 1 replicates everything."""
    cfg = _smoke(arch)
    specs = sh.tp_specs(cfg, tp)
    params = jax.eval_shape(lambda k: tr.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    assert (jax.tree_util.tree_structure(specs)
            == jax.tree_util.tree_structure(params))
    plan = tr.tp_plan(cfg, tp)
    for p, s in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(specs)):
        assert isinstance(s, sh.TPSpec)
        if s.dim >= 0:
            assert p.shape[s.dim] % tp == 0, (p.shape, s)
            assert s.kind in ("col", "row", "vocab", "expert")
        else:
            assert s.kind in ("replicate", "partial")
    if tp == 1:
        assert not plan.active
        assert all(s.kind == "replicate"
                   for s in jax.tree_util.tree_leaves(specs))


def test_tp_plan_fallbacks():
    cfg = _smoke("qwen2-0.5b")          # heads=4, kv=2, d_ff=512, V=512
    assert tr.tp_plan(cfg, 2) == tr.TPPlan(2, attn=True, ffn=True,
                                           vocab=True)
    p4 = tr.tp_plan(cfg, 4)
    assert not p4.attn                  # kv=2 cannot split 4 ways
    assert p4.ffn and p4.vocab and p4.active
    assert not tr.tp_plan(cfg, 1).active
    p3 = tr.tp_plan(cfg, 3)
    assert not (p3.attn or p3.ffn or p3.vocab)  # nothing divides by 3
    assert p3.ctx == 3 and p3.active    # ...but the ctx ring shards the
    # sequence at ANY size (weights replicated; the runtime still falls
    # back per-trace when S itself doesn't divide)
    qk = dataclasses.replace(cfg, qk_norm=True)
    specs = sh.tp_specs(qk, 2)
    assert specs["blocks"]["q_norm"].kind == "partial"
    # at tp=4 attention head-sharding falls back, but the ctx ring
    # sequence-shards the region, so its grads are still slice-partial
    assert sh.tp_specs(qk, 4)["blocks"]["q_norm"].kind == "partial"


def test_family_plans():
    """ISSUE 4: every family in the zoo gets an active model-axis plan
    (at a divisible size) — moe/ssm/hybrid no longer replicate."""
    moe = _smoke("olmoe-1b-7b")         # smoke: 4 experts, heads=4, kv=2
    p = tr.tp_plan(moe, 2)
    assert p.moe and p.vocab and p.attn and p.active
    p4 = tr.tp_plan(moe, 4)
    assert p4.moe and not p4.attn       # kv=2: attention falls back
    specs = sh.tp_specs(moe, 2)
    assert specs["blocks"]["w_gate"] == sh.TPSpec(1, "expert")
    assert specs["blocks"]["router"].kind == "partial"

    ssm = _smoke("xlstm-350m")          # 4 mLSTM heads, gated 2*D proj
    p = tr.tp_plan(ssm, 4)
    assert p.mixer and p.ffn and p.vocab and p.active
    specs = sh.tp_specs(ssm, 4)
    assert specs["blocks"]["xq"] == sh.TPSpec(2, "col")
    assert specs["blocks"]["xo"] == sh.TPSpec(1, "row")
    assert specs["blocks"]["b_i"] == sh.TPSpec(1, "col")
    assert specs["blocks"]["p_down"] == sh.TPSpec(1, "row")

    hyb = _smoke("hymba-1.5b")          # channel-sharded mamba branch
    p = tr.tp_plan(hyb, 2)
    assert p.mixer and p.ffn and p.attn
    specs = sh.tp_specs(hyb, 2)
    assert specs["blocks"]["m_dt"] == sh.TPSpec(2, "col")
    assert specs["blocks"]["m_out"] == sh.TPSpec(1, "row")
    assert specs["blocks"]["m_in"].kind == "partial"
    assert specs["blocks"]["m_bc"].kind == "partial"
    # indivisible experts/heads fall back to replication of that region
    odd = dataclasses.replace(moe, n_experts=3)
    assert not tr.tp_plan(odd, 2).moe


def test_seq_plan_gating_and_partial_kinds():
    """A seq plan needs ffn+vocab; block/final norms (and, under the
    GQA attention fallback, the attention leaves) become partial-grad."""
    cfg = dataclasses.replace(_smoke("qwen2-0.5b"), seq_parallel=True)
    p2 = tr.tp_plan(cfg, 2)
    assert p2.seq and p2.attn
    specs = sh.tp_specs(cfg, 2)
    assert specs["blocks"]["ln1"].kind == "partial"
    assert specs["ln_f"].kind == "partial"
    assert specs["blocks"]["wq"] == sh.TPSpec(2, "col")
    p4 = tr.tp_plan(cfg, 4)             # kv=2: attention replicates...
    assert p4.seq and not p4.attn
    specs4 = sh.tp_specs(cfg, 4)
    # ...but its grads only cover this position's sequence slice
    assert specs4["blocks"]["wq"].kind == "partial"
    assert specs4["blocks"]["wo"].kind == "partial"
    # without a shardable vocab (or ffn) the seq request is refused
    odd_v = dataclasses.replace(cfg, vocab=511)
    assert not tr.tp_plan(odd_v, 2).seq
    # and without the knob nothing changes
    off = dataclasses.replace(cfg, seq_parallel=False)
    assert not tr.tp_plan(off, 2).seq
    assert sh.tp_specs(off, 2)["blocks"]["ln1"].kind == "replicate"


def test_param_roles_cover_every_family():
    """The role table names every block leaf of every family's
    param_spec (the metadata tp_specs derives placements from)."""
    for arch in ["qwen2-0.5b", "olmoe-1b-7b", "xlstm-350m", "hymba-1.5b"]:
        cfg = _smoke(arch)
        roles = sp.PARAM_ROLES[cfg.family]
        for name in tr.param_spec(cfg)["blocks"]:
            if name in ("ln1", "ln2"):
                continue                # norm scales: seq-partial rule
            assert name in roles, (cfg.family, name)


@given(pre=st.integers(1, 3), mid=st.integers(1, 4), post=st.integers(1, 3),
       dim=st.integers(0, 2), tp=st.sampled_from([1, 2, 4]))
@settings(max_examples=25, deadline=None)
def test_tp_split_merge_roundtrip(pre, mid, post, dim, tp):
    shape = [3 * pre, 4 * mid, 5 * post]
    shape[dim] *= tp
    x = jnp.arange(np.prod(shape), dtype=jnp.float32).reshape(shape)
    spec = sh.TPSpec(dim, "col")
    shards = sh.tp_split_leaf(x, spec, tp)
    assert shards.shape == (tp, *sh.tp_local_shape(tuple(shape), spec, tp))
    np.testing.assert_array_equal(sh.tp_merge_leaf(shards, spec), x)
    # replicated leaves: stacked copies, merge = shard 0
    rep = sh.TPSpec()
    np.testing.assert_array_equal(
        sh.tp_merge_leaf(sh.tp_split_leaf(x, rep, tp), rep), x)


def test_composite_store_spec():
    from jax.sharding import PartitionSpec as P
    # distinct dims: one axis each
    assert sh.composite_store_spec(2, 1, "data") == P(None, "data", "model")
    # same dim: model-major contiguous blocks, client-segmented within
    assert sh.composite_store_spec(1, 1, ("pod", "data")) == \
        P(None, ("model", "pod", "data"))
    assert sh.composite_store_spec(-1, 0, "data") == P("data")
    assert sh.composite_store_spec(0, -1, "data") == P("model")
    assert sh.composite_store_spec(-1, -1, "data") == P()


def test_store_layout_is_model_and_client_sharded():
    """The 'store' layout of a TP-able config shards FFN/vocab leaves
    over BOTH meshes and keeps every leaf's spec consistent with its
    TP-local scatter dim."""
    cfg = _smoke("qwen2-0.5b")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # build specs AS IF the mesh were (2 data, 2 model) — spec math only
    specs = sh.tp_specs(cfg, 2)
    assert specs["embed"] == sh.TPSpec(0, "vocab")
    assert specs["blocks"]["w_down"] == sh.TPSpec(1, "row")
    assert specs["blocks"]["wo"] == sh.TPSpec(1, "row")
    # on the real (trivial) mesh the composite reduces to the FSA layout
    from jax.sharding import PartitionSpec as P
    store = sh.store_specs(cfg, mesh)
    for s in jax.tree_util.tree_leaves(
            store, is_leaf=lambda x: isinstance(x, P)):
        flat = []
        for part in tuple(s):
            flat.extend(part if isinstance(part, tuple) else [part])
        assert "model" not in flat, s


# ----------------------------------------- sharded-vs-replicated parity
_PARITY_PRELUDE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.configs import get_config
    from repro.dist import sharding as sh
    from repro.launch.train import _shard_map
    from repro.models import transformer as tr

    KEY = jax.random.PRNGKey(0)

    def run_case(name, tp, cfg, use_mask=False):
        params = tr.init_params(KEY, cfg)
        toks = jax.random.randint(jax.random.fold_in(KEY, 1), (2, 16),
                                  0, cfg.vocab)
        batch = {"tokens": toks}
        if use_mask:
            batch["loss_mask"] = (jax.random.uniform(
                jax.random.fold_in(KEY, 2), (2, 16)) > 0.3).astype(
                jnp.float32)

        ref_loss, ref_grads = jax.value_and_grad(
            lambda p: tr.loss_fn(p, cfg, batch))(params)

        mesh = Mesh(np.array(jax.devices()[:tp]), ("model",))
        specs = sh.tp_specs(cfg, tp)
        plan = tr.tp_plan(cfg, tp)
        pspec = jax.tree.map(
            lambda s: P(*([None] * s.dim + ["model"])) if s.dim >= 0
            else P(), specs)

        def body(params, midx):
            tp_rt = tr.TPRuntime("model", tp, midx[0], plan)
            loss, grads = jax.value_and_grad(
                lambda p: tr.loss_fn(p, cfg, batch, tp=tp_rt))(params)
            grads = sh.tp_grad_sync(grads, specs, "model")
            return loss, grads

        fn = _shard_map(body, mesh, in_specs=(pspec, P("model")),
                        out_specs=(P(), pspec))
        with mesh:
            loss, grads = jax.jit(fn)(params,
                                      jnp.arange(tp, dtype=jnp.int32))
        errs = {"loss": abs(float(loss) - float(ref_loss))}
        worst = 0.0       # per-leaf max abs error, scaled by the leaf's
        for g, r in zip(jax.tree.leaves(grads), jax.tree.leaves(ref_grads)):
            g, r = np.asarray(g, np.float64), np.asarray(r, np.float64)
            # scale floor: leaves whose true grad is pure f32 noise
            # (e.g. mLSTM gate biases at init, ~1e-8) stay comparable
            worst = max(worst, float(
                np.max(np.abs(g - r)) / max(np.max(np.abs(r)), 1e-4)))
        errs["grad_relerr"] = worst
        return errs
""")

PARITY_TP_SCRIPT = _PARITY_PRELUDE + textwrap.dedent("""
    # minimal TP-able config: the wiring is identical per layer, so one
    # layer at small width keeps the subprocess fast-tier-cheap while
    # exercising every collective placement
    BASE = dataclasses.replace(
        get_config("qwen2-0.5b").smoke(), n_layers=1, d_model=128,
        head_dim=32, d_ff=256, vocab=256, attn_chunk=16)

    CASES = [
        ("tp2_full", 2, {}),                       # attn+ffn+vocab all TP
        ("tp4_gqa_fallback", 4, {}),               # kv=2: attn replicated
        ("tp2_qknorm_untied", 2,                   # partial grads + lm_head
         dict(qk_norm=True, tie_embeddings=False, loss_fp32_logits=False)),
        ("tp4_masked", 4, {"_mask": True}),
    ]

    out = {}
    for name, tp, opts in CASES:
        opts = dict(opts)
        use_mask = opts.pop("_mask", False)
        out[name] = run_case(name, tp, dataclasses.replace(BASE, **opts),
                             use_mask)
    print("TPPARITY" + json.dumps(out))
""")


PARITY_FAMILY_SCRIPT = _PARITY_PRELUDE + textwrap.dedent("""
    def small(arch, **kw):
        return dataclasses.replace(get_config(arch).smoke(), n_layers=1,
                                   **kw)

    CASES = [
        # expert-parallel MoE: group-sharded tokens, all_to_all
        # dispatch/combine, replicated router w/ partial grads; tp4 also
        # exercises the GQA attention fallback alongside expert sharding
        ("moe_tp2", 2, small("olmoe-1b-7b", moe_group_size=8)),
        ("moe_tp4", 4, small("olmoe-1b-7b", moe_group_size=8)),
        # head-sharded mLSTM mixer + gated in-block projection pair
        ("ssm_tp2", 2, small("xlstm-350m")),
        ("ssm_tp4", 4, small("xlstm-350m")),
        # hybrid: attention (tp2) / fallback (tp4) + channel-sharded
        # mamba branch (m_in/m_bc partial, psum'd m_ln statistics) + ffn
        ("hybrid_tp2", 2, small("hymba-1.5b")),
        ("hybrid_tp4", 4, small("hymba-1.5b")),
        # sequence parallelism: psum_scatter/all_gather conjugates; tp4
        # runs the replicated-attention region inside the seq plan
        ("seq_tp2", 2, small("qwen2-0.5b", seq_parallel=True)),
        ("seq_tp4", 4, small("qwen2-0.5b", seq_parallel=True)),
    ]

    out = {}
    for name, tp, cfg in CASES:
        plan = tr.tp_plan(cfg, tp)
        assert plan.active, (name, plan)
        if name.startswith("moe"):
            assert plan.moe, plan
        if name.startswith(("ssm", "hybrid")):
            assert plan.mixer, plan
        if name.startswith("seq"):
            assert plan.seq, plan
        out[name] = run_case(name, tp, cfg)
    print("TPPARITY" + json.dumps(out))
""")


def _run_parity_script(script: str) -> dict:
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, timeout=900,
                       env=SUBPROC_ENV)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("TPPARITY")][-1]
    return json.loads(line[len("TPPARITY"):])


def test_tp_loss_and_grads_match_replicated():
    """Dense-family plan variants: loss_fn under 2-way and 4-way TP
    reproduces the replicated loss AND gradients to fp32 tolerance
    (full TP, GQA attention fallback, qk-norm partial grads, untied
    unembed, masked loss)."""
    out = _run_parity_script(PARITY_TP_SCRIPT)
    assert set(out) == {"tp2_full", "tp4_gqa_fallback",
                        "tp2_qknorm_untied", "tp4_masked"}
    for name, errs in out.items():
        assert errs["loss"] < 1e-5, (name, errs)
        assert errs["grad_relerr"] < 1e-3, (name, errs)


def test_family_plans_match_replicated():
    """ISSUE 4 acceptance: sharded-vs-replicated parity of loss AND
    grads at 2- and 4-way for an expert-parallel MoE config, a
    head-sharded SSM config, a channel-sharded hybrid config, and a
    dense config with sequence parallelism enabled."""
    out = _run_parity_script(PARITY_FAMILY_SCRIPT)
    assert set(out) == {"moe_tp2", "moe_tp4", "ssm_tp2", "ssm_tp4",
                        "hybrid_tp2", "hybrid_tp4", "seq_tp2", "seq_tp4"}
    for name, errs in out.items():
        assert errs["loss"] < 1e-5, (name, errs)
        assert errs["grad_relerr"] < 1e-3, (name, errs)
