"""Tensor-parallelism (model axis) contracts.

Two layers of coverage for the Megatron-style manual-collective TP:

  * property tests (no devices): ``TPSpec`` maps EVERY entry of
    ``transformer.param_spec`` with tree congruence, shard dims divide,
    split/merge round-trips, plan fallbacks (GQA kv < tp, moe/ssm
    families) and the composite model x client store spec;
  * sharded-vs-replicated parity (subprocess, 4 host devices):
    ``loss_fn(tp=None)`` against the 2-way and 4-way TP lowering under a
    manual shard_map — loss AND gradients to fp32 tolerance, sweeping
    qkv-bias/tied/qk-norm/untied/masked-loss variants so the col, row,
    vocab AND partial TPSpec kinds are all exercised.
"""
import dataclasses
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import SUBPROC_ENV
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.dist import sharding as sh
from repro.models import transformer as tr


def _smoke(arch: str):
    return get_config(arch).smoke()


# ------------------------------------------------------------ TPSpec map
@pytest.mark.parametrize("arch", ["qwen2-0.5b", "olmoe-1b-7b", "xlstm-350m",
                                  "hymba-1.5b", "internvl2-26b"])
@pytest.mark.parametrize("tp", [1, 2, 4])
def test_tp_specs_cover_param_tree(arch, tp):
    """Every param leaf gets a TPSpec (congruent trees), every sharded
    dim divides, and non-dense families replicate entirely."""
    cfg = _smoke(arch)
    specs = sh.tp_specs(cfg, tp)
    params = jax.eval_shape(lambda k: tr.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    assert (jax.tree_util.tree_structure(specs)
            == jax.tree_util.tree_structure(params))
    plan = tr.tp_plan(cfg, tp)
    for p, s in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(specs)):
        assert isinstance(s, sh.TPSpec)
        if s.dim >= 0:
            assert p.shape[s.dim] % tp == 0, (p.shape, s)
            assert s.kind in ("col", "row", "vocab")
        else:
            assert s.kind in ("replicate", "partial")
    if cfg.family not in ("dense", "audio", "vlm") or tp == 1:
        assert not plan.active
        assert all(s.kind == "replicate"
                   for s in jax.tree_util.tree_leaves(specs))


def test_tp_plan_fallbacks():
    cfg = _smoke("qwen2-0.5b")          # heads=4, kv=2, d_ff=512, V=512
    assert tr.tp_plan(cfg, 2) == tr.TPPlan(2, attn=True, ffn=True,
                                           vocab=True)
    p4 = tr.tp_plan(cfg, 4)
    assert not p4.attn                  # kv=2 cannot split 4 ways
    assert p4.ffn and p4.vocab and p4.active
    assert not tr.tp_plan(cfg, 1).active
    assert not tr.tp_plan(cfg, 3).active       # nothing divides by 3
    qk = dataclasses.replace(cfg, qk_norm=True)
    specs = sh.tp_specs(qk, 2)
    assert specs["blocks"]["q_norm"].kind == "partial"
    assert sh.tp_specs(qk, 4)["blocks"]["q_norm"].kind == "replicate"


@given(pre=st.integers(1, 3), mid=st.integers(1, 4), post=st.integers(1, 3),
       dim=st.integers(0, 2), tp=st.sampled_from([1, 2, 4]))
@settings(max_examples=25, deadline=None)
def test_tp_split_merge_roundtrip(pre, mid, post, dim, tp):
    shape = [3 * pre, 4 * mid, 5 * post]
    shape[dim] *= tp
    x = jnp.arange(np.prod(shape), dtype=jnp.float32).reshape(shape)
    spec = sh.TPSpec(dim, "col")
    shards = sh.tp_split_leaf(x, spec, tp)
    assert shards.shape == (tp, *sh.tp_local_shape(tuple(shape), spec, tp))
    np.testing.assert_array_equal(sh.tp_merge_leaf(shards, spec), x)
    # replicated leaves: stacked copies, merge = shard 0
    rep = sh.TPSpec()
    np.testing.assert_array_equal(
        sh.tp_merge_leaf(sh.tp_split_leaf(x, rep, tp), rep), x)


def test_composite_store_spec():
    from jax.sharding import PartitionSpec as P
    # distinct dims: one axis each
    assert sh.composite_store_spec(2, 1, "data") == P(None, "data", "model")
    # same dim: model-major contiguous blocks, client-segmented within
    assert sh.composite_store_spec(1, 1, ("pod", "data")) == \
        P(None, ("model", "pod", "data"))
    assert sh.composite_store_spec(-1, 0, "data") == P("data")
    assert sh.composite_store_spec(0, -1, "data") == P("model")
    assert sh.composite_store_spec(-1, -1, "data") == P()


def test_store_layout_is_model_and_client_sharded():
    """The 'store' layout of a TP-able config shards FFN/vocab leaves
    over BOTH meshes and keeps every leaf's spec consistent with its
    TP-local scatter dim."""
    cfg = _smoke("qwen2-0.5b")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # build specs AS IF the mesh were (2 data, 2 model) — spec math only
    specs = sh.tp_specs(cfg, 2)
    assert specs["embed"] == sh.TPSpec(0, "vocab")
    assert specs["blocks"]["w_down"] == sh.TPSpec(1, "row")
    assert specs["blocks"]["wo"] == sh.TPSpec(1, "row")
    # on the real (trivial) mesh the composite reduces to the FSA layout
    from jax.sharding import PartitionSpec as P
    store = sh.store_specs(cfg, mesh)
    for s in jax.tree_util.tree_leaves(
            store, is_leaf=lambda x: isinstance(x, P)):
        flat = []
        for part in tuple(s):
            flat.extend(part if isinstance(part, tuple) else [part])
        assert "model" not in flat, s


# ----------------------------------------- sharded-vs-replicated parity
PARITY_TP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.configs import get_config
    from repro.dist import sharding as sh
    from repro.launch.train import _shard_map
    from repro.models import transformer as tr

    KEY = jax.random.PRNGKey(0)
    # minimal TP-able config: the wiring is identical per layer, so one
    # layer at small width keeps the subprocess fast-tier-cheap while
    # exercising every collective placement
    BASE = dataclasses.replace(
        get_config("qwen2-0.5b").smoke(), n_layers=1, d_model=128,
        head_dim=32, d_ff=256, vocab=256, attn_chunk=16)

    CASES = [
        ("tp2_full", 2, {}),                       # attn+ffn+vocab all TP
        ("tp4_gqa_fallback", 4, {}),               # kv=2: attn replicated
        ("tp2_qknorm_untied", 2,                   # partial grads + lm_head
         dict(qk_norm=True, tie_embeddings=False, loss_fp32_logits=False)),
        ("tp4_masked", 4, {"_mask": True}),
    ]

    def run_case(name, tp, opts):
        opts = dict(opts)
        use_mask = opts.pop("_mask", False)
        cfg = dataclasses.replace(BASE, **opts)
        params = tr.init_params(KEY, cfg)
        toks = jax.random.randint(jax.random.fold_in(KEY, 1), (2, 16),
                                  0, cfg.vocab)
        batch = {"tokens": toks}
        if use_mask:
            batch["loss_mask"] = (jax.random.uniform(
                jax.random.fold_in(KEY, 2), (2, 16)) > 0.3).astype(
                jnp.float32)

        ref_loss, ref_grads = jax.value_and_grad(
            lambda p: tr.loss_fn(p, cfg, batch))(params)

        mesh = Mesh(np.array(jax.devices()[:tp]), ("model",))
        specs = sh.tp_specs(cfg, tp)
        plan = tr.tp_plan(cfg, tp)
        pspec = jax.tree.map(
            lambda s: P(*([None] * s.dim + ["model"])) if s.dim >= 0
            else P(), specs)

        def body(params, midx):
            tp_rt = tr.TPRuntime("model", tp, midx[0], plan)
            loss, grads = jax.value_and_grad(
                lambda p: tr.loss_fn(p, cfg, batch, tp=tp_rt))(params)
            grads = sh.tp_grad_sync(grads, specs, "model")
            return loss, grads

        fn = _shard_map(body, mesh, in_specs=(pspec, P("model")),
                        out_specs=(P(), pspec))
        with mesh:
            loss, grads = jax.jit(fn)(params,
                                      jnp.arange(tp, dtype=jnp.int32))
        errs = {"loss": abs(float(loss) - float(ref_loss))}
        worst = 0.0       # per-leaf max abs error, scaled by the leaf's
        for g, r in zip(jax.tree.leaves(grads), jax.tree.leaves(ref_grads)):
            g, r = np.asarray(g, np.float64), np.asarray(r, np.float64)
            worst = max(worst, float(
                np.max(np.abs(g - r)) / (np.max(np.abs(r)) + 1e-8)))
        errs["grad_relerr"] = worst
        return errs

    out = {name: run_case(name, tp, opts) for name, tp, opts in CASES}
    print("TPPARITY" + json.dumps(out))
""")


def test_tp_loss_and_grads_match_replicated():
    """ISSUE acceptance: loss_fn under 2-way and 4-way TP reproduces the
    replicated loss AND gradients to fp32 tolerance across plan variants
    (full TP, GQA attention fallback, qk-norm partial grads, untied
    unembed, masked loss)."""
    r = subprocess.run([sys.executable, "-c", PARITY_TP_SCRIPT],
                       capture_output=True, text=True, timeout=900,
                       env=SUBPROC_ENV)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("TPPARITY")][-1]
    out = json.loads(line[len("TPPARITY"):])
    assert set(out) == {"tp2_full", "tp4_gqa_fallback",
                        "tp2_qknorm_untied", "tp4_masked"}
    for name, errs in out.items():
        assert errs["loss"] < 1e-5, (name, errs)
        assert errs["grad_relerr"] < 1e-3, (name, errs)
