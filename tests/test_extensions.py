"""Paper optional features + infrastructure coverage:
heterogeneous shards (Sec. 5), FSA with server-side Adam/momentum
(Sec. 5 'Benefits'), checkpointing, input-spec registry, HLO analyzer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import baselines, dsc, fsa, masks
from repro.optim import adam, momentum

KEY = jax.random.PRNGKey(0)


# ------------------------------------------- heterogeneous shards (Sec. 5)
@settings(max_examples=15, deadline=None)
@given(n=st.integers(16, 300), seed=st.integers(0, 100))
def test_weighted_assignment_disjoint_complete(n, seed):
    w = jax.random.uniform(jax.random.PRNGKey(seed), (5,), minval=0.1)
    assign = masks.make_weighted_assignment(n, w,
                                            key=jax.random.PRNGKey(seed))
    assert masks.check_disjoint_complete(assign, 5)


def test_weighted_assignment_proportions_and_equivalence():
    n = 1000
    w = [0.5, 0.3, 0.2]
    assign = masks.make_weighted_assignment(n, w)
    sizes = np.asarray(masks.shard_sizes(assign, 3))
    np.testing.assert_allclose(sizes / n, w, atol=0.01)
    # Thm B.1 holds for ANY disjoint+complete masks, incl. weighted
    x = jax.random.normal(KEY, (n,))
    g = jax.random.normal(jax.random.fold_in(KEY, 1), (4, n))
    out = fsa.fsa_round_sharded(x, g, assign, 3, 0.1)
    ref = baselines.fedavg_round(x, g, 0.1)
    np.testing.assert_allclose(np.asarray(out.x_new), np.asarray(ref),
                               atol=1e-6)


# ------------------------------ FSA + any centralized optimizer (Sec. 5)
@pytest.mark.parametrize("make_opt", [lambda: adam(0.05),
                                      lambda: momentum(0.05)])
def test_fsa_with_server_optimizer_equals_centralized(make_opt):
    """Coordinate-wise server optimizers (FedAdam-style) commute with
    FSA sharding: each aggregator running the optimizer on its disjoint
    segment == the centralized optimizer on the full vector."""
    n, K, A, T = 96, 3, 4, 20
    opt_c, opt_s = make_opt(), make_opt()
    assign = masks.make_assignment(n, A, "strided")
    m = masks.masks_stacked(assign, A)                    # (A, n)
    x_c = x_s = jax.random.normal(KEY, (n,))
    st_c = opt_c.init(x_c)
    st_s = [opt_s.init(x_s * m[a]) for a in range(A)]     # per-aggregator
    for t in range(T):
        g = jax.random.normal(jax.random.fold_in(KEY, t), (K, n)).mean(0)
        # centralized
        d_c, st_c = opt_c.update(g, st_c, x_c)
        x_c = x_c + d_c
        # sharded: each aggregator updates its masked segment
        new_segs = []
        for a in range(A):
            d_a, st_s[a] = opt_s.update(g * m[a], st_s[a], x_s * m[a])
            new_segs.append((x_s * m[a] + d_a) * m[a])
        x_s = sum(new_segs)
        np.testing.assert_allclose(np.asarray(x_s), np.asarray(x_c),
                                   atol=1e-5, err_msg=f"t={t}")


# ------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import restore, save
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32),
                  "d": jnp.zeros((2, 2), jnp.float16)}}
    p = tmp_path / "ckpt.msgpack"
    save(p, tree)
    got = restore(p, jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_checkpoint_shape_mismatch_raises(tmp_path):
    from repro.checkpoint import restore, save
    p = tmp_path / "c.msgpack"
    save(p, {"w": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        restore(p, {"w": jnp.ones((3, 2))})


# --------------------------------------------------------- input specs
def test_input_specs_every_arch_and_shape():
    from repro.configs import ARCHS, get_config
    from repro.launch.shapes import SHAPES, input_specs
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES:
            spec = input_specs(cfg, shape)
            leaves = jax.tree.leaves(spec)
            assert leaves, (arch, shape)
            for leaf in leaves:
                assert isinstance(leaf, jax.ShapeDtypeStruct)
            if SHAPES[shape].kind == "decode":
                assert spec["token"].shape == (SHAPES[shape].global_batch, 1)
                # sub-quadratic policy: ssm archs carry recurrent state
                if cfg.family == "ssm":
                    assert "kv" not in spec["cache"]


# ------------------------------------------------------ HLO analyzer
def test_hlo_analyzer_trip_counts():
    from repro.launch.hlo_analysis import analyze
    D = 128
    W = jax.ShapeDtypeStruct((D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((8, D), jnp.float32)

    def fwd(w, x):
        def body(h, _):
            return jnp.tanh(h @ w), ()
        return jnp.sum(jax.lax.scan(body, x, None, length=6)[0])

    mm = 2 * 8 * D * D
    hlo = jax.jit(fwd).lower(W, x).compile().as_text()
    a = analyze(hlo)
    assert a["flops"] == pytest.approx(6 * mm, rel=0.01)

    def fwd_remat(w, x):
        body = jax.checkpoint(lambda h, _: (jnp.tanh(h @ w), ()))
        return jnp.sum(jax.lax.scan(body, x, None, length=6)[0])

    hlo_g = jax.jit(jax.grad(fwd_remat)).lower(W, x).compile().as_text()
    ag = analyze(hlo_g)
    # fwd 6 + remat-recompute 6 + bwd 2 dots x 6 = 24 matmul-equivalents
    assert ag["flops"] == pytest.approx(24 * mm, rel=0.05)


def test_dsc_telescoping_identity_compressor():
    """With C = Id and gamma = 1, v_global telescopes to mean(grads) every
    round regardless of history (hypothesis over random histories)."""
    K, n = 3, 20
    state = dsc.init_state(K, n)
    key = KEY
    for t in range(5):
        key, k1, k2 = jax.random.split(key, 3)
        grads = jax.random.normal(k1, (K, n))
        from repro.core.compressors import Identity
        v, s_new = dsc.client_compress(state, grads, Identity(), 1.0, k2)
        v_global, s_agg = dsc.aggregate(state, v, 1.0)
        np.testing.assert_allclose(np.asarray(v_global),
                                   np.asarray(grads.mean(0)), atol=1e-5)
        state = dsc.DSCState(s_new, s_agg)
