"""Quick (small-model) empirical privacy-audit suite — the tier-1 slice
of the Thm 3.3 monotonicity gate.

Covers: the bootstrap-CI upgrade of ``mia_audit`` (the audit key now
drives resampling instead of being dead), AUC monotone non-increasing in
A on seeded trajectories (interval-compared, not point-compared),
attacking the QUANTIZED wire (int8 payloads must not reconstruct better
than f32), Cor. D.2 collusion recovering the A=1 attack strength, and
the attacks running against transformer-family models from the config
zoo (token canaries for MIA, input-embedding DLG) — not just ravel'd
linear toys.  The full grid lives in ``benchmarks/privacy_snapshot.py``
and is regenerated + gated nightly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import masks as masks_lib
from repro.core import privacy
from repro.privacy import harness

KEY = jax.random.PRNGKey(0)


def ci_leq(lo_side, hi_side, slack: float = 0.0) -> bool:
    """Interval comparison: 'lo_side <= hi_side' holds unless the entire
    CI of lo_side sits above the entire CI of hi_side (plus slack)."""
    return lo_side[0] <= hi_side[1] + slack


# ------------------------------------------------------- bootstrap CIs
def test_mia_bootstrap_ci_uses_key():
    """The audit key drives a bootstrap CI on AUC / balanced accuracy:
    intervals bracket the point estimates, are deterministic per key,
    move with the key, and n_bootstrap=0 disables them."""
    spec = harness.AuditSpec(A=2, rounds=12, n_bootstrap=64, seed=1)
    params0, loss_fn, batches, members, non = harness.mlp_canary_problem(
        spec)
    run, x_traj, views = harness.capture_run(spec, params0, loss_fn,
                                             batches)
    assign = masks_lib.make_assignment(run.n, spec.A, spec.mask_scheme)
    obs, v = harness.coalition_views(views, assign, 1)
    grad_fn = jax.grad(lambda xf, c: loss_fn(
        run.unravel(xf), (c[:-1][None], c[-1][None].astype(jnp.int32))))

    r1 = privacy.mia_audit(jax.random.PRNGKey(7), grad_fn, x_traj, v, obs,
                           members, non, n_bootstrap=64)
    r2 = privacy.mia_audit(jax.random.PRNGKey(7), grad_fn, x_traj, v, obs,
                           members, non, n_bootstrap=64)
    r3 = privacy.mia_audit(jax.random.PRNGKey(8), grad_fn, x_traj, v, obs,
                           members, non, n_bootstrap=64)
    for r in (r1, r3):
        lo, hi = r["auc_ci"]
        assert 0.0 <= lo <= hi <= 1.0
        assert lo - 1e-6 <= r["auc"] <= hi + 1e-6
        blo, bhi = r["bal_acc_ci"]
        assert blo - 1e-6 <= r["balanced_accuracy"] <= bhi + 1e-6
    assert r1["auc_ci"] == r2["auc_ci"]          # keyed, deterministic
    assert r1["auc"] == r3["auc"]                # scores key-independent
    # intervals from different keys overlap (same underlying scores)
    assert ci_leq(r1["auc_ci"], r3["auc_ci"]) \
        and ci_leq(r3["auc_ci"], r1["auc_ci"])
    r0 = privacy.mia_audit(jax.random.PRNGKey(7), grad_fn, x_traj, v, obs,
                           members, non, n_bootstrap=0)
    assert "auc_ci" not in r0 and r0["auc"] == r1["auc"]


def test_mia_scan_scores_match_direct_computation():
    """The lax.scan round fold computes exactly the calibrated alignment
    score the pre-scan implementation defined."""
    n, T, C = 24, 5, 6
    k1, k2, k3 = jax.random.split(KEY, 3)
    x_traj = jax.random.normal(k1, (T, n))
    views = jax.random.normal(k2, (T, n))
    canaries = jax.random.normal(k3, (C, n))
    obs = masks_lib.mask_for(masks_lib.make_assignment(n, 2, "strided"), 0)

    def grad_fn(x, c):
        return c * jnp.sum(x) + x            # arbitrary smooth map

    got = privacy._mia_scores(grad_fn, x_traj, views, obs, canaries)
    want = np.zeros(C)
    for t in range(T):
        g = np.stack([np.asarray(grad_fn(x_traj[t], c) * obs)
                      for c in canaries])
        g = g - g.mean(0, keepdims=True)
        v = np.asarray(views[t] * obs)
        want += g @ v / (np.linalg.norm(v) + 1e-12)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)


# ------------------------------------------- Thm 3.3 monotonicity in A
AUDIT_KW = dict(rounds=40, lr=0.5, n_canaries=24, n_bootstrap=128)
AUDIT_DIM = 16


def test_mia_auc_monotone_in_A():
    """Same seed => same trajectory (Theorem B.1: the FSA aggregate is
    A-independent), so the audits at A = 1, 4, 8 attack the SAME
    trajectories through shrinking views — AUC must be monotone
    non-increasing, compared as bootstrap intervals plus a point-estimate
    tolerance band."""
    res = {A: harness.mia_mlp(harness.AuditSpec(A=A, seed=0, **AUDIT_KW),
                              dim=AUDIT_DIM) for A in (1, 4, 8)}
    assert res[1]["auc"] > 0.7                   # full view: strong attack
    for lo_A, hi_A in ((1, 4), (4, 8), (1, 8)):
        assert ci_leq(res[hi_A]["auc_ci"], res[lo_A]["auc_ci"]), (
            lo_A, hi_A, res[lo_A]["auc_ci"], res[hi_A]["auc_ci"])
        assert res[hi_A]["auc"] <= res[lo_A]["auc"] + 0.05, (lo_A, hi_A,
                                                             res)
    # the bound shrinks with A alongside the empirical attack
    assert res[8]["mi_bound"] < res[4]["mi_bound"] < res[1]["mi_bound"]


def test_mia_auc_monotone_with_int8_and_dsc_wire():
    """The monotone trend survives the REAL wire composition (DSC shifted
    compression + int8 round trip in the observed payload)."""
    mk = lambda A: harness.mia_mlp(harness.AuditSpec(
        A=A, seed=1, use_dsc=True, int8_wire=True, p=1.0, **AUDIT_KW),
        dim=AUDIT_DIM)
    res = {A: mk(A) for A in (1, 8)}
    assert res[1]["auc"] > 0.65
    assert ci_leq(res[8]["auc_ci"], res[1]["auc_ci"]), res
    assert res[8]["auc"] <= res[1]["auc"] + 0.05, res


def test_colluding_views_recover_full_attack_strength():
    """Cor. D.2: a coalition of a_c = A aggregators observes everything —
    its AUC matches the A=1 audit within interval tolerance, and AUC is
    non-decreasing in a_c (interval-compared) along the sweep."""
    sweep = harness.mia_mlp_collusion_sweep(
        harness.AuditSpec(A=8, seed=0, **AUDIT_KW), dim=AUDIT_DIM)
    full = harness.mia_mlp(harness.AuditSpec(A=1, seed=0, **AUDIT_KW),
                           dim=AUDIT_DIM)
    auc, ci = sweep["auc"], sweep["auc_ci"]
    # a_c = A union == the full view: identical scores to the A=1 audit
    np.testing.assert_allclose(auc[-1], full["auc"], atol=1e-6)
    # non-decreasing in a_c, interval-compared
    for i in range(len(auc) - 1):
        assert ci_leq(tuple(ci[i]), tuple(ci[i + 1])), (i, ci)


# ------------------------------------- sampling amplification (async)
def test_mia_sampling_amplification_quick():
    """AUC vs participation probability q (fixed A, async engine):
    q = 1 bit-recovers the synchronous audit, q < 1 masks the skipped
    rounds' wire rows to exactly zero, the amplified Thm 3.3 bound
    scales linearly in q, and the leakage stays monotone non-decreasing
    in q within interval tolerance."""
    kw = dict(A=4, rounds=10, n_canaries=8, n_bootstrap=32, lr=0.5,
              seed=2)
    res = harness.mia_mlp_sampling(harness.AuditSpec(**kw),
                                   (0.25, 1.0))
    sync = harness.mia_mlp(harness.AuditSpec(**kw))
    # q = 1 IS the synchronous engine (no arrival model in the pipeline)
    assert res[1.0]["auc"] == sync["auc"]
    assert res[1.0]["mi_bound"] == sync["mi_bound"]
    # the amplified bound is linear in the participation probability
    np.testing.assert_allclose(res[0.25]["mi_bound"],
                               0.25 * res[1.0]["mi_bound"], rtol=1e-9)
    # subsampling must not make the attack stronger (interval-compared)
    assert ci_leq(res[0.25]["auc_ci"], res[1.0]["auc_ci"]), res


def test_sampling_views_zero_on_skipped_rounds():
    """The async arrival model zeroes EVERY wire row of a dropped
    client-round: the adversary view of a skipped round carries nothing,
    and with q = 0.25 over 12 rounds some rounds are actually skipped
    (keyed draw, deterministic)."""
    spec = harness.AuditSpec(A=2, rounds=12, K=4, n_canaries=4,
                             n_bootstrap=0, q=0.25, seed=3)
    assert harness.fl_config(spec).method == "eris_async"
    params0, loss_fn, batches, _, _ = harness.mlp_canary_problem(spec)
    _, _, views = harness.capture_run(spec, params0, loss_fn, batches)
    mass = np.abs(np.asarray(views)).sum(axis=(1, 3))    # (T, K)
    alive = mass > 0
    assert not alive.all() and alive.any()
    # a round is skipped per client, not per coordinate: the client's
    # rows are zero across ALL aggregator shards at once
    per_agg = np.abs(np.asarray(views)).sum(axis=3)      # (T, A, K)
    assert ((per_agg > 0).all(axis=1) == alive).all()
    assert ((per_agg > 0).any(axis=1) == alive).all()


# ------------------------------------------------ attacking the wire
def test_dlg_against_int8_wire_not_better_than_f32():
    """DLG against the dequantized int8 payload must not reconstruct
    BETTER than against the f32 view (quantization adds noise, never
    information), at full view and under 1/8 sharding."""
    f32 = harness.dlg_mlp([1, 8], wire="f32", steps=300)
    s8 = harness.dlg_mlp([1, 8], wire="int8", steps=300)
    for A in (1, 8):
        assert s8[A] >= f32[A] - 0.05, (A, s8, f32)
    # and sharding still degrades the quantized-wire attack
    assert s8[8] > 2 * s8[1]
    assert f32[1] < 0.5                          # near-perfect at A=1


# ------------------------------------- transformer-family (config zoo)
def test_mia_transformer_family_monotone():
    """The audit runs against a transformer from the config zoo (token
    canaries, scan-compiled capture): members separate and the A-trend
    is monotone within interval tolerance."""
    cfg = harness.tiny_lm_config()
    mk = lambda A: harness.mia_lm(cfg, harness.AuditSpec(
        A=A, rounds=8, K=2, n_canaries=6, lr=0.5, seed=4,
        n_bootstrap=64))
    res = {A: mk(A) for A in (1, 8)}
    assert res[1]["auc"] > 0.8
    assert ci_leq(res[8]["auc_ci"], res[1]["auc_ci"]), res


def test_dlg_transformer_embedding_inversion():
    """DLG reconstructs the input EMBEDDINGS of a training sequence from
    the observed transformer gradient (``forward(inputs_embeds=...)``);
    an eighth of the view degrades the inversion."""
    cfg = harness.tiny_lm_config()
    out = harness.dlg_lm(cfg, [1, 8], wire="f32", steps=120)
    assert out[1] < 1.0                          # attack signal present
    assert out[8] > 1.5 * out[1]
    s8 = harness.dlg_lm(cfg, [1], wire="int8", steps=120)
    assert s8[1] >= out[1] - 0.05                # int8 never helps


# ------------------------------------------------- simulator view sums
def test_keep_views_sum_to_transmitted():
    """FSASharded views are the masked decomposition of the transmitted
    payload: summing an aggregator axis reassembles each client's full
    wire vector (disjoint + complete masks) — int8 wire included."""
    spec = harness.AuditSpec(A=4, rounds=3, int8_wire=True, seed=5,
                             n_bootstrap=0)
    params0, loss_fn, batches, _, _ = harness.mlp_canary_problem(spec)
    run, _, views = harness.capture_run(spec, params0, loss_fn, batches)
    views = np.asarray(views)                    # (T, A, K, n)
    total = views.sum(axis=1)                    # (T, K, n)
    # per-aggregator supports are disjoint: |sum| == sum |.|
    np.testing.assert_allclose(np.abs(views).sum(axis=1), np.abs(total),
                               rtol=1e-6, atol=1e-6)
    assert np.abs(total).max() > 0
