"""HBM-traffic proxy regression (the PR 6 leftover, fixed this PR).

``hlo_analysis.traffic_bytes`` must charge dynamic (update) slices at
SLICE size — standalone AND through fusions — instead of the full
sliced-into buffer.  Interpret-mode Pallas kernels lower to while-loop
grid emulations that address one chunk per trip; charging the whole
buffer per trip multiplied the memory term by the trip count and
inflated the ``opt`` dryrun entry's roofline (the ``flash_attention`` +
``overlap_collectives`` config looked 3x more memory-bound than the
base it is supposed to beat).

Two locks:

* a synthetic HLO module with known trip counts and slice sizes pins
  the exact charging rules (full-use fusions keep the conservative
  full charge; windowed accesses charge the window);
* the committed ``BENCH_tp.json`` pins the end-to-end consequence: the
  opt entry's roofline memory term stays comparable to base (reads the
  JSON directly — no benchmarks/ import — so the test is hermetic).
"""
import json
import pathlib

from repro.launch.hlo_analysis import HloModule

REPO = pathlib.Path(__file__).resolve().parent.parent

# A counted while loop (10 trips) whose body exercises every charging
# rule: a fusion reading a param only through dynamic-slice, a fusion
# rooted at dynamic-update-slice with an aliased buffer param, the
# standalone DS/DUS ops, and a full-tensor fusion (no override).
SYNTHETIC_HLO = """\
HloModule synthetic

%slice_body (sp0: f32[1024], sp1: s32[]) -> f32[16] {
  %sp0 = f32[1024] parameter(0)
  %sp1 = s32[] parameter(1)
  %ds = f32[16] dynamic-slice(%sp0, %sp1), dynamic_slice_sizes={16}
  ROOT %neg = f32[16] negate(%ds)
}

%dus_body (dp0: f32[1024], dp1: f32[16], dp2: s32[]) -> f32[1024] {
  %dp0 = f32[1024] parameter(0)
  %dp1 = f32[16] parameter(1)
  %dp2 = s32[] parameter(2)
  %m = f32[16] multiply(%dp1, %dp1)
  ROOT %dus = f32[1024] dynamic-update-slice(%dp0, %m, %dp2)
}

%full_body (fp0: f32[1024]) -> f32[1024] {
  %fp0 = f32[1024] parameter(0)
  ROOT %fneg = f32[1024] negate(%fp0)
}

%cond (cp: (f32[1024], s32[])) -> pred[] {
  %cp = (f32[1024], s32[]) parameter(0)
  %iv = s32[] get-tuple-element(%cp), index=1
  %limit = s32[] constant(10)
  ROOT %lt = pred[] compare(%iv, %limit), direction=LT
}

%body (bp: (f32[1024], s32[])) -> (f32[1024], s32[]) {
  %bp = (f32[1024], s32[]) parameter(0)
  %big = f32[1024] get-tuple-element(%bp), index=0
  %idx = s32[] get-tuple-element(%bp), index=1
  %f1 = f32[16] fusion(%big, %idx), kind=kLoop, calls=%slice_body
  %sds = f32[32] dynamic-slice(%big, %idx), dynamic_slice_sizes={32}
  %f2 = f32[1024] fusion(%big, %f1, %idx), kind=kLoop, calls=%dus_body
  %sdus = f32[1024] dynamic-update-slice(%f2, %sds, %idx)
  %f3 = f32[1024] fusion(%sdus), kind=kLoop, calls=%full_body
  %one = s32[] constant(1)
  %ivn = s32[] subtract(%idx, %one)
  ROOT %bt = (f32[1024], s32[]) tuple(%f3, %ivn)
}

ENTRY %main (a: f32[1024]) -> f32[1024] {
  %a = f32[1024] parameter(0)
  %zero = s32[] constant(0)
  %init = (f32[1024], s32[]) tuple(%a, %zero)
  %w = (f32[1024], s32[]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[1024] get-tuple-element(%w), index=0
}
"""


def test_synthetic_traffic_charges_slices_not_buffers():
    mod = HloModule(SYNTHETIC_HLO)
    # the counted loop's trip count propagates to body and cond
    assert mod.multipliers["body"] == 10
    assert mod.multipliers["cond"] == 10
    # fusion bodies are VMEM-internal: never charged directly
    assert {"slice_body", "dus_body", "full_body"} <= mod.fusion_bodies

    # --- per-trip charges, exact -------------------------------------
    # %f1 (DS-only param): reads min(slice 16*4, full) + idx scalar,
    #     writes the f32[16] result              -> 64 + 4 + 64  = 132
    # %sds (standalone DS, f32[32]): 2 * 128                     = 256
    # %f2 (DUS-rooted, aliased buffer): buffer read 0 + chunk
    #     f32[16] + idx, writes the update chunk -> 0 + 68 + 64  = 132
    # %sdus (standalone DUS, f32[32] update): 2 * 128            = 256
    # %f3 (full-tensor use): conservative full operand + result
    #     charge                                 -> 4096 + 4096  = 8192
    per_trip = 132 + 256 + 132 + 256 + 8192
    assert mod.traffic_bytes() == 10 * per_trip


def test_fusion_access_rules():
    mod = HloModule(SYNTHETIC_HLO)
    # DS-only param: read at summed slice size; index param untouched
    reads, result = mod._fusion_access("slice_body")
    assert reads == {0: 16 * 4}
    assert result is None
    # DUS root: aliased buffer reads 0, writes the update chunk only
    reads, result = mod._fusion_access("dus_body")
    assert reads == {0: 0}
    assert result == 16 * 4
    # full-tensor body: no overrides at all
    assert mod._fusion_access("full_body") == ({}, None)


def test_full_use_defeats_the_slice_override():
    """A param that is BOTH dynamic-sliced and used whole keeps the
    conservative full charge — the override only applies when every
    access is windowed."""
    hlo = SYNTHETIC_HLO.replace(
        "  ROOT %neg = f32[16] negate(%ds)\n",
        "  %red = f32[] reduce-sum-like(%sp0)\n"
        "  ROOT %neg = f32[16] negate(%ds)\n")
    mod = HloModule(hlo)
    reads, result = mod._fusion_access("slice_body")
    assert reads == {}            # sp0 fell back to the full charge
    assert result is None


def test_committed_opt_roofline_memory_comparable_to_base():
    """End-to-end lock on BENCH_tp.json: the flash+overlap ``opt``
    entry's memory term must stay comparable to ``base`` (< 2.5x: the
    remaining gap is remat recompute + interpret-loop carry copies, not
    per-grid-step full-operand charges, which made it ~3x before the
    fix and would grow with grid size).  The gate only triggers on
    regressions of the charging rule — both entries are regenerated by
    the same CI step."""
    bench = json.loads((REPO / "BENCH_tp.json").read_text())
    base = bench["eris-gptneo-1.3b/train_1k/2x16x16/base"]
    opt = bench["eris-gptneo-1.3b/train_1k/2x16x16/opt"]
    b_mem = base["roofline"]["terms_s"]["memory"]
    o_mem = opt["roofline"]["terms_s"]["memory"]
    assert o_mem < 2.5 * b_mem, (o_mem, b_mem)
    # and the roofline still ranks the optimised entry as compute/
    # memory sane: mfu bounds are finite and positive
    for rec in (base, opt):
        assert 0 < rec["roofline"]["mfu_upper_bound"] < 1


# ----------------------------------------- collective-permute axis labels
def _pairs_line(pairs):
    inner = ",".join("{%d,%d}" % p for p in pairs)
    return ("%cp = f32[128] collective-permute(%x), "
            "source_target_pairs={" + inner + "}")


def test_permute_axis_from_cycle_stride():
    """ppermutes carry no replica_groups, so the axis label comes from
    the source-target cycle stride: 1 = the minor-most 'model' ring,
    model_size = a 'pipe' boundary send, model*pipe = the client ring —
    and BOTH ring directions must classify identically (a reverse ring's
    deltas are -stride except the wraparound)."""
    from repro.launch.hlo_analysis import (_classify_permute,
                                           _permute_stride)
    fwd = _pairs_line([(0, 1), (1, 2), (2, 3), (3, 0)])
    rev = _pairs_line([(1, 0), (2, 1), (3, 2), (0, 3)])
    assert _permute_stride(fwd) == 1
    assert _permute_stride(rev) == 1
    assert _classify_permute(1, model_size=16, pipe_size=4) == "model"
    # pipe-boundary sends hop model_size ids
    pipe = _pairs_line([(0, 16), (16, 32), (32, 48), (48, 0)])
    assert _permute_stride(pipe) == 16
    assert _classify_permute(16, model_size=16, pipe_size=4) == "pipe"
    # client rings hop model*pipe ids
    assert _classify_permute(64, model_size=16, pipe_size=4) == "client"
    # unknown strides and unparseable lines stay on the 'all' bound
    assert _classify_permute(7, model_size=16, pipe_size=4) == "all"
    assert _permute_stride("%cp = f32[128] collective-permute(%x)") is None
    assert _classify_permute(None, model_size=16, pipe_size=4) == "all"
    # without a pipe axis, stride model_size is NOT a pipe send
    assert _classify_permute(16, model_size=16, pipe_size=1) != "pipe"
